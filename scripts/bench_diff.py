#!/usr/bin/env python3
"""Fail-soft bench diff: print per-metric deltas between two BENCH_*.json
trajectory files (`make bench-diff`).

Every numeric leaf shared by both files is reported as old -> new with an
absolute and relative delta; keys present in only one file are listed so a
new counter (or a dropped one) is visible at a glance.  The script never
fails the build: a missing or unparsable file prints a note and exits 0 —
the diff is advisory, the bench artifact itself is the record.
"""

import json
import sys


def flatten(value, prefix=""):
    """Flatten nested dicts/lists into {dotted.path: numeric leaf}."""
    out = {}
    if isinstance(value, dict):
        for k, v in value.items():
            out.update(flatten(v, f"{prefix}.{k}" if prefix else k))
    elif isinstance(value, list):
        for i, v in enumerate(value):
            out.update(flatten(v, f"{prefix}[{i}]"))
    elif isinstance(value, (int, float)) and not isinstance(value, bool):
        out[prefix] = float(value)
    return out


def main(argv):
    old_path = argv[1] if len(argv) > 1 else "BENCH_pr3.json"
    new_path = argv[2] if len(argv) > 2 else "BENCH_pr4.json"
    sides = {}
    for name, path in (("old", old_path), ("new", new_path)):
        try:
            with open(path) as f:
                sides[name] = flatten(json.load(f))
        except (OSError, ValueError) as e:
            print(f"bench-diff: cannot read {path}: {e} (skipping diff)")
            return 0
    old, new = sides["old"], sides["new"]
    shared = sorted(set(old) & set(new))
    print(f"bench-diff: {old_path} -> {new_path} ({len(shared)} shared metrics)")
    for key in shared:
        a, b = old[key], new[key]
        if a == b:
            continue
        rel = f" ({(b - a) / a * 100:+.1f}%)" if a else ""
        print(f"  {key}: {a:g} -> {b:g}  [{b - a:+g}{rel}]")
    only_old = sorted(set(old) - set(new))
    only_new = sorted(set(new) - set(old))
    if only_old:
        print(f"  only in {old_path}: {', '.join(only_old[:20])}"
              + (" ..." if len(only_old) > 20 else ""))
    if only_new:
        print(f"  only in {new_path}: {', '.join(only_new[:20])}"
              + (" ..." if len(only_new) > 20 else ""))
    if not only_old and not only_new and all(old[k] == new[k] for k in shared):
        print("  no differences")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
