#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file (`make trace-smoke`).

Checks the invariants Perfetto / chrome://tracing rely on:

* the file parses and `traceEvents` is a non-empty list
* every event carries `name`, `ph`, `pid`, `tid`, `ts`
* `B`/`E` pairs balance per (pid, tid) row and never go negative
* timestamps are monotonic non-decreasing per (pid, tid) row
* `X` events carry a non-negative `dur`

Exits non-zero with a diagnostic on the first violation — unlike the
bench diff, a malformed trace IS a build failure.
"""

import json
import sys


def fail(msg):
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main(argv):
    if len(argv) != 2:
        fail(f"usage: {argv[0]} TRACE.json")
    try:
        with open(argv[1]) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{argv[1]}: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing or empty")

    depth = {}  # (pid, tid) -> open B count
    last_ts = {}  # (pid, tid) -> last timestamp seen
    for i, e in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                fail(f"event {i} missing '{key}': {e}")
        if e["ph"] == "M":  # metadata rows carry no timestamp
            continue
        if "ts" not in e:
            fail(f"event {i} missing 'ts': {e}")
        row = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(row, 0):
            fail(f"event {i} ts {e['ts']} goes backwards on row {row}")
        last_ts[row] = e["ts"]
        if e["ph"] == "B":
            depth[row] = depth.get(row, 0) + 1
        elif e["ph"] == "E":
            depth[row] = depth.get(row, 0) - 1
            if depth[row] < 0:
                fail(f"event {i}: E without open B on row {row}")
        elif e["ph"] == "X" and e.get("dur", 0) < 0:
            fail(f"event {i}: negative dur: {e}")
    open_rows = {row: d for row, d in depth.items() if d != 0}
    if open_rows:
        fail(f"unbalanced B/E on rows: {open_rows}")
    print(
        f"validate_trace: ok — {len(events)} events, "
        f"{len(last_ts)} (pid,tid) rows, "
        f"{doc.get('otherData', {}).get('dropped_events', 0)} dropped"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
