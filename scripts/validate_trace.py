#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file (`make trace-smoke`).

Checks the invariants Perfetto / chrome://tracing rely on:

* the file parses and `traceEvents` is a non-empty list
* every event carries `name`, `ph`, `pid`, `tid`, `ts`
* `B`/`E` pairs balance per (pid, tid) row and never go negative
* timestamps are monotonic non-decreasing per (pid, tid) row
* `X` events carry a non-negative `dur`
* every request id observes the full lifecycle vocabulary: an `enqueue`,
  then EITHER a `shed` (with a reason) XOR an `admit` followed by a
  `retire`; `prime` implies a later `join`, `join` implies a `leave`
  (continuous batching), and `decode_step` never precedes `join`

Exits non-zero with a diagnostic on the first violation — unlike the
bench diff, a malformed trace IS a build failure.
"""

import json
import sys


def fail(msg):
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main(argv):
    if len(argv) != 2:
        fail(f"usage: {argv[0]} TRACE.json")
    try:
        with open(argv[1]) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{argv[1]}: {e}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing or empty")

    LIFECYCLE = {"enqueue", "admit", "shed", "prime", "join",
                 "decode_step", "retire", "leave"}
    depth = {}  # (pid, tid) -> open B count
    last_ts = {}  # (pid, tid) -> last timestamp seen
    life = {}  # request id -> [(lifecycle name, ts)]
    for i, e in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                fail(f"event {i} missing '{key}': {e}")
        if e["ph"] == "M":  # metadata rows carry no timestamp
            continue
        if "ts" not in e:
            fail(f"event {i} missing 'ts': {e}")
        row = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(row, 0):
            fail(f"event {i} ts {e['ts']} goes backwards on row {row}")
        last_ts[row] = e["ts"]
        if e["ph"] == "B":
            depth[row] = depth.get(row, 0) + 1
        elif e["ph"] == "E":
            depth[row] = depth.get(row, 0) - 1
            if depth[row] < 0:
                fail(f"event {i}: E without open B on row {row}")
        elif e["ph"] == "X" and e.get("dur", 0) < 0:
            fail(f"event {i}: negative dur: {e}")
        args = e.get("args") or {}
        if e["ph"] == "i" and e["name"] in LIFECYCLE and args.get("req") is not None:
            if e["name"] == "shed" and not args.get("reason"):
                fail(f"event {i}: shed without a reason: {e}")
            life.setdefault(args["req"], []).append((e["name"], e["ts"]))
    open_rows = {row: d for row, d in depth.items() if d != 0}
    if open_rows:
        fail(f"unbalanced B/E on rows: {open_rows}")

    # per-request lifecycle vocabulary: a truncated or mis-instrumented
    # trace must not validate just because its rows happen to balance
    for req, evs in sorted(life.items()):
        seen = {n for n, _ in evs}
        names = [n for n, _ in evs]
        if "enqueue" not in seen:
            fail(f"request {req}: no 'enqueue' (saw {names})")
        if "shed" in seen and "admit" in seen:
            fail(f"request {req}: both shed and admitted")
        if "shed" not in seen and "admit" not in seen:
            fail(f"request {req}: neither shed nor admitted")
        if "shed" in seen:
            continue  # shed requests end their lifecycle at the shed
        if "retire" not in seen:
            fail(f"request {req}: admitted but never retired (truncated trace?)")
        if "prime" in seen and "join" not in seen:
            fail(f"request {req}: primed but never joined the running batch")
        if "join" in seen and "leave" not in seen:
            fail(f"request {req}: joined but never left")
        if "decode_step" in seen:
            if "join" not in seen:
                fail(f"request {req}: decode_step without a join")
            first_step = min(ts for n, ts in evs if n == "decode_step")
            join_ts = min(ts for n, ts in evs if n == "join")
            if first_step < join_ts:
                fail(f"request {req}: decode_step at {first_step} "
                     f"precedes join at {join_ts}")

    print(
        f"validate_trace: ok — {len(events)} events, "
        f"{len(last_ts)} (pid,tid) rows, "
        f"{len(life)} request lifecycle(s), "
        f"{doc.get('otherData', {}).get('dropped_events', 0)} dropped"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
