//! `make bench` driver: record a machine-readable perf trajectory so
//! future PRs can diff serving behavior (`make bench-diff`).
//!
//! Five runs, all with unthrottled storage (fast + free of disk variance):
//!
//! * `one_model`         — generative serve, KV cache OFF (paper decode)
//! * `one_model_kv`      — same workload with `--kv-cache`
//! * `router_two_kv_lanes` — tiny-gpt + tiny-gptj lanes under one shared
//!   budget, each with a KV allocation
//! * `elastic_shrink_grow` — the KV serve again, with a shrink-grow
//!   memory-pressure trace resizing the budget mid-run
//! * `decode_gpt2_pinned` — a pinned (`--pin-budget-mb`) gpt2-base-sim
//!   decode, recorded TWICE under the same key: overlap off (PR 4's
//!   feature semantics; the worker-pool refactor is common to both) into
//!   `BENCH_pr4.json` and overlapped (`--prefetch-depth` +
//!   device-resident cache) into `BENCH_pr5.json`, so `make bench-diff`
//!   reports the per-token speedup of the overlap features directly.
//!
//! The JSON keys are the stable `serve --json` / summary keys (the decode
//! run uses the `RunReport` keys, incl. `decode_p50_ms` / `decode_p95_ms`
//! / `tokens_per_sec`).  CI uploads both files as build artifacts.

use std::time::Duration;

use anyhow::Result;
use hermes::config::{Mode, RunConfig};
use hermes::elastic::{PressureStep, PressureTrace};
use hermes::engine::Engine;
use hermes::server::{serve, InferRequest, Router, RouterConfig, ServeConfig};
use hermes::util::json::Value;

fn main() -> Result<()> {
    let engine = Engine::with_default_paths()?;
    let gpt_profile = engine.runtime.profile("tiny-gpt")?;
    let gpt = gpt_profile.total_weight_bytes;
    let gpt_max_stage = gpt_profile.max_stage_bytes();
    let gptj = engine.runtime.profile("tiny-gptj")?.total_weight_bytes;

    let base = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(4),
        ..RunConfig::default()
    };

    // one-model serve, KV off vs on, identical workload
    let off_cfg =
        ServeConfig { run: base.clone(), num_requests: 6, max_batch: 2, ..ServeConfig::default() };
    let off = serve(&engine, &off_cfg)?;
    let mut kv_run = base.clone();
    kv_run.kv_cache = true;
    let on_cfg = ServeConfig {
        run: kv_run.clone(),
        num_requests: 6,
        max_batch: 2,
        ..ServeConfig::default()
    };
    let on = serve(&engine, &on_cfg)?;

    // two generative KV lanes under one shared budget
    let mut lane_b = kv_run.clone();
    lane_b.profile = "tiny-gptj".into();
    let router = Router::new(
        &engine,
        RouterConfig {
            models: vec![kv_run.clone(), lane_b],
            budget: Some(gpt + gptj),
            kv_budget: Some(1 << 20),
            max_batch: 2,
            batch_window: Duration::from_millis(5),
            ..RouterConfig::default()
        },
    )?;
    let handle = router.handle();
    let producer = std::thread::spawn(move || {
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        for t in tickets {
            let _ = t.wait();
        }
        handle.shutdown();
    });
    let router_summary = router.run()?;
    producer.join().expect("producer panicked");

    // elastic: the same KV workload while a shrink-grow trace resizes the
    // budget mid-run (pins + KV give the shrink something to reclaim).
    // Steps are aligned to batch boundaries: serve polls the trace between
    // batches, and each request runs 4 passes, so at_pass 4 lands before
    // batch 2 and at_pass 12 before batch 4 — the canonical shrink_grow
    // constants (2/4) would both fall due at the first boundary and
    // collapse into the settled (grow) value.
    let elastic_budget = gpt + gpt_max_stage;
    let mut elastic_run = kv_run.clone();
    elastic_run.budget = Some(elastic_budget);
    elastic_run.pin_budget = Some(gpt);
    let trace = PressureTrace::new(vec![
        PressureStep { at_pass: 4, budget_bytes: elastic_budget * 60 / 100 },
        PressureStep { at_pass: 12, budget_bytes: elastic_budget },
    ])?;
    let elastic_cfg = ServeConfig {
        run: elastic_run,
        num_requests: 6,
        max_batch: 1, // one request per batch: more pass boundaries for steps
        memory_trace: Some(trace),
        ..ServeConfig::default()
    };
    let elastic = serve(&engine, &elastic_cfg)?;

    // gpt2-base-sim pinned decode, measured both ways: overlap OFF
    // (`--prefetch-depth 0` + device cache disabled — PR 4's FEATURE
    // semantics; note both runs ride the persistent worker pool, so the
    // thread-spawn savings are shared, not part of this delta) and
    // overlap ON.  Same profile, seed, and token count — the per-token
    // delta isolates prefetch + device-resident weights.
    let gpt2_total = engine.runtime.profile("gpt2-base-sim")?.total_weight_bytes;
    let decode_base = RunConfig {
        profile: "gpt2-base-sim".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(4),
        pin_budget: Some(gpt2_total),
        prefetch_depth: 0,
        device_cache: false,
        ..RunConfig::default()
    };
    let mut session = engine.open_session(&decode_base)?;
    let (decode_pr4, _) = session.run_batch(1, 42)?;
    drop(session);
    let mut decode_overlap_cfg = decode_base.clone();
    decode_overlap_cfg.prefetch_depth = 4;
    decode_overlap_cfg.device_cache = true;
    let mut session = engine.open_session(&decode_overlap_cfg)?;
    let (decode_pr5, _) = session.run_batch(1, 42)?;
    drop(session);

    let pr4 = Value::obj()
        .set("bench", "pr4-elastic")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_summary.to_json())
        .set("elastic_shrink_grow", elastic.to_json())
        .set("decode_gpt2_pinned", decode_pr4.to_json());
    pr4.to_file(&std::path::PathBuf::from("BENCH_pr4.json"))?;
    let pr5 = Value::obj()
        .set("bench", "pr5-overlapped-decode")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_summary.to_json())
        .set("elastic_shrink_grow", elastic.to_json())
        .set("decode_gpt2_pinned", decode_pr5.to_json());
    pr5.to_file(&std::path::PathBuf::from("BENCH_pr5.json"))?;
    println!("wrote BENCH_pr4.json + BENCH_pr5.json");
    println!(
        "one-model p50 {:.1} ms (kv off) vs {:.1} ms (kv on, {} incremental passes); \
         router: {} served, {} kv incremental passes, peak {} B; \
         elastic: {} budget steps, {} evictions, p50 {:.1} ms",
        off.latency.p50(),
        on.latency.p50(),
        on.kv_inc_passes,
        router_summary.served,
        router_summary.kv_inc_passes,
        router_summary.peak_bytes,
        elastic.budget_steps,
        elastic.elastic_evictions,
        elastic.latency.p50(),
    );
    println!(
        "gpt2 pinned decode: token p50 {:.1} ms -> {:.1} ms, {:.2} -> {:.2} tokens/s \
         ({} device hits, {} prefetched, {} spawns avoided)",
        decode_pr4.decode_p50_ms,
        decode_pr5.decode_p50_ms,
        decode_pr4.tokens_per_sec,
        decode_pr5.tokens_per_sec,
        decode_pr5.device_cache_hits,
        decode_pr5.prefetched_stages,
        decode_pr5.spawns_avoided,
    );
    Ok(())
}
