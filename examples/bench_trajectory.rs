//! `make bench` driver: record a machine-readable perf trajectory in
//! `BENCH_pr3.json` so future PRs can diff serving behavior.
//!
//! Three runs, all on tiny profiles with unthrottled storage (fast + free
//! of disk variance):
//!
//! * `one_model`         — generative serve, KV cache OFF (paper decode)
//! * `one_model_kv`      — same workload with `--kv-cache`
//! * `router_two_kv_lanes` — tiny-gpt + tiny-gptj lanes under one shared
//!   budget, each with a KV allocation
//!
//! The JSON keys are the stable `serve --json` / router summary keys.
//! CI runs this and uploads the file as a build artifact.

use std::time::Duration;

use anyhow::Result;
use hermes::config::{Mode, RunConfig};
use hermes::engine::Engine;
use hermes::server::{serve, InferRequest, Router, RouterConfig, ServeConfig};
use hermes::util::json::Value;

fn main() -> Result<()> {
    let engine = Engine::with_default_paths()?;
    let gpt = engine.runtime.profile("tiny-gpt")?.total_weight_bytes;
    let gptj = engine.runtime.profile("tiny-gptj")?.total_weight_bytes;

    let base = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(4),
        ..RunConfig::default()
    };

    // one-model serve, KV off vs on, identical workload
    let off_cfg =
        ServeConfig { run: base.clone(), num_requests: 6, max_batch: 2, ..ServeConfig::default() };
    let off = serve(&engine, &off_cfg)?;
    let mut kv_run = base.clone();
    kv_run.kv_cache = true;
    let on_cfg = ServeConfig {
        run: kv_run.clone(),
        num_requests: 6,
        max_batch: 2,
        ..ServeConfig::default()
    };
    let on = serve(&engine, &on_cfg)?;

    // two generative KV lanes under one shared budget
    let mut lane_b = kv_run.clone();
    lane_b.profile = "tiny-gptj".into();
    let router = Router::new(
        &engine,
        RouterConfig {
            models: vec![kv_run, lane_b],
            budget: Some(gpt + gptj),
            kv_budget: Some(1 << 20),
            max_batch: 2,
            batch_window: Duration::from_millis(5),
        },
    )?;
    let handle = router.handle();
    let producer = std::thread::spawn(move || {
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        for t in tickets {
            let _ = t.wait();
        }
        handle.shutdown();
    });
    let router_summary = router.run()?;
    producer.join().expect("producer panicked");

    let v = Value::obj()
        .set("bench", "pr3-kv-cache")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_summary.to_json());
    let out = std::path::PathBuf::from("BENCH_pr3.json");
    v.to_file(&out)?;
    println!("wrote {}", out.display());
    println!(
        "one-model p50 {:.1} ms (kv off) vs {:.1} ms (kv on, {} incremental passes); \
         router: {} served, {} kv incremental passes, peak {} B",
        off.latency.p50(),
        on.latency.p50(),
        on.kv_inc_passes,
        router_summary.served,
        router_summary.kv_inc_passes,
        router_summary.peak_bytes,
    );
    Ok(())
}
