//! `make bench` driver: record a machine-readable perf trajectory so
//! future PRs can diff serving behavior (`make bench-diff`).
//!
//! Four runs, all on tiny profiles with unthrottled storage (fast + free
//! of disk variance):
//!
//! * `one_model`         — generative serve, KV cache OFF (paper decode)
//! * `one_model_kv`      — same workload with `--kv-cache`
//! * `router_two_kv_lanes` — tiny-gpt + tiny-gptj lanes under one shared
//!   budget, each with a KV allocation
//! * `elastic_shrink_grow` — the KV serve again, with a shrink-grow
//!   memory-pressure trace resizing the budget mid-run
//!
//! The JSON keys are the stable `serve --json` / router summary keys.
//! The first three runs also land in `BENCH_pr3.json` (the PR 3 baseline
//! layout, for cross-PR diffing); all four land in `BENCH_pr4.json`.  CI
//! uploads both files as build artifacts.

use std::time::Duration;

use anyhow::Result;
use hermes::config::{Mode, RunConfig};
use hermes::elastic::{PressureStep, PressureTrace};
use hermes::engine::Engine;
use hermes::server::{serve, InferRequest, Router, RouterConfig, ServeConfig};
use hermes::util::json::Value;

fn main() -> Result<()> {
    let engine = Engine::with_default_paths()?;
    let gpt_profile = engine.runtime.profile("tiny-gpt")?;
    let gpt = gpt_profile.total_weight_bytes;
    let gpt_max_stage = gpt_profile.max_stage_bytes();
    let gptj = engine.runtime.profile("tiny-gptj")?.total_weight_bytes;

    let base = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(4),
        ..RunConfig::default()
    };

    // one-model serve, KV off vs on, identical workload
    let off_cfg =
        ServeConfig { run: base.clone(), num_requests: 6, max_batch: 2, ..ServeConfig::default() };
    let off = serve(&engine, &off_cfg)?;
    let mut kv_run = base.clone();
    kv_run.kv_cache = true;
    let on_cfg = ServeConfig {
        run: kv_run.clone(),
        num_requests: 6,
        max_batch: 2,
        ..ServeConfig::default()
    };
    let on = serve(&engine, &on_cfg)?;

    // two generative KV lanes under one shared budget
    let mut lane_b = kv_run.clone();
    lane_b.profile = "tiny-gptj".into();
    let router = Router::new(
        &engine,
        RouterConfig {
            models: vec![kv_run.clone(), lane_b],
            budget: Some(gpt + gptj),
            kv_budget: Some(1 << 20),
            max_batch: 2,
            batch_window: Duration::from_millis(5),
            ..RouterConfig::default()
        },
    )?;
    let handle = router.handle();
    let producer = std::thread::spawn(move || {
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        for t in tickets {
            let _ = t.wait();
        }
        handle.shutdown();
    });
    let router_summary = router.run()?;
    producer.join().expect("producer panicked");

    // elastic: the same KV workload while a shrink-grow trace resizes the
    // budget mid-run (pins + KV give the shrink something to reclaim).
    // Steps are aligned to batch boundaries: serve polls the trace between
    // batches, and each request runs 4 passes, so at_pass 4 lands before
    // batch 2 and at_pass 12 before batch 4 — the canonical shrink_grow
    // constants (2/4) would both fall due at the first boundary and
    // collapse into the settled (grow) value.
    let elastic_budget = gpt + gpt_max_stage;
    let mut elastic_run = kv_run.clone();
    elastic_run.budget = Some(elastic_budget);
    elastic_run.pin_budget = Some(gpt);
    let trace = PressureTrace::new(vec![
        PressureStep { at_pass: 4, budget_bytes: elastic_budget * 60 / 100 },
        PressureStep { at_pass: 12, budget_bytes: elastic_budget },
    ])?;
    let elastic_cfg = ServeConfig {
        run: elastic_run,
        num_requests: 6,
        max_batch: 1, // one request per batch: more pass boundaries for steps
        memory_trace: Some(trace),
        ..ServeConfig::default()
    };
    let elastic = serve(&engine, &elastic_cfg)?;

    let pr3 = Value::obj()
        .set("bench", "pr3-kv-cache")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_summary.to_json());
    pr3.to_file(&std::path::PathBuf::from("BENCH_pr3.json"))?;
    let pr4 = Value::obj()
        .set("bench", "pr4-elastic")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_summary.to_json())
        .set("elastic_shrink_grow", elastic.to_json());
    pr4.to_file(&std::path::PathBuf::from("BENCH_pr4.json"))?;
    println!("wrote BENCH_pr3.json + BENCH_pr4.json");
    println!(
        "one-model p50 {:.1} ms (kv off) vs {:.1} ms (kv on, {} incremental passes); \
         router: {} served, {} kv incremental passes, peak {} B; \
         elastic: {} budget steps, {} evictions, p50 {:.1} ms",
        off.latency.p50(),
        on.latency.p50(),
        on.kv_inc_passes,
        router_summary.served,
        router_summary.kv_inc_passes,
        router_summary.peak_bytes,
        elastic.budget_steps,
        elastic.elastic_evictions,
        elastic.latency.p50(),
    );
    Ok(())
}
