//! `make bench` driver: record a machine-readable perf trajectory so
//! future PRs can diff serving behavior (`make bench-diff`).
//!
//! Sections, all with unthrottled storage (fast + free of disk
//! variance):
//!
//! * `one_model`         — generative serve, KV cache OFF (paper decode)
//! * `one_model_kv`      — same workload with `--kv-cache`
//! * `router_two_kv_lanes` — tiny-gpt + tiny-gptj lanes on the concurrent
//!   router under one shared budget
//! * `continuous_burst`  — bursty multi-client traffic on the same two
//!   lanes under iteration-level continuous batching (`--continuous`,
//!   cross-request KV prefix sharing), each burst sharing one system
//!   prompt (one seed)
//! * `elastic_shrink_grow` — the KV serve again, with a shrink-grow
//!   memory-pressure trace resizing the budget mid-run; this run carries
//!   an enabled telemetry bus, and its per-pass accountant high-water
//!   samples land in the PR 8 file as `mem_high_water` (the serving path
//!   itself is identical with the bus on — tokens don't change)
//! * `decode_gpt2_pinned` — a pinned (`--pin-budget-mb`) gpt2-base-sim
//!   overlapped decode (prefetch + device-resident weights)
//! * `recovery` — the KV serve twice more with the device cache off (so
//!   every pass streams from disk): once clean, once under a fixed-seed
//!   transparent fault plan (disk errors absorbed by the bounded load
//!   retry, an injected stuck medium, transient accountant refusals).
//!   The faulted run must still serve every request; the section records
//!   both summaries plus the fired-fault/retry counters, so the cost of
//!   recovering is a tracked metric, not an anecdote.
//!
//! `BENCH_pr7.json` keeps the previous PR's layout; `BENCH_pr8.json` is
//! the same summaries plus the telemetry-derived `mem_high_water`
//! timeline; `BENCH_pr9.json` adds the offline analyzer's view of the
//! elastic run (`analyze`: per-stage bubble attribution, request
//! breakdown percentiles, memory-audit drift); `BENCH_pr10.json` adds
//! the `recovery` section, so `make bench-diff` shows the new
//! fault-tolerance numbers (and any perturbation they were to introduce)
//! at a glance.
//!
//! The JSON keys are the stable `serve --json` / summary keys (the decode
//! run uses the `RunReport` keys, incl. `decode_p50_ms` / `decode_p95_ms`
//! / `tokens_per_sec`).  CI uploads the files as build artifacts.

use std::time::Duration;

use anyhow::Result;
use hermes::config::{Mode, RunConfig};
use hermes::elastic::{PressureStep, PressureTrace};
use hermes::engine::Engine;
use hermes::server::{
    serve, ConcurrentRouter, InferRequest, RouterConfig, RouterHandle, ServeConfig,
};
use hermes::telemetry::Telemetry;
use hermes::util::json::Value;

/// Submit `n` requests alternating between the two lanes, wait for every
/// reply, then shut the router down.
fn drive_lanes(handle: RouterHandle, n: usize) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let tickets: Vec<_> = (0..n)
            .map(|i| {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        for t in tickets {
            let _ = t.wait();
        }
        handle.shutdown();
    })
}

/// Bursty multi-client traffic: three client bursts of four requests,
/// profiles mixed within each burst, every request in a burst priming the
/// SAME system prompt (one shared seed) — the cross-request KV
/// prefix-sharing case.  Each request carries a lax SLO target so
/// `slo_attained_pct` is live (and expected at 100 on an idle machine).
fn drive_bursts(handle: RouterHandle) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut tickets = Vec::new();
        for burst in 0..3u64 {
            for i in 0..4u64 {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                tickets.push(
                    handle
                        .submit(InferRequest {
                            profile: profile.into(),
                            seed: Some(4200 + burst), // the burst's shared system prompt
                            slo_ms: Some(10_000.0),
                            ..InferRequest::default()
                        })
                        .unwrap(),
                );
            }
            std::thread::sleep(Duration::from_millis(3));
        }
        for t in tickets {
            let _ = t.wait();
        }
        handle.shutdown();
    })
}

fn main() -> Result<()> {
    let engine = Engine::with_default_paths()?;
    let gpt_profile = engine.runtime.profile("tiny-gpt")?;
    let gpt = gpt_profile.total_weight_bytes;
    let gpt_max_stage = gpt_profile.max_stage_bytes();
    let gptj = engine.runtime.profile("tiny-gptj")?.total_weight_bytes;

    let base = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(4),
        ..RunConfig::default()
    };

    // one-model serve, KV off vs on, identical workload
    let off_cfg =
        ServeConfig { run: base.clone(), num_requests: 6, max_batch: 2, ..ServeConfig::default() };
    let off = serve(&engine, &off_cfg)?;
    let mut kv_run = base.clone();
    kv_run.kv_cache = true;
    let on_cfg = ServeConfig {
        run: kv_run.clone(),
        num_requests: 6,
        max_batch: 2,
        ..ServeConfig::default()
    };
    let on = serve(&engine, &on_cfg)?;

    // two generative KV lanes overlapping passes under one shared budget
    let mut lane_b = kv_run.clone();
    lane_b.profile = "tiny-gptj".into();
    let lanes_cfg = RouterConfig {
        models: vec![kv_run.clone(), lane_b],
        budget: Some(2 * (gpt + gptj)),
        kv_budget: Some(1 << 20),
        max_batch: 2,
        batch_window: Duration::from_millis(5),
        concurrent: true,
        ..RouterConfig::default()
    };
    let conc = ConcurrentRouter::new(engine.paths.clone(), lanes_cfg.clone())?;
    let producer = drive_lanes(conc.handle(), 8);
    let router_two = conc.run()?;
    producer.join().expect("producer panicked");

    // the same two lanes under bursty shared-prompt traffic with
    // iteration-level continuous batching.  Small KV blocks so the tiny
    // profiles' prompts seal (and dedup) whole blocks.
    let mk_burst = |profile: &str| RunConfig {
        profile: profile.into(),
        kv_block_tokens: Some(2),
        continuous: true,
        slo_ms: Some(10_000.0),
        max_active: Some(2),
        ..kv_run.clone()
    };
    let burst_cfg = RouterConfig {
        models: vec![mk_burst("tiny-gpt"), mk_burst("tiny-gptj")],
        budget: Some(2 * (gpt + gptj)),
        kv_budget: Some(1 << 20),
        max_batch: 2,
        batch_window: Duration::from_millis(5),
        concurrent: true,
        ..RouterConfig::default()
    };
    let conc = ConcurrentRouter::new(engine.paths.clone(), burst_cfg)?;
    let producer = drive_bursts(conc.handle());
    let burst_cont = conc.run()?;
    producer.join().expect("producer panicked");

    // elastic: the same KV workload while a shrink-grow trace resizes the
    // budget mid-run (pins + KV give the shrink something to reclaim).
    // Steps are aligned to batch boundaries: serve polls the trace between
    // batches, and each request runs 4 passes, so at_pass 4 lands before
    // batch 2 and at_pass 12 before batch 4 — the canonical shrink_grow
    // constants (2/4) would both fall due at the first boundary and
    // collapse into the settled (grow) value.
    let elastic_budget = gpt + gpt_max_stage;
    let mut elastic_run = kv_run.clone();
    elastic_run.budget = Some(elastic_budget);
    elastic_run.pin_budget = Some(gpt);
    let trace = PressureTrace::new(vec![
        PressureStep { at_pass: 4, budget_bytes: elastic_budget * 60 / 100 },
        PressureStep { at_pass: 12, budget_bytes: elastic_budget },
    ])?;
    // the elastic run carries an enabled event bus: its per-pass
    // accountant high-water samples become the PR 8 `mem_high_water`
    // timeline (the bus observes only — the summary is unchanged by it)
    let telemetry = Telemetry::on();
    let elastic_cfg = ServeConfig {
        run: elastic_run,
        num_requests: 6,
        max_batch: 1, // one request per batch: more pass boundaries for steps
        memory_trace: Some(trace),
        telemetry: telemetry.clone(),
        ..ServeConfig::default()
    };
    let elastic = serve(&engine, &elastic_cfg)?;
    let events = telemetry.drain();
    // the analyzer's view of the same events: critical-path attribution,
    // lifecycle percentiles, and the memory-audit reconciliation
    let analysis = hermes::analyze::Analysis::from_bus(&events, telemetry.dropped());
    let high_water: Vec<Value> = events
        .iter()
        .filter(|e| e.name == "mem_high_water")
        .map(|e| e.args.value.unwrap_or(0.0).into())
        .collect();
    let budget_epoch_events = events.iter().filter(|e| e.name == "budget_epoch").count();
    let high_water_len = high_water.len();
    let mem_high_water = Value::obj()
        .set("samples", high_water_len)
        .set("budget_epoch_events", budget_epoch_events)
        .set("dropped_events", telemetry.dropped())
        .set("peak_bytes_per_pass", high_water);

    // gpt2-base-sim pinned overlapped decode (prefetch + device-resident
    // weights); the single-session decode path is unchanged this PR, so
    // the same run lands in both files and diffs flat.
    let gpt2_total = engine.runtime.profile("gpt2-base-sim")?.total_weight_bytes;
    let decode_cfg = RunConfig {
        profile: "gpt2-base-sim".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(4),
        pin_budget: Some(gpt2_total),
        prefetch_depth: 4,
        device_cache: true,
        ..RunConfig::default()
    };
    let mut session = engine.open_session(&decode_cfg)?;
    let (decode, _) = session.run_batch(1, 42)?;
    drop(session);

    // recovery cost: the one-model KV serve with the device cache off
    // (every pass streams from disk, keeping the disk-fault seams hot),
    // clean vs under a fixed-seed transparent fault plan.  Every request
    // still succeeds — `serve` fails on any rejection — so the delta
    // between the two runs IS the price of riding out the faults.
    let mut rec_run = kv_run.clone();
    rec_run.device_cache = false;
    let rec_ref_cfg = ServeConfig {
        run: rec_run.clone(),
        num_requests: 6,
        max_batch: 2,
        ..ServeConfig::default()
    };
    let rec_ref = serve(&engine, &rec_ref_cfg)?;
    rec_run.fault_plan = Some("seed=42;disk_error@2x2;disk_slow@3+20;acquire_fail@4x2".into());
    let rec_fault_cfg = ServeConfig {
        run: rec_run,
        num_requests: 6,
        max_batch: 2,
        ..ServeConfig::default()
    };
    let rec_fault = serve(&engine, &rec_fault_cfg)?;
    let recovery = Value::obj()
        .set("fault_plan", "seed=42;disk_error@2x2;disk_slow@3+20;acquire_fail@4x2")
        .set("reference", rec_ref.to_json())
        .set("faulted", rec_fault.to_json())
        .set("recovery_overhead_p50_ms", rec_fault.latency.p50() - rec_ref.latency.p50())
        .set("recovery_overhead_p95_ms", rec_fault.latency.p95() - rec_ref.latency.p95());

    let pr7 = Value::obj()
        .set("bench", "pr7-continuous-batching")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_two.to_json())
        .set("continuous_burst", burst_cont.to_json())
        .set("elastic_shrink_grow", elastic.to_json())
        .set("decode_gpt2_pinned", decode.to_json());
    pr7.to_file(&std::path::PathBuf::from("BENCH_pr7.json"))?;
    let pr8 = Value::obj()
        .set("bench", "pr8-telemetry")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_two.to_json())
        .set("continuous_burst", burst_cont.to_json())
        .set("elastic_shrink_grow", elastic.to_json())
        .set("mem_high_water", mem_high_water.clone())
        .set("decode_gpt2_pinned", decode.to_json());
    pr8.to_file(&std::path::PathBuf::from("BENCH_pr8.json"))?;
    let pr9 = Value::obj()
        .set("bench", "pr9-trace-analytics")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_two.to_json())
        .set("continuous_burst", burst_cont.to_json())
        .set("elastic_shrink_grow", elastic.to_json())
        .set("mem_high_water", mem_high_water.clone())
        .set("analyze", analysis.to_json())
        .set("decode_gpt2_pinned", decode.to_json());
    pr9.to_file(&std::path::PathBuf::from("BENCH_pr9.json"))?;
    let pr10 = Value::obj()
        .set("bench", "pr10-fault-tolerance")
        .set("one_model", off.to_json())
        .set("one_model_kv", on.to_json())
        .set("router_two_kv_lanes", router_two.to_json())
        .set("continuous_burst", burst_cont.to_json())
        .set("elastic_shrink_grow", elastic.to_json())
        .set("mem_high_water", mem_high_water)
        .set("analyze", analysis.to_json())
        .set("recovery", recovery)
        .set("decode_gpt2_pinned", decode.to_json());
    pr10.to_file(&std::path::PathBuf::from("BENCH_pr10.json"))?;
    println!("wrote BENCH_pr7.json + BENCH_pr8.json + BENCH_pr9.json + BENCH_pr10.json");
    println!(
        "recovery: clean p50 {:.1} ms vs faulted p50 {:.1} ms \
         ({} faults injected, {} load retries)",
        rec_ref.latency.p50(),
        rec_fault.latency.p50(),
        rec_fault.faults_injected,
        rec_fault.load_retries,
    );
    println!(
        "one-model p50 {:.1} ms (kv off) vs {:.1} ms (kv on, {} incremental passes); \
         elastic: {} budget steps, {} evictions, p50 {:.1} ms",
        off.latency.p50(),
        on.latency.p50(),
        on.kv_inc_passes,
        elastic.budget_steps,
        elastic.elastic_evictions,
        elastic.latency.p50(),
    );
    println!(
        "two-lane router (fixed batch): {:.2} req/s, {} served, peak {} B, \
         {} pass(es) in flight at peak",
        router_two.throughput_rps,
        router_two.served,
        router_two.peak_bytes,
        router_two.concurrent_passes_peak,
    );
    println!(
        "bursty shared-prompt (continuous): {:.2} tok/s \
         ({} joins / {} leaves / {} shed, SLO attained {:.1}%, \
         {} shared blocks, {} B deduplicated, queue wait p50 {:.1} ms)",
        burst_cont.tokens_per_sec,
        burst_cont.joins,
        burst_cont.leaves,
        burst_cont.shed_overload,
        burst_cont.slo_attained_pct,
        burst_cont.shared_kv_blocks,
        burst_cont.kv_dedup_bytes,
        burst_cont.queue_wait_p50_ms,
    );
    println!(
        "elastic high-water timeline: {} pass sample(s), {} budget-epoch event(s), \
         {} telemetry event(s) dropped",
        high_water_len,
        budget_epoch_events,
        telemetry.dropped(),
    );
    println!(
        "elastic analyzer view: {} pass(es), bubble {:.1} ms, stall-mem {:.1} ms, \
         audit {} sample(s) (max drift {} B), {} analysis error(s)",
        analysis.passes.len(),
        analysis.bubble_total_ms(),
        analysis.totals.stall_mem_ms,
        analysis.audit.samples,
        analysis.audit.max_drift_bytes,
        analysis.errors.len(),
    );
    println!(
        "gpt2 pinned overlapped decode: token p50 {:.1} ms, {:.2} tokens/s \
         ({} device hits, {} prefetched, {} spawns avoided)",
        decode.decode_p50_ms,
        decode.tokens_per_sec,
        decode.device_cache_hits,
        decode.prefetched_stages,
        decode.spawns_avoided,
    );
    Ok(())
}
