//! Elastic memory controller demo: a generative decode rides through a
//! shrink-grow memory-pressure trace.
//!
//! The budget shrinks mid-decode (a co-resident app claimed memory), the
//! controller evicts pinned hot layers until the session fits again and
//! re-plans the Loading Agent count against a real planner schedule; when
//! the budget grows back, the pin cap and agent count re-raise.  Tokens
//! are identical to a static-budget run throughout.
//!
//! Run with: `cargo run --release --example elastic_pressure`

use anyhow::Result;
use hermes::config::{Mode, RunConfig};
use hermes::elastic::{PressureStep, PressureTrace};
use hermes::engine::Engine;
use hermes::planner;
use hermes::report;
use hermes::util::human_bytes;

fn main() -> Result<()> {
    let engine = Engine::with_default_paths()?;
    let model = "tiny-gpt";
    let profile = engine.runtime.profile(model)?;
    let total = profile.total_weight_bytes;
    let max_stage = profile.max_stage_bytes();

    // a real planner schedule over both constraints (analytic: no pre-runs)
    let stats = report::profile_one(&engine, model, "unthrottled")?;
    let min_feasible = planner::min_feasible_budget(&stats, profile.body_kind());
    let base = total + 2 * max_stage;
    let shrunk = (base * 60 / 100).max(min_feasible);
    let schedule = planner::plan(&engine, &stats, &[shrunk, base], 4, false)?;
    println!("schedule for {model}:");
    for e in &schedule.entries {
        println!("  budget {:>10} -> {} Loading Agents", human_bytes(e.budget_bytes), e.agents);
    }

    let trace = PressureTrace::new(vec![
        PressureStep { at_pass: 2, budget_bytes: shrunk },
        PressureStep { at_pass: 5, budget_bytes: base },
    ])?;

    let cfg = RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: schedule.pick(base).map(|e| e.agents).unwrap_or(2),
        budget: Some(base),
        pin_budget: Some(total),
        disk: "unthrottled".into(),
        gen_tokens: Some(8),
        ..RunConfig::default()
    };

    // static reference: same workload, budget never moves
    let mut static_session = engine.open_session(&cfg)?;
    let (_, static_out) = static_session.run_batch(1, 7)?;
    drop(static_session);

    let mut session =
        engine.session(&cfg).memory_trace(trace).schedule(schedule).open()?;
    let (rep, out) = session.run_batch(1, 7)?;

    println!("\ndecode under pressure ({} tokens):", rep.tokens);
    println!(
        "  {} budget steps, {} elastic evictions, {} re-plans",
        rep.budget_steps, rep.elastic_evictions, rep.replans
    );
    for ep in session.budget_epochs() {
        println!(
            "  pass {:>2}: budget {:>10} -> used {:>10}, freed {:>10}, {} agents, pin cap {}{}",
            ep.at_pass,
            human_bytes(ep.budget_bytes),
            human_bytes(ep.used_after_bytes),
            human_bytes(ep.freed_bytes),
            ep.agents,
            human_bytes(ep.pin_cap_bytes),
            if ep.replanned { "  [re-planned]" } else { "" },
        );
        assert!(ep.used_after_bytes <= ep.budget_bytes, "must settle under the step budget");
    }
    assert_eq!(
        static_out.generated_rows, out.generated_rows,
        "elastic decode must match the static-budget tokens bit-for-bit"
    );
    println!("\ntokens identical to the static-budget run: {:?}", out.generated);
    Ok(())
}
