//! Fig-7 style sweep: latency + optimal #Loading-Agents vs memory budget.
//!
//! Runs the Layer Profiler once, then asks the Pipeline Planner (with
//! empirical pre-runs, the paper's method) for the best agent count under
//! a range of budgets, and prints the paper's Fig-7 series.
//!
//! ```bash
//! cargo run --release --example memory_sweep                 # bert-large-sim
//! HERMES_SWEEP_MODEL=vit-large-sim cargo run --release --example memory_sweep
//! ```

use hermes::engine::Engine;
use hermes::planner;
use hermes::report::profile_one;
use hermes::util::{human_bytes, human_ms};

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let model = std::env::var("HERMES_SWEEP_MODEL").unwrap_or_else(|_| "bert-large-sim".into());
    let disk = "edge-emmc";
    let profile = engine.runtime.profile(&model)?;
    let total = profile.total_weight_bytes;

    println!("== memory sweep: {model} ({}) on {disk} ==\n", human_bytes(total));
    println!("profiling layers...");
    let stats = profile_one(&engine, &model, disk)?;
    let (l, c, _) = stats.body_means(profile.body_kind());
    println!(
        "  per body layer: load {} / compute {}  (ratio {:.1}x)\n",
        human_ms(l),
        human_ms(c),
        stats.load_compute_ratio(profile.body_kind())
    );

    let min_feasible = planner::min_feasible_budget(&stats, profile.body_kind());
    let budgets: Vec<u64> = [0.12, 0.18, 0.25, 0.35, 0.5, 0.7]
        .iter()
        .map(|f| ((total as f64 * f) as u64).max(min_feasible))
        .collect();

    println!("planning (empirical pre-runs per budget)...");
    let sched = planner::plan(&engine, &stats, &budgets, 8, true)?;
    println!("\n{:>12} | {:>5} | {:>10} | {:>10}", "budget", "#LAs", "latency", "peak");
    println!("{}", "-".repeat(48));
    let mut prev_agents = 0;
    let mut prev_latency = f64::INFINITY;
    for e in &sched.entries {
        let lat = e.measured_latency_ms.unwrap_or(e.predicted_latency_ms);
        println!(
            "{:>12} | {:>5} | {:>10} | {:>10}",
            human_bytes(e.budget_bytes),
            e.agents,
            human_ms(lat),
            e.measured_peak_bytes.map(human_bytes).unwrap_or_else(|| "-".into()),
        );
        // paper's Fig-7 trend: relaxing the budget never hurts
        assert!(e.agents >= prev_agents, "agents should not shrink with budget");
        prev_agents = e.agents;
        prev_latency = prev_latency.min(lat);
    }
    println!("\npaper Fig 7: latency falls and the optimal #LAs grows with the budget");
    Ok(())
}
