//! End-to-end serving driver (EXPERIMENTS.md "E2E validation").
//!
//! Loads the BERT-Large sim profile (the paper's NLP workload) and serves
//! batched requests through the full stack — request queue -> batcher ->
//! PIPELOAD (loading agents + inference agent + daemon over the throttled
//! edge disk) -> PJRT layer executables — reporting latency percentiles,
//! throughput, peak memory, and the paper's §V-C SLO verdict.
//!
//! ```bash
//! cargo run --release --example edge_serving            # default: 12 requests
//! HERMES_E2E_REQUESTS=32 cargo run --release --example edge_serving
//! ```

use hermes::config::{Mode, RunConfig};
use hermes::engine::Engine;
use hermes::server::{serve, ServeConfig};
use hermes::util::json::Value;
use hermes::util::{human_bytes, human_ms};

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let model = std::env::var("HERMES_E2E_MODEL").unwrap_or_else(|_| "bert-large-sim".into());
    let requests: usize = std::env::var("HERMES_E2E_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let profile = engine.runtime.profile(&model)?;
    let budget = profile.total_weight_bytes / 3; // a third of the model fits

    println!("== Hermes E2E serving: {model} ==");
    println!(
        "model {} in {} stages; budget {} ({}% of model); disk edge-emmc\n",
        human_bytes(profile.total_weight_bytes),
        profile.stages.len(),
        human_bytes(budget),
        100 * budget / profile.total_weight_bytes.max(1),
    );

    // warmup: compile + first-touch weights off the measured path
    let _ = engine.run(&RunConfig {
        profile: model.clone(),
        mode: Mode::PipeLoad,
        agents: 2,
        budget: Some(budget),
        ..RunConfig::default()
    })?;

    let cfg = ServeConfig {
        run: RunConfig {
            profile: model.clone(),
            mode: Mode::PipeLoad,
            agents: 4,
            budget: Some(budget),
            disk: "edge-emmc".into(),
            ..RunConfig::default()
        },
        num_requests: requests,
        arrival_rps: 2.0,
        max_batch: 4,
        slo_ms: 30_000.0,
        ..ServeConfig::default()
    };
    let s = serve(&engine, &cfg)?;

    println!("served    : {} requests in {} batches (mean batch {:.2})", s.served, s.batches, s.mean_batch_size);
    println!("throughput: {:.2} req/s", s.throughput_rps);
    println!(
        "latency   : p50 {}  p95 {}  p99 {}  max {}",
        human_ms(s.latency.p50()),
        human_ms(s.latency.p95()),
        human_ms(s.latency.p99()),
        human_ms(s.latency.max())
    );
    println!("peak mem  : {}  (budget {})", human_bytes(s.peak_bytes), human_bytes(budget));
    println!(
        "SLO       : p95 {} <= {} -> {}",
        human_ms(s.slo.p95_ms),
        human_ms(s.slo.target_ms),
        if s.slo.met { "MET" } else { "MISSED" }
    );

    // record for EXPERIMENTS.md
    let out = Value::obj()
        .set("model", model.clone())
        .set("requests", s.served)
        .set("batches", s.batches)
        .set("throughput_rps", s.throughput_rps)
        .set("latency", s.latency.to_json())
        .set("peak_bytes", s.peak_bytes)
        .set("budget_bytes", budget)
        .set("slo_met", s.slo.met);
    let path = engine.paths.results.join("e2e_serving.json");
    out.to_file(&path)?;
    println!("\nrecorded -> {}", path.display());

    anyhow::ensure!(s.slo.met, "SLO missed");
    anyhow::ensure!(s.peak_bytes <= budget + budget / 2, "peak far above budget");
    Ok(())
}
