//! Multi-model serving through one Router and one shared memory budget.
//!
//! Two model profiles — an encoder (BERT sim) and a generative decoder
//! (GPT sim) — are served by a single [`hermes::server::Router`]: one
//! long-lived session per profile, both opened against one shared
//! `MemoryAccountant` whose budget is the device-wide memory limit.  A
//! producer thread interleaves requests for both models through a cloned
//! `RouterHandle`; the router batches per profile, applies
//! deadline-aware admission, and lets one model's `S^stop` pressure evict
//! the other model's pinned hot layers.
//!
//! ```bash
//! cargo run --release --example router_multi_model
//! ```

use std::time::Duration;

use hermes::config::{Mode, RunConfig};
use hermes::engine::Engine;
use hermes::server::{InferRequest, Router, RouterConfig};
use hermes::util::{human_bytes, human_ms};

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let encoder = std::env::var("HERMES_ROUTER_ENCODER").unwrap_or_else(|_| "tiny-bert".into());
    let decoder = std::env::var("HERMES_ROUTER_DECODER").unwrap_or_else(|_| "tiny-gpt".into());
    let requests: usize = std::env::var("HERMES_ROUTER_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);

    let total_a = engine.runtime.profile(&encoder)?.total_weight_bytes;
    let total_b = engine.runtime.profile(&decoder)?.total_weight_bytes;
    // both models fit only *together with pins evicted*: real contention
    let budget = total_a + total_b / 2;

    println!("== Hermes multi-model router: {encoder} + {decoder} ==");
    println!(
        "models {} + {}; shared budget {}\n",
        human_bytes(total_a),
        human_bytes(total_b),
        human_bytes(budget),
    );

    let base = |profile: &str| RunConfig {
        profile: profile.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        pin_budget: Some(budget / 4),
        ..RunConfig::default()
    };
    let mut dec = base(&decoder);
    dec.gen_tokens = Some(2);

    let router = Router::new(
        &engine,
        RouterConfig {
            models: vec![base(&encoder), dec],
            budget: Some(budget),
            kv_budget: None,
            max_batch: 2,
            batch_window: Duration::from_millis(10),
            ..RouterConfig::default()
        },
    )?;
    let handle = router.handle();

    let enc = encoder.clone();
    let dec_name = decoder.clone();
    let producer = std::thread::spawn(move || -> anyhow::Result<()> {
        let tickets: Vec<_> = (0..requests)
            .map(|i| {
                let profile = if i % 2 == 0 { enc.clone() } else { dec_name.clone() };
                handle.submit(InferRequest {
                    profile,
                    deadline: Some(Duration::from_secs(120)),
                    ..InferRequest::default()
                })
            })
            .collect::<anyhow::Result<_>>()?;
        for t in tickets {
            let r = t.wait()?;
            println!(
                "  [{}] #{} {} in {} (batch {}, {} tokens)",
                r.profile,
                r.id,
                if r.ok { "ok" } else { "REJECTED" },
                human_ms(r.latency_ms),
                r.batch,
                r.tokens,
            );
        }
        handle.shutdown();
        Ok(())
    });

    let s = router.run()?;
    producer.join().expect("producer thread")?;

    println!(
        "\nserved {} requests ({} rejected) in {} batches (mean batch {:.2})",
        s.served, s.rejected, s.batches, s.mean_batch_size
    );
    println!("throughput: {:.2} req/s", s.throughput_rps);
    println!(
        "latency   : p50 {}  p95 {}  max {}",
        human_ms(s.latency.p50()),
        human_ms(s.latency.p95()),
        human_ms(s.latency.max())
    );
    println!("peak mem  : {}  (shared budget {})", human_bytes(s.peak_bytes), human_bytes(budget));
    for m in &s.per_model {
        println!(
            "  [{}] served {} in {} batches, p95 {}, cache {}/{}",
            m.profile,
            m.served,
            m.batches,
            human_ms(m.latency.p95()),
            m.cache_hits,
            m.cache_hits + m.cache_misses,
        );
    }

    anyhow::ensure!(s.served == requests, "all requests must complete");
    anyhow::ensure!(s.peak_bytes <= budget + budget / 4, "peak far above shared budget");
    Ok(())
}
