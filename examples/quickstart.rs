//! Quickstart: run PIPELOAD on a tiny model and compare the three modes.
//!
//! ```bash
//! make artifacts           # once: AOT-lower the models (python, build time)
//! cargo run --release --example quickstart
//! ```
//!
//! Weights are synthesized on first use; everything below is pure Rust on
//! the PJRT CPU runtime — python never runs here.

use hermes::config::{Mode, RunConfig};
use hermes::engine::Engine;
use hermes::util::{human_bytes, human_ms};

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let model = "tiny-bert";
    println!("== Hermes quickstart: {model} ==\n");

    // one warmup run so XLA compilation is off the comparison
    let _ = engine.run(&RunConfig {
        profile: model.into(),
        mode: Mode::Baseline,
        disk: "unthrottled".into(),
        ..RunConfig::default()
    })?;

    let mut baseline_ms = 0.0;
    for (mode, agents) in [(Mode::Baseline, 1), (Mode::PipeSwitch, 1), (Mode::PipeLoad, 2), (Mode::PipeLoad, 4)] {
        let cfg = RunConfig {
            profile: model.into(),
            mode,
            agents,
            disk: "edge-sd".into(), // tiny model: slow storage shows the effect
            ..RunConfig::default()
        };
        let (rep, out) = engine.run(&cfg)?;
        if mode == Mode::Baseline {
            baseline_ms = rep.latency_ms;
        }
        println!(
            "{:<11} agents={:<2} latency {:>9}  speedup {:>5.2}x  peak {:>10}  head[0]={:+.4}",
            rep.mode,
            rep.agents,
            human_ms(rep.latency_ms),
            baseline_ms / rep.latency_ms,
            human_bytes(rep.peak_bytes),
            out.head_sample.first().copied().unwrap_or(0.0),
        );
    }
    println!("\nPIPELOAD destroys each layer after compute: peak memory stays at a");
    println!("few layers instead of the whole model, while parallel Loading Agents");
    println!("keep the inference lane busy (paper sections III, V).");
    Ok(())
}
