//! Generative decode with per-token pipeline reload (paper §V-B2).
//!
//! GPT-style models under PIPELOAD reload every layer for each generated
//! token (weights were destroyed after the previous one).  This example
//! reproduces the paper's Table II observation that pipelined modes can be
//! *slower than the non-pipeline baseline* at low agent counts — and shows
//! where more Loading Agents claw it back — while memory stays a fraction
//! of the model.
//!
//! ```bash
//! cargo run --release --example text_generation             # gpt2-base-sim
//! HERMES_GEN_MODEL=gptj-sim cargo run --release --example text_generation
//! ```

use hermes::config::{Mode, RunConfig};
use hermes::engine::Engine;
use hermes::util::{human_bytes, human_ms};

fn main() -> anyhow::Result<()> {
    let engine = Engine::with_default_paths()?;
    let model = std::env::var("HERMES_GEN_MODEL").unwrap_or_else(|_| "gpt2-base-sim".into());
    let tokens: usize = std::env::var("HERMES_GEN_TOKENS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let profile = engine.runtime.profile(&model)?;
    println!(
        "== text generation: {model} ({} decoder layers, {}) — {tokens} tokens ==\n",
        profile.layers,
        human_bytes(profile.total_weight_bytes)
    );

    // warmup compile
    let _ = engine.run(&RunConfig {
        profile: model.clone(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(1),
        ..RunConfig::default()
    })?;

    let mut baseline_ms = 0.0;
    for (mode, agents) in [(Mode::Baseline, 1), (Mode::PipeSwitch, 1), (Mode::PipeLoad, 2), (Mode::PipeLoad, 6)] {
        let cfg = RunConfig {
            profile: model.clone(),
            mode,
            agents,
            disk: "edge-emmc".into(),
            gen_tokens: Some(tokens),
            ..RunConfig::default()
        };
        let (rep, out) = engine.run(&cfg)?;
        if mode == Mode::Baseline {
            baseline_ms = rep.latency_ms;
        }
        println!(
            "{:<11} agents={:<2} total {:>9} ({:>8}/token)  speedup {:>5.2}x  peak {:>10}  tokens {:?}",
            rep.mode,
            rep.agents,
            human_ms(rep.latency_ms),
            human_ms(rep.latency_ms / tokens as f64),
            baseline_ms / rep.latency_ms,
            human_bytes(rep.peak_bytes),
            out.generated,
        );
    }
    println!("\nbaseline loads once and infers per token; pipelines reload every");
    println!("token — the paper's crossover: speedup < 1 at few agents, recovering");
    println!("as agents multiply the effective load bandwidth (Table II, GPT rows).");
    Ok(())
}
