//! Integration tests for the Hermes framework layer: Engine modes, Layer
//! Profiler, Pipeline Planner, serving loop, report harness.
//! Needs `make artifacts`.

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;
use hermes::planner;
use hermes::report;
use hermes::server::{serve, ServeConfig};

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn quick_cfg(model: &str, mode: Mode, agents: usize) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode,
        agents,
        disk: "unthrottled".into(),
        gen_tokens: Some(2),
        ..RunConfig::default()
    }
}

#[test]
fn all_modes_produce_identical_outputs() {
    let e = engine();
    let mut heads: Vec<Vec<f32>> = Vec::new();
    let mut gens: Vec<Vec<i32>> = Vec::new();
    for (mode, agents) in [(Mode::Baseline, 1), (Mode::PipeSwitch, 1), (Mode::PipeLoad, 3)] {
        let (_, out) = e.run(&quick_cfg("tiny-gpt", mode, agents)).unwrap();
        heads.push(out.head_sample);
        gens.push(out.generated);
    }
    assert_eq!(heads[0], heads[1], "baseline vs pipeswitch outputs differ");
    assert_eq!(heads[0], heads[2], "baseline vs pipeload outputs differ");
    assert_eq!(gens[0], gens[1]);
    assert_eq!(gens[0], gens[2]);
    assert_eq!(gens[0].len(), 2);
}

#[test]
fn generative_decode_is_deterministic_across_runs() {
    let e = engine();
    let (_, a) = e.run(&quick_cfg("tiny-gptj", Mode::PipeLoad, 2)).unwrap();
    let (_, b) = e.run(&quick_cfg("tiny-gptj", Mode::PipeLoad, 4)).unwrap();
    assert_eq!(a.generated, b.generated, "agent count must not change outputs");
}

#[test]
fn profiler_reflects_disk_speed() {
    let e = engine();
    let fast = report::profile_one(&e, "tiny-bert", "unthrottled").unwrap();
    let slow = report::profile_one(&e, "tiny-bert", "edge-sd").unwrap();
    let p = e.runtime.profile("tiny-bert").unwrap();
    let (l_fast, c_fast, _) = fast.body_means(p.body_kind());
    let (l_slow, c_slow, _) = slow.body_means(p.body_kind());
    assert!(l_slow > l_fast * 3.0, "throttle not visible: {l_slow} vs {l_fast}");
    // compute time should be roughly disk-independent
    assert!((c_slow - c_fast).abs() < c_fast.max(c_slow), "{c_fast} vs {c_slow}");
}

#[test]
fn planner_empirical_schedule_is_sane() {
    let e = engine();
    let stats = report::profile_one(&e, "tiny-bert", "edge-sd").unwrap();
    let p = e.runtime.profile("tiny-bert").unwrap();
    let min = planner::min_feasible_budget(&stats, p.body_kind());
    let budgets = vec![min, min + 2 * stats.max_stage_bytes(), p.total_weight_bytes * 2];
    let sched = planner::plan(&e, &stats, &budgets, 6, true).unwrap();
    assert_eq!(sched.entries.len(), 3);
    // agents monotone non-decreasing with budget
    let agents: Vec<usize> = sched.entries.iter().map(|x| x.agents).collect();
    assert!(agents.windows(2).all(|w| w[0] <= w[1]), "{agents:?}");
    // every entry's measured peak respects its budget (within transient slack)
    for entry in &sched.entries {
        let peak = entry.measured_peak_bytes.unwrap();
        assert!(
            peak <= entry.budget_bytes + 2 * stats.max_stage_bytes(),
            "peak {peak} above budget {}",
            entry.budget_bytes
        );
    }
}

#[test]
fn schedule_pick_drives_engine() {
    let e = engine();
    let stats = report::profile_one(&e, "tiny-gpt", "unthrottled").unwrap();
    let p = e.runtime.profile("tiny-gpt").unwrap();
    let budgets = vec![p.total_weight_bytes, p.total_weight_bytes * 4];
    let sched = planner::plan(&e, &stats, &budgets, 4, false).unwrap();
    let pick = sched.pick(p.total_weight_bytes * 2).unwrap();
    let cfg = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: pick.agents,
        budget: Some(p.total_weight_bytes * 2),
        disk: "unthrottled".into(),
        gen_tokens: Some(1),
        ..RunConfig::default()
    };
    let (rep, _) = e.run(&cfg).unwrap();
    assert_eq!(rep.agents, pick.agents);
}

#[test]
fn serving_meets_relaxed_slo_and_batches() {
    let e = engine();
    let cfg = ServeConfig {
        run: RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 2,
            disk: "unthrottled".into(),
            ..RunConfig::default()
        },
        num_requests: 6,
        arrival_rps: 0.0, // closed loop
        max_batch: 2,
        slo_ms: 60_000.0,
        ..ServeConfig::default()
    };
    let s = serve(&e, &cfg).unwrap();
    assert_eq!(s.served, 6);
    assert!(s.batches <= 6);
    assert!(s.slo.met);
    assert!(s.throughput_rps > 0.0);
    assert_eq!(s.latency.len(), 6);
}

#[test]
fn report_table1_and_fig2_render() {
    let e = engine();
    let t1 = report::table1(&e).unwrap();
    for m in report::PAPER_MODELS {
        assert!(t1.contains(m), "table1 missing {m}:\n{t1}");
    }
    assert!(t1.contains("TABLE I"));
    let f2 = report::fig2(&e).unwrap();
    assert!(f2.contains("bart-large-sim"));
    // Obs I shows up: every paper model's body share in the 70..99.6 band
    for line in f2.lines().filter(|l| l.contains("-sim")) {
        let cols: Vec<&str> = line.split('|').map(|c| c.trim()).collect();
        let share: f64 = cols[3].parse().unwrap();
        assert!((70.0..=99.9).contains(&share), "{line}");
    }
}

#[test]
fn engine_rejects_bad_configs() {
    let e = engine();
    assert!(e.run(&RunConfig { profile: "nope".into(), ..RunConfig::default() }).is_err());
    assert!(e
        .run(&RunConfig {
            profile: "tiny-bert".into(),
            disk: "floppy".into(),
            ..RunConfig::default()
        })
        .is_err());
    assert!(e
        .run(&RunConfig {
            profile: "tiny-bert".into(),
            batch: 3, // no such AOT entry
            disk: "unthrottled".into(),
            ..RunConfig::default()
        })
        .is_err());
}

#[test]
fn fig1b_reports_idle_fraction() {
    let e = engine();
    let s = report::fig1b(&e, "edge-sd", "tiny-bert", None).unwrap();
    assert!(s.contains("idle fraction"), "{s}");
    assert!(s.contains("IA"), "{s}");
}
