//! Overlapped-decode integration tests: the persistent worker pool,
//! cross-pass prefetch, and the device-resident layer cache are pure
//! optimizations — tokens must stay bit-identical to the non-overlapped
//! path on the golden GPT profiles (kv-cache on and off, pressure on and
//! off), accounting must stay inside the budget, and the new counters
//! must prove the machinery actually engaged.  Needs `make artifacts`.

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn cfg(model: &str) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(6),
        // the non-overlapped reference: no speculation, re-upload per pass
        prefetch_depth: 0,
        device_cache: false,
        ..RunConfig::default()
    }
}

/// The acceptance contract: decode with prefetch + device cache on yields
/// exactly the tokens the plain path yields, for every golden generative
/// profile, batch size, and kv-cache setting — and peak accounted bytes
/// stay inside the budget.
#[test]
fn overlapped_decode_is_bit_identical_across_golden_profiles() {
    let e = engine();
    for model in ["tiny-gpt", "tiny-gptj"] {
        let total = e.runtime.profile(model).unwrap().total_weight_bytes;
        for kv in [false, true] {
            for batch in [1usize, 2] {
                let mut plain = cfg(model);
                plain.kv_cache = kv;
                let mut s = e.open_session(&plain).unwrap();
                let (_, plain_out) = s.run_batch(batch, 1234).unwrap();
                drop(s);

                let mut overlapped = cfg(model);
                overlapped.kv_cache = kv;
                overlapped.budget = Some(3 * total);
                overlapped.pin_budget = Some(total);
                overlapped.prefetch_depth = 8;
                overlapped.device_cache = true;
                let mut s = e.open_session(&overlapped).unwrap();
                let (rep, out) = s.run_batch(batch, 1234).unwrap();

                assert_eq!(
                    plain_out.generated_rows, out.generated_rows,
                    "{model} kv={kv} batch={batch}: overlap must be bit-identical ({rep:?})"
                );
                assert_eq!(plain_out.generated, out.generated);
                assert!(
                    rep.peak_bytes <= 3 * total,
                    "{model} kv={kv} batch={batch}: peak {} above budget {}",
                    rep.peak_bytes,
                    3 * total
                );
                assert!(
                    rep.device_cache_hits > 0,
                    "{model} kv={kv} batch={batch}: device cache never engaged ({rep:?})"
                );
            }
        }
    }
}

/// Prefetch without a hot-layer cache: every next pass re-loads, so the
/// speculative loads are guaranteed useful — the counters must show stages
/// loaded ahead and consumed, and tokens must not change.
#[test]
fn prefetch_engages_and_preserves_tokens_without_pins() {
    let e = engine();
    let total = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let mut plain = cfg("tiny-gpt");
    plain.budget = Some(2 * total);
    let mut s = e.open_session(&plain).unwrap();
    let (_, plain_out) = s.run_batch(1, 77).unwrap();
    drop(s);

    let mut pf = plain.clone();
    pf.prefetch_depth = 8; // covers every stage of the tiny profiles
    let mut s = e.open_session(&pf).unwrap();
    let (rep, out) = s.run_batch(1, 77).unwrap();
    assert_eq!(plain_out.generated_rows, out.generated_rows, "{rep:?}");
    assert!(
        rep.prefetched_stages > 0,
        "6-token decode with budget slack must prefetch something: {rep:?}"
    );
    let pf_stats = s.prefetch_stats();
    assert!(pf_stats.used > 0, "prefetched stages must be consumed: {pf_stats:?}");
    // admissions and speculation respect the budget; only transient
    // activation force_adds may ride above it (the established semantic)
    let max_stage = e.runtime.profile("tiny-gpt").unwrap().max_stage_bytes();
    assert!(
        rep.peak_bytes <= 2 * total + max_stage,
        "peak {} above budget {}",
        rep.peak_bytes,
        2 * total
    );
    // speculation never outlives its usefulness bound: nothing may still
    // be parked once the request is over and no next pass was announced
    assert_eq!(pf_stats.buffered_bytes, 0, "{pf_stats:?}");
}

/// Device-resident weights: with budget slack and a full-model pin budget,
/// every post-first-token stage must execute from retained `PjRtBuffer`s —
/// exactly as many device hits as host-cache hits — without changing
/// tokens or head outputs.
#[test]
fn device_cache_serves_every_hot_stage_and_matches_uncached_output() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let total = profile.total_weight_bytes;
    let n_stages = profile.stages.len();

    let mut without = cfg("tiny-gpt");
    without.pin_budget = Some(total); // host pins on, device cache off
    let mut s = e.open_session(&without).unwrap();
    let (rep_off, out_off) = s.run_batch(1, 42).unwrap();
    drop(s);
    assert_eq!(rep_off.device_cache_hits, 0);

    let mut with = without.clone();
    with.device_cache = true;
    let mut s = e.open_session(&with).unwrap();
    let (rep_on, out_on) = s.run_batch(1, 42).unwrap();

    assert_eq!(out_off.generated, out_on.generated, "device cache changed decode output");
    assert_eq!(out_off.head_sample, out_on.head_sample, "device cache changed head output");
    // tokens 2..6 hit both the host pin cache AND the device cache
    assert_eq!(rep_on.device_cache_hits as usize, 5 * n_stages, "{rep_on:?}");
    assert_eq!(rep_on.device_cache_hits, rep_on.cache_hits, "{rep_on:?}");
    assert_eq!(s.device_stats().hits, rep_on.device_cache_hits);
}

/// A memory budget too tight to keep speculation AND weights in flight:
/// the eviction chain may reclaim prefetched stages (and KV blocks)
/// mid-decode, and the loaders must fall back to normal disk loads —
/// tokens stay identical, the run completes, accounting settles.
#[test]
fn tight_budget_overlap_decode_survives_eviction_with_identical_tokens() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    let budget = max_stage + max_stage / 2;

    let mut plain = cfg("tiny-gpt");
    plain.budget = Some(budget);
    plain.kv_cache = true;
    let mut s = e.open_session(&plain).unwrap();
    let (_, plain_out) = s.run_batch(1, 55).unwrap();
    drop(s);

    let mut overlapped = plain.clone();
    overlapped.prefetch_depth = 8;
    overlapped.device_cache = true; // pin budget 0 => device cap stays 0
    let mut s = e.open_session(&overlapped).unwrap();
    let (rep, out) = s.run_batch(1, 55).unwrap();
    assert_eq!(
        plain_out.generated_rows, out.generated_rows,
        "tokens must survive tight-budget overlap: {rep:?}"
    );
    // every speculative byte was either consumed or reclaimed; nothing
    // may stay parked against a budget this tight
    assert_eq!(s.prefetch_stats().buffered_bytes, 0, "{:?}", s.prefetch_stats());
    assert!(
        rep.peak_bytes <= budget + 2 * max_stage,
        "peak {} far above tight budget {}",
        rep.peak_bytes,
        budget
    );
}

/// The persistent pool amortizes thread creation: a 4-token decode used to
/// spawn 4 x (agents + daemon) threads; the pool spawns each exactly once.
#[test]
fn worker_pool_avoids_per_pass_thread_spawns() {
    let e = engine();
    let mut c = cfg("tiny-gpt");
    c.gen_tokens = Some(4);
    let mut s = e.open_session(&c).unwrap();
    let (rep, _) = s.run_batch(1, 7).unwrap();
    assert_eq!(rep.tokens, 4);
    // 4 passes x (2 agents + 1 daemon) = 12 legacy spawns, 3 real threads
    assert_eq!(rep.spawns_avoided, 9, "{rep:?}");
    let stats = s.pool_stats();
    assert_eq!(stats.threads_spawned, 3);
    assert_eq!(stats.passes, 4);
    // a second request on the same session spawns nothing new
    let (rep2, _) = s.run_batch(1, 8).unwrap();
    assert_eq!(rep2.spawns_avoided, 12, "all 4 passes avoided all 3 spawns: {rep2:?}");
    assert_eq!(s.pool_stats().threads_spawned, 3);
}

/// Per-token decode percentiles and throughput surface in the report
/// (the bench trajectory records them).
#[test]
fn decode_latency_percentiles_reported() {
    let e = engine();
    let mut s = e.open_session(&cfg("tiny-gpt")).unwrap();
    let (rep, _) = s.run_batch(1, 3).unwrap();
    assert_eq!(rep.tokens, 6);
    assert!(rep.decode_p50_ms > 0.0, "{rep:?}");
    assert!(rep.decode_p95_ms >= rep.decode_p50_ms, "{rep:?}");
    assert!(rep.tokens_per_sec > 0.0, "{rep:?}");
    let v = rep.to_json();
    for key in ["decode_p50_ms", "decode_p95_ms", "tokens_per_sec", "prefetched_stages",
        "prefetch_wasted", "device_cache_hits", "spawns_avoided"]
    {
        assert!(v.get(key).is_some(), "missing RunReport json key {key}");
    }
    // non-generative runs report zeros, not garbage
    let mut bert = cfg("tiny-bert");
    bert.gen_tokens = None;
    let mut s = e.open_session(&bert).unwrap();
    let (rep, _) = s.run_batch(1, 3).unwrap();
    assert_eq!(rep.tokens_per_sec, 0.0);
    assert_eq!(rep.decode_p50_ms, 0.0);
}
