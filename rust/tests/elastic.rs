//! Elastic memory controller integration tests: under a shrink-grow
//! memory-pressure trace the stack must (a) settle `used` back under each
//! step's budget, (b) generate bit-identical tokens to a static-budget
//! run, and (c) demonstrably adapt — the agent count and the pin cap
//! re-raise on grow — including across a two-lane router sharing one
//! resizing accountant.  Needs `make artifacts`.

use std::time::Duration;

use hermes::config::{Mode, Paths, RunConfig};
use hermes::elastic::{PressureStep, PressureTrace, GROW_AT_PASS, SHRINK_AT_PASS};
use hermes::engine::Engine;
use hermes::planner::{PlanEntry, Schedule};
use hermes::server::{InferRequest, Router, RouterConfig};

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn gpt_cfg() -> RunConfig {
    RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        gen_tokens: Some(6),
        ..RunConfig::default()
    }
}

/// (a) + (b): a shrink-grow trace must evict pins back under each step's
/// budget (used <= budget after every step settles, per-epoch peak window
/// reset) while the generated tokens stay bit-identical to a static run.
#[test]
fn shrink_grow_settles_under_budget_with_identical_tokens() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let total = profile.total_weight_bytes;
    let max_stage = profile.max_stage_bytes();
    // pin everything while the budget is wide: the shrink then has real
    // state to reclaim
    let base = total + max_stage;
    let mut cfg = gpt_cfg();
    cfg.budget = Some(base);
    cfg.pin_budget = Some(total);

    let trace = PressureTrace::shrink_grow(base);
    let shrunk = trace.steps()[0].budget_bytes;
    assert!(shrunk >= max_stage, "shrunk budget must still admit the largest stage");
    assert!(total > shrunk, "pins must overflow the shrunk budget for this test to bite");

    let mut stat = e.open_session(&cfg).unwrap();
    let (_, static_out) = stat.run_batch(1, 4242).unwrap();
    drop(stat);

    let mut s = e.session(&cfg).memory_trace(trace).open().unwrap();
    let (rep, out) = s.run_batch(1, 4242).unwrap();

    // (b) bit-identical: shrink only evicts, grow only widens
    assert_eq!(static_out.generated_rows, out.generated_rows, "{rep:?}");
    assert_eq!(static_out.generated, out.generated);
    assert_eq!(rep.tokens, 6);

    // (a) the instantaneous invariant, via the per-step epoch records
    assert_eq!(rep.budget_steps, 2, "{rep:?}");
    let epochs = s.budget_epochs();
    assert_eq!(epochs.len(), 2);
    for ep in epochs {
        assert!(
            ep.used_after_bytes <= ep.budget_bytes,
            "used {} must settle under budget {} at pass {}",
            ep.used_after_bytes,
            ep.budget_bytes,
            ep.at_pass
        );
    }
    assert_eq!(epochs[0].budget_bytes, shrunk);
    assert_eq!(epochs[1].budget_bytes, base);
    assert_eq!(epochs[0].at_pass, SHRINK_AT_PASS);
    assert_eq!(epochs[1].at_pass, GROW_AT_PASS);

    // the shrink had to reclaim pinned layers
    assert!(rep.elastic_evictions > 0, "{rep:?}");
    assert!(epochs[0].freed_bytes > 0);
    // (c) the grow re-raises the pin cap (budget - max_stage re-derivation)
    assert!(
        epochs[1].pin_cap_bytes > epochs[0].pin_cap_bytes,
        "grow must widen the pin cap: {epochs:?}"
    );
    assert_eq!(epochs[1].pin_cap_bytes, total.min(base - max_stage));
    // no schedule attached: the agent count never moved
    assert_eq!(rep.replans, 0);
    assert_eq!(s.current_agents(), 2);
}

/// (c) epoch re-planning: with a schedule attached, the shrink drops the
/// Loading Agent count and the grow restores it (counters prove it).
/// KV-cache decode rides along: evicted sequences recompute, tokens match.
#[test]
fn grow_step_restores_agents_via_schedule_replanning() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let max_stage = profile.max_stage_bytes();
    let base = profile.total_weight_bytes + max_stage;
    let trace = PressureTrace::shrink_grow(base);
    let shrunk = trace.steps()[0].budget_bytes;
    assert!(shrunk >= max_stage);

    let mut cfg = gpt_cfg();
    cfg.agents = 3;
    cfg.budget = Some(base);
    cfg.kv_cache = true;

    let entry = |budget: u64, agents: usize| PlanEntry {
        budget_bytes: budget,
        agents,
        predicted_latency_ms: 1.0,
        predicted_peak_bytes: budget,
        measured_latency_ms: None,
        measured_peak_bytes: None,
    };
    let schedule = Schedule {
        profile: "tiny-gpt".into(),
        disk: "unthrottled".into(),
        entries: vec![entry(shrunk, 1), entry(base, 3)],
    };

    let mut stat = e.open_session(&cfg).unwrap();
    let (_, static_out) = stat.run_batch(1, 777).unwrap();
    drop(stat);

    let mut s = e.session(&cfg).memory_trace(trace).schedule(schedule).open().unwrap();
    let (rep, out) = s.run_batch(1, 777).unwrap();

    assert_eq!(static_out.generated_rows, out.generated_rows, "{rep:?}");
    assert_eq!(rep.budget_steps, 2);
    assert_eq!(rep.replans, 2, "shrink AND grow must re-plan: {rep:?}");
    let epochs = s.budget_epochs();
    assert!(epochs[0].replanned && epochs[1].replanned);
    assert_eq!(epochs[0].agents, 1, "shrink drops to the 1-agent plan");
    assert_eq!(epochs[1].agents, 3, "grow re-raises the agent count");
    assert_eq!(s.current_agents(), 3);
    assert_eq!(rep.agents, 3, "the report carries the agents now in force");
    for ep in epochs {
        assert!(ep.used_after_bytes <= ep.budget_bytes, "{ep:?}");
    }
}

/// Two generative KV lanes under ONE shared, resizing accountant: the
/// router applies the trace to the shared budget, rebalances the per-lane
/// KV shares, and every response stays bit-identical to the static run.
#[test]
fn router_two_lanes_adapt_under_shared_resizing_accountant() {
    let e = engine();
    let gpt = e.runtime.profile("tiny-gpt").unwrap();
    let gptj = e.runtime.profile("tiny-gptj").unwrap();
    let base = gpt.total_weight_bytes + gptj.total_weight_bytes;
    let max_stage = gpt.max_stage_bytes().max(gptj.max_stage_bytes());
    let shrunk = base * 60 / 100;
    assert!(shrunk >= max_stage);
    // serialized requests generate 4 passes each; put the shrink after
    // request 1 and the grow after request 2 so both land between batches
    let trace = PressureTrace::new(vec![
        PressureStep { at_pass: 4, budget_bytes: shrunk },
        PressureStep { at_pass: 8, budget_bytes: base },
    ])
    .unwrap();

    let kv_budget = (1u64 << 20) + 1; // odd on purpose: the split must not drop the remainder
    let mk = |p: &str| RunConfig {
        profile: p.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        gen_tokens: Some(4),
        ..RunConfig::default()
    };
    let run_fleet = |trace: Option<PressureTrace>| {
        let cfg = RouterConfig {
            models: vec![mk("tiny-gpt"), mk("tiny-gptj")],
            budget: Some(base),
            kv_budget: Some(kv_budget),
            max_batch: 2,
            batch_window: Duration::from_millis(2),
            memory_trace: trace,
        };
        let mut router = Router::new(&e, cfg).unwrap();
        // satellite guard: the split grants every configured KV byte
        let granted: u64 = router.lane_kv_budgets().iter().map(|b| b.unwrap()).sum();
        assert_eq!(granted, kv_budget, "kv split must not drop the remainder");
        // the gpt lane re-plans per epoch: 2 agents wide, 1 when shrunk
        let entry = |budget: u64, agents: usize| PlanEntry {
            budget_bytes: budget,
            agents,
            predicted_latency_ms: 1.0,
            predicted_peak_bytes: budget,
            measured_latency_ms: None,
            measured_peak_bytes: None,
        };
        router
            .set_lane_schedule(
                "tiny-gpt",
                Schedule {
                    profile: "tiny-gpt".into(),
                    disk: "unthrottled".into(),
                    entries: vec![entry(shrunk, 1), entry(base, 2)],
                },
            )
            .unwrap();
        let handle = router.handle();
        let producer = std::thread::spawn(move || {
            let mut outs = Vec::new();
            for i in 0..6u64 {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                let resp = handle
                    .submit(InferRequest {
                        profile: profile.into(),
                        batch_hint: 1,
                        deadline: None,
                        seed: Some(9000 + i),
                        slo_ms: None,
                    })
                    .unwrap()
                    .wait()
                    .unwrap();
                assert!(resp.ok, "request {i} failed: {:?}", resp.error);
                outs.push(resp.generated_rows);
            }
            handle.shutdown();
            outs
        });
        let summary = router.run().unwrap();
        let outs = producer.join().unwrap();
        (summary, outs)
    };

    let (static_summary, static_outs) = run_fleet(None);
    let (elastic_summary, elastic_outs) = run_fleet(Some(trace));

    assert_eq!(static_summary.budget_steps, 0);
    assert_eq!(static_summary.replans, 0, "no trace, no re-planning");
    assert_eq!(elastic_summary.budget_steps, 2, "shrink and grow must both land");
    assert_eq!(elastic_summary.served, 6);
    assert_eq!(elastic_summary.rejected, 0);
    assert_eq!(
        static_outs, elastic_outs,
        "tokens must be bit-identical under the resizing shared budget"
    );
    // the scheduled lane re-planned on BOTH steps (2 -> 1 -> 2 agents);
    // the unscheduled lane never moved
    assert_eq!(elastic_summary.replans, 2, "{elastic_summary:?}");
    for m in &elastic_summary.per_model {
        assert_eq!(m.served, 3, "{m:?}");
        let want = if m.profile == "tiny-gpt" { 2 } else { 0 };
        assert_eq!(m.replans, want, "{m:?}");
    }
}
