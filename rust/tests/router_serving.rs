//! Router / multi-model serving integration tests: two profiles sharing
//! one global memory budget, cross-session pin eviction, deadline-aware
//! admission, graceful producer teardown, the central config validation
//! funnel, and the TCP front-end round trip.  Needs `make artifacts`.

use std::net::TcpStream;
use std::time::Duration;

use hermes::config::{Mode, Paths, RunConfig};
use hermes::elastic::{PressureStep, PressureTrace};
use hermes::engine::Engine;
use hermes::memory::MemoryAccountant;
use hermes::server::tcp::roundtrip;
use hermes::server::{
    ConcurrentRouter, InferRequest, Router, RouterConfig, RouterHandle, TcpFrontend,
};
use hermes::util::json::Value;

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn run_cfg(model: &str, agents: usize) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents,
        disk: "unthrottled".into(),
        ..RunConfig::default()
    }
}

#[test]
fn router_serves_two_profiles_under_one_shared_budget() {
    let e = engine();
    let total_a = e.runtime.profile("tiny-bert").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let budget = total_a + total_b;

    let mut gpt = run_cfg("tiny-gpt", 2);
    gpt.gen_tokens = Some(2);
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2), gpt],
        budget: Some(budget),
        kv_budget: None,
        max_batch: 2,
        batch_window: Duration::from_millis(5),
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    assert_eq!(router.accountant().budget(), Some(budget));

    let handle = router.handle();
    let producer = std::thread::spawn(move || {
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let profile = if i % 2 == 0 { "tiny-bert" } else { "tiny-gpt" };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        let responses: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        handle.shutdown();
        responses
    });
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    assert_eq!(summary.served, 8, "all requests must complete");
    assert_eq!(summary.rejected, 0);
    assert!(responses.iter().all(|r| r.ok), "{responses:?}");
    assert!(
        summary.peak_bytes <= budget,
        "shared peak {} above global budget {}",
        summary.peak_bytes,
        budget
    );
    assert_eq!(summary.per_model.len(), 2);
    for m in &summary.per_model {
        assert_eq!(m.served, 4, "lane {} served {}", m.profile, m.served);
    }
    assert!(responses
        .iter()
        .filter(|r| r.profile == "tiny-gpt")
        .all(|r| r.tokens == 2));
    assert_eq!(
        e.runtime.prepare_calls(),
        2,
        "one AOT prepare per session (per model), never per batch"
    );
}

#[test]
fn router_two_generative_kv_lanes_stay_under_budget() {
    // Acceptance: two GPT-style lanes decode with --kv-cache under ONE
    // shared budget; peak accounted bytes never exceed it, every request
    // gets its own per-row tokens, and the decode is incremental.
    let e = engine();
    let total_a = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gptj").unwrap().total_weight_bytes;
    let budget = total_a + total_b;

    let mut ga = run_cfg("tiny-gpt", 2);
    ga.kv_cache = true;
    ga.gen_tokens = Some(4);
    let mut gb = run_cfg("tiny-gptj", 2);
    gb.kv_cache = true;
    gb.gen_tokens = Some(4);
    let cfg = RouterConfig {
        models: vec![ga, gb],
        budget: Some(budget),
        // split across the two kv lanes; ample for tiny profiles
        kv_budget: Some(1 << 20),
        max_batch: 2,
        batch_window: Duration::from_millis(5),
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    let handle = router.handle();
    let producer = std::thread::spawn(move || {
        let tickets: Vec<_> = (0..6)
            .map(|i| {
                let profile = if i % 2 == 0 { "tiny-gpt" } else { "tiny-gptj" };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        let responses: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        handle.shutdown();
        responses
    });
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    assert_eq!(summary.served, 6, "{:?}", summary.first_error);
    assert_eq!(summary.rejected, 0);
    assert!(
        summary.peak_bytes <= budget,
        "kv blocks + weights peaked at {} over the shared budget {}",
        summary.peak_bytes,
        budget
    );
    assert!(summary.kv_inc_passes > 0, "decode must run incrementally: {summary:?}");
    assert_eq!(summary.kv_recomputes, 0, "no pressure -> no recompute: {summary:?}");
    for r in &responses {
        assert!(r.ok, "{r:?}");
        assert_eq!(r.tokens, 4);
        assert_eq!(r.generated_rows.len(), 1, "one row per batch_hint=1 request");
        assert_eq!(r.generated_rows[0].len(), 4);
    }
    // per-lane counters surfaced
    for m in &summary.per_model {
        assert!(m.kv_inc_passes > 0, "{m:?}");
    }
}

#[test]
fn shared_accountant_contention_evicts_other_sessions_pins() {
    let e = engine();
    let pa = e.runtime.profile("tiny-bert").unwrap();
    let pb = e.runtime.profile("tiny-gpt").unwrap();
    let max_a = pa.stages.iter().map(|s| pa.stage_bytes(s)).max().unwrap();
    let max_b = pb.stages.iter().map(|s| pb.stage_bytes(s)).max().unwrap();
    let max_both = max_a.max(max_b);
    // A can pin its whole model; B's pass then cannot hold two stages in
    // flight without hitting the budget -> S^stop pressure on A's pins.
    // (The -1 keeps two B stages from fitting exactly on the boundary, so
    // B's second prefetch admission deterministically stalls and evicts.)
    let budget = pa.total_weight_bytes + 2 * max_both - 1;
    let shared = MemoryAccountant::new(Some(budget));

    let mut ca = run_cfg("tiny-bert", 2);
    ca.pin_budget = Some(pa.total_weight_bytes);
    let mut cb = run_cfg("tiny-gpt", 2);
    cb.gen_tokens = Some(2); // no pin budget: B only applies pressure

    let mut sa = e.open_session_shared(&ca, &shared).unwrap();
    let mut sb = e.open_session_shared(&cb, &shared).unwrap();
    let cache_a = sa.layer_cache().expect("A has a pin budget").clone();
    assert!(sb.layer_cache().is_none());
    sb.add_eviction_victim(cache_a.clone());

    // A's first pass pins every stage (budget slack); the second is all hits
    sa.run_batch(1, 7).unwrap();
    sa.run_batch(1, 8).unwrap();
    let pins = cache_a.stats();
    assert_eq!(pins.pinned_layers, pa.stages.len(), "{pins:?}");
    assert!(sa.cache_stats().hits >= pa.stages.len() as u64, "{:?}", sa.cache_stats());
    assert_eq!(pins.evictions, 0, "A alone must not feel pressure");

    // B's pass must stall on the shared budget and evict A's pins
    sb.run_batch(1, 9).unwrap();
    let after = cache_a.stats();
    assert!(
        after.evictions > 0,
        "B's S^stop pressure must evict A's pinned layers ({after:?})"
    );
    assert!(after.pinned_bytes < pins.pinned_bytes);

    // both sessions keep working after cross-eviction
    sa.run_batch(1, 10).unwrap();
    sb.run_batch(1, 11).unwrap();
    assert_eq!(sa.passes_run(), 3);
    assert_eq!(sb.passes_run(), 4, "2 decode tokens per run_batch");

    // the shared peak stays within budget + per-pass transients (one
    // device-upload weight copy + activations), mirroring the slack the
    // single-session tests allow
    assert!(
        shared.peak() <= budget + 2 * max_both,
        "peak {} far above shared budget {}",
        shared.peak(),
        budget
    );
}

#[test]
fn expired_deadline_is_rejected_without_a_pass() {
    let e = engine();
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2)],
        budget: None,
        kv_budget: None,
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    let handle = router.handle();
    let t_ok = handle.submit(InferRequest::new("tiny-bert")).unwrap();
    let t_exp = handle
        .submit(InferRequest {
            profile: "tiny-bert".into(),
            deadline: Some(Duration::ZERO),
            ..InferRequest::default()
        })
        .unwrap();
    let t_missing = handle.submit(InferRequest::new("no-such-profile")).unwrap();
    handle.shutdown();
    drop(handle);
    let summary = router.run().unwrap();

    let ok = t_ok.wait().unwrap();
    assert!(ok.ok);
    assert!(ok.batch >= 1);
    let exp = t_exp.wait().unwrap();
    assert!(!exp.ok);
    assert!(exp.error.as_deref().unwrap().contains("deadline"), "{exp:?}");
    let missing = t_missing.wait().unwrap();
    assert!(!missing.ok);
    assert!(missing.error.as_deref().unwrap().contains("unknown profile"), "{missing:?}");
    assert_eq!(summary.served, 1);
    assert_eq!(summary.rejected, 2);
}

#[test]
fn dropped_producer_ends_serving_gracefully() {
    // Regression for the old `rx.recv().expect("producer ended early")`:
    // dropping every handle (no shutdown message) must end the loop
    // cleanly, serving what was queued — never panic.
    let e = engine();
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2)],
        budget: None,
        kv_budget: None,
        max_batch: 4,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    let handle = router.handle();
    let ticket = handle.submit(InferRequest::new("tiny-bert")).unwrap();
    drop(handle); // producer "ends early"
    let summary = router.run().unwrap();
    assert_eq!(summary.served, 1);
    assert!(ticket.wait().unwrap().ok);
}

#[test]
fn config_validation_rejects_bad_entries_at_open() {
    let e = engine();
    let mut bad_batch = run_cfg("tiny-bert", 2);
    bad_batch.batch = 3; // no such AOT entry
    let err = e.open_session(&bad_batch).unwrap_err().to_string();
    assert!(err.contains("not AOT-compiled"), "{err}");

    // --kv-cache is live for pipelined modes now; the baseline still bails
    let mut kv = run_cfg("tiny-bert", 2);
    kv.kv_cache = true;
    kv.mode = Mode::Baseline;
    let err = e.open_session(&kv).unwrap_err().to_string();
    assert!(err.contains("pipelined mode"), "{err}");

    let mut kv_budget_alone = run_cfg("tiny-bert", 2);
    kv_budget_alone.kv_budget = Some(1 << 20);
    let err = e.open_session(&kv_budget_alone).unwrap_err().to_string();
    assert!(err.contains("--kv-cache"), "{err}");

    let mut pin_over = run_cfg("tiny-bert", 2);
    pin_over.budget = Some(1000);
    pin_over.pin_budget = Some(2000);
    let err = e.open_session(&pin_over).unwrap_err().to_string();
    assert!(err.contains("pin budget"), "{err}");

    // the same funnel guards the router: one bad entry fails construction
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2), RunConfig { agents: 0, ..run_cfg("tiny-gpt", 2) }],
        budget: None,
        kv_budget: None,
        max_batch: 2,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let err = Router::new(&e, cfg).unwrap_err().to_string();
    assert!(err.contains("agents"), "{err}");

    // duplicate model entries are rejected
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2), run_cfg("tiny-bert", 4)],
        budget: None,
        kv_budget: None,
        max_batch: 2,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let err = Router::new(&e, cfg).unwrap_err().to_string();
    assert!(err.contains("duplicate"), "{err}");
}

/// Submit 12 alternating requests from a producer thread; returns the
/// responses in submission order after asking the router to shut down.
fn drive_two_lanes(
    handle: RouterHandle,
    lane_a: &'static str,
    lane_b: &'static str,
) -> std::thread::JoinHandle<Vec<hermes::server::InferResponse>> {
    std::thread::spawn(move || {
        let tickets: Vec<_> = (0..12)
            .map(|i| {
                let profile = if i % 2 == 0 { lane_a } else { lane_b };
                handle.submit(InferRequest::new(profile)).unwrap()
            })
            .collect();
        let responses: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        handle.shutdown();
        responses
    })
}

#[test]
fn concurrent_router_overlaps_lanes_with_serialized_identical_tokens() {
    // PR 6 acceptance: two KV-decode lanes served by the concurrent router
    // must (a) overlap passes (concurrent_passes_peak >= 2), (b) stay under
    // the ONE shared budget, and (c) emit per-lane token streams
    // bit-identical to the serialized router's for the same traffic.
    let e = engine();
    let total_a = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gptj").unwrap().total_weight_bytes;
    // headroom for both lanes' weights in flight at once, plus KV
    let budget = 2 * (total_a + total_b);
    let mk_cfg = || {
        let mut ga = run_cfg("tiny-gpt", 2);
        ga.kv_cache = true;
        ga.gen_tokens = Some(4);
        let mut gb = run_cfg("tiny-gptj", 2);
        gb.kv_cache = true;
        gb.gen_tokens = Some(4);
        RouterConfig {
            models: vec![ga, gb],
            budget: Some(budget),
            kv_budget: Some(1 << 20),
            max_batch: 1,
            batch_window: Duration::from_millis(1),
            ..RouterConfig::default()
        }
    };

    // serialized reference run
    let router = Router::new(&e, mk_cfg()).unwrap();
    let producer = drive_two_lanes(router.handle(), "tiny-gpt", "tiny-gptj");
    let serial = router.run().unwrap();
    let serial_rows: Vec<_> = producer
        .join()
        .unwrap()
        .into_iter()
        .map(|r| (r.profile, r.generated_rows))
        .collect();
    assert_eq!(serial.served, 12, "{:?}", serial.first_error);
    assert_eq!(
        serial.concurrent_passes_peak, 1,
        "one dispatch thread can never overlap passes"
    );

    // concurrent run, same traffic
    let router = ConcurrentRouter::new(Paths::detect(), mk_cfg()).unwrap();
    assert_eq!(router.accountant().budget(), Some(budget));
    let producer = drive_two_lanes(router.handle(), "tiny-gpt", "tiny-gptj");
    let summary = router.run().unwrap();
    let conc_rows: Vec<_> = producer
        .join()
        .unwrap()
        .into_iter()
        .map(|r| (r.profile, r.generated_rows))
        .collect();

    assert_eq!(summary.served, 12, "{:?}", summary.first_error);
    assert_eq!(summary.rejected, 0);
    assert!(
        summary.concurrent_passes_peak >= 2,
        "lanes never overlapped a pass: {summary:?}"
    );
    assert!(
        summary.peak_bytes <= budget,
        "shared peak {} above global budget {}",
        summary.peak_bytes,
        budget
    );
    assert_eq!(summary.per_model.len(), 2);
    for m in &summary.per_model {
        assert_eq!(m.served, 6, "lane {} served {}", m.profile, m.served);
        assert!(m.kv_inc_passes > 0, "decode must stay incremental: {m:?}");
    }
    assert_eq!(
        conc_rows, serial_rows,
        "per-lane tokens must be bit-identical to the serialized router"
    );
    // per-lane queue-wait percentiles are live on both paths
    assert!(summary.queue_wait_p95_ms >= summary.queue_wait_p50_ms);
}

#[test]
fn concurrent_router_elastic_shrink_rebalances_mid_flight() {
    // An elastic shrink landing while both lanes are serving must settle
    // under the new budget without stopping either lane, and rebalance the
    // worker allotment (replans) across the running lanes.
    let e = engine();
    let total_a = e.runtime.profile("tiny-bert").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let budget = 2 * (total_a + total_b);
    let trace = PressureTrace::new(vec![PressureStep {
        at_pass: 4,
        budget_bytes: budget / 2,
    }])
    .unwrap();

    let mut gpt = run_cfg("tiny-gpt", 2);
    gpt.gen_tokens = Some(2);
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2), gpt],
        budget: Some(budget),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        memory_trace: Some(trace),
        concurrent: true,
        worker_allotment: Some(4),
        ..RouterConfig::default()
    };
    let router = ConcurrentRouter::new(Paths::detect(), cfg).unwrap();
    let accountant = router.accountant().clone();
    let producer = drive_two_lanes(router.handle(), "tiny-bert", "tiny-gpt");
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    assert_eq!(summary.served, 12, "{:?}", summary.first_error);
    assert_eq!(summary.rejected, 0);
    assert!(responses.iter().all(|r| r.ok), "{responses:?}");
    assert!(summary.budget_steps >= 1, "the trace step must apply: {summary:?}");
    assert!(
        summary.replans >= 1,
        "the shrink must rebalance worker slices across running lanes: {summary:?}"
    );
    // the fleet settled under the shrunk budget without deadlocking
    assert_eq!(accountant.budget(), Some(budget / 2));
    assert!(
        accountant.used() <= budget / 2,
        "steady-state bytes {} above the shrunk budget {}",
        accountant.used(),
        budget / 2
    );
}

#[test]
fn tcp_front_end_serves_the_concurrent_router() {
    // --concurrent swaps the router behind the same wire protocol.
    let e = engine();
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2)],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        concurrent: true,
        ..RouterConfig::default()
    };
    let frontend = TcpFrontend::bind("127.0.0.1:0").unwrap();
    let addr = frontend.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let reply =
            roundtrip(&mut stream, &InferRequest::new("tiny-bert").to_json()).unwrap();
        assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");
        let reply =
            roundtrip(&mut stream, &Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "shutdown");
    });
    let summary = frontend.run(&e, cfg).unwrap();
    client.join().unwrap();
    assert_eq!(summary.served, 1, "{:?}", summary.first_error);
}

#[test]
fn tcp_front_end_round_trip() {
    let e = engine();
    let cfg = RouterConfig {
        models: vec![run_cfg("tiny-bert", 2)],
        budget: None,
        kv_budget: None,
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let frontend = TcpFrontend::bind("127.0.0.1:0").unwrap();
    let addr = frontend.local_addr().unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let reply =
            roundtrip(&mut stream, &Value::parse(r#"{"op":"ping"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "pong");

        let req = InferRequest::new("tiny-bert").to_json();
        let reply = roundtrip(&mut stream, &req).unwrap();
        assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");
        assert_eq!(reply.get("profile").unwrap().as_str().unwrap(), "tiny-bert");
        assert_eq!(reply.get("batch").unwrap().as_usize().unwrap(), 1);

        // unknown profile: graceful JSON error, connection stays usable
        let reply = roundtrip(
            &mut stream,
            &Value::parse(r#"{"op":"infer","profile":"no-such-profile"}"#).unwrap(),
        )
        .unwrap();
        assert!(!reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");

        // malformed line: graceful JSON error too
        let mut raw = TcpStream::connect(addr).unwrap();
        use std::io::{BufRead, BufReader, Write};
        raw.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = Value::parse(line.trim()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());

        let reply =
            roundtrip(&mut stream, &Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "shutdown");
    });

    let summary = frontend.run(&e, cfg).unwrap();
    client.join().unwrap();
    assert_eq!(summary.served, 1);
    assert_eq!(summary.rejected, 1, "the unknown-profile request");
}
