//! Unified-telemetry integration tests (PR 8): the structured event bus
//! must *reconcile* with the counters the engine already reports (trace
//! spans are the same stalls, not a second opinion), the Chrome trace it
//! exports must be schema-valid under two-lane continuous churn, the
//! mid-flight `stats` snapshot must use the same aggregation as the final
//! summary, and — the cardinal rule — telemetry must never perturb the
//! tokens it observes.  Needs `make artifacts`.

use std::time::Duration;

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;
use hermes::server::{ConcurrentRouter, InferRequest, Router, RouterConfig};
use hermes::telemetry::{chrome, worker, Event, Telemetry};

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

/// Sum the extents of every `X` span named `name`, in milliseconds.
fn span_sum_ms(events: &[Event], name: &str) -> f64 {
    events.iter().filter(|e| e.name == name).map(|e| e.dur_us as f64 / 1000.0).sum()
}

fn close(trace_ms: f64, report_ms: f64, what: &str) {
    let tol = 0.15 * trace_ms.max(report_ms) + 10.0;
    assert!(
        (trace_ms - report_ms).abs() <= tol,
        "{what}: trace says {trace_ms:.2} ms, report says {report_ms:.2} ms (tol {tol:.2})"
    );
}

/// Trace-derived stall sums must reconcile with the `RunReport` counters:
/// both sides time the same gate waits / recv waits with their own clock
/// reads, so they agree within a small tolerance.
#[test]
fn trace_stall_sums_reconcile_with_run_report() {
    let e = engine();
    let profile = e.runtime.profile("tiny-bert").unwrap();
    let max_stage = profile.max_stage_bytes();
    let cfg = RunConfig {
        profile: "tiny-bert".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        // two loaders against a two-stage window: the loader ahead blocks
        // on the gate (mem stalls) while the throttled disk starves the
        // inference agent (wait stalls)
        budget: Some(2 * max_stage),
        disk: "edge-sd".into(),
        ..RunConfig::default()
    };
    let telemetry = Telemetry::on();
    let mut session = e.open_session(&cfg).unwrap();
    session.set_telemetry(telemetry.clone());
    let (rep, _) = session.run().unwrap();
    drop(session); // joins the worker pool: every span is flushed

    let events = telemetry.drain();
    assert_eq!(telemetry.dropped(), 0);
    assert!(rep.wait_stall_ms > 0.0, "throttled disk must starve the inference agent");
    assert!(rep.mem_stall_ms > 0.0, "tight budget must block the look-ahead loader");
    close(span_sum_ms(&events, "stall_wait"), rep.wait_stall_ms, "wait stalls");
    close(span_sum_ms(&events, "stall_mem"), rep.mem_stall_ms, "mem stalls");

    // the load spans cover every stage of the pass, on loader rows
    let loads: Vec<&Event> = events.iter().filter(|e| e.name == "load").collect();
    assert_eq!(loads.len(), profile.stages.len(), "one load span per stage");
    assert!(loads.iter().all(|e| e.worker >= worker::loader(0)));
    assert!(span_sum_ms(&events, "compute") > 0.0, "compute spans on the inference row");
}

/// A generative continuous KV lane for the router tests.
fn kv_lane(model: &str) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(4),
        continuous: true,
        max_active: Some(1),
        ..RunConfig::default()
    }
}

/// Two-lane continuous serve under churn (plus one engineered shed): the
/// exported Chrome trace must validate — every `B` has a matching `E` on
/// its row, timestamps are monotonic per row, and the full lifecycle
/// vocabulary (join / leave / shed included) is present across both lane
/// pids.
#[test]
fn two_lane_continuous_trace_is_schema_valid() {
    let cfg = RouterConfig {
        models: vec![kv_lane("tiny-gpt"), kv_lane("tiny-gptj")],
        kv_budget: Some(1 << 20),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        concurrent: true,
        ..RouterConfig::default()
    };
    let telemetry = Telemetry::on();
    let mut router = ConcurrentRouter::new(Paths::detect(), cfg).unwrap();
    router.set_telemetry(telemetry.clone());
    let handle = router.handle();

    // lane A: a live head plus a request whose SLO is already blown by
    // the time the slot frees (max_active 1) -> a guaranteed shed
    let t_head = handle
        .submit(InferRequest {
            profile: "tiny-gpt".into(),
            seed: Some(1),
            ..InferRequest::default()
        })
        .unwrap();
    let t_shed = handle
        .submit(InferRequest {
            profile: "tiny-gpt".into(),
            seed: Some(2),
            slo_ms: Some(0.001),
            ..InferRequest::default()
        })
        .unwrap();
    // lane B: ordinary churn
    let t_b: Vec<_> = (0..2u64)
        .map(|i| {
            handle
                .submit(InferRequest {
                    profile: "tiny-gptj".into(),
                    seed: Some(10 + i),
                    ..InferRequest::default()
                })
                .unwrap()
        })
        .collect();
    handle.shutdown();
    drop(handle);
    let summary = router.run().unwrap();

    assert!(t_head.wait().unwrap().ok);
    let shed = t_shed.wait().unwrap();
    assert!(!shed.ok, "{shed:?}");
    assert_eq!(shed.reason.as_deref(), Some("shed_overload"), "{shed:?}");
    for t in t_b {
        assert!(t.wait().unwrap().ok);
    }
    assert_eq!(summary.served, 3, "{:?}", summary.first_error);
    assert_eq!(summary.shed_overload, 1);
    assert_eq!(summary.reject_reasons.shed_overload, 1, "{:?}", summary.reject_reasons);

    let events = telemetry.drain();
    assert_eq!(telemetry.dropped(), 0, "the default shard cap must hold a short serve");
    for name in ["enqueue", "admit", "prime", "join", "decode_step", "retire", "leave", "shed"] {
        assert!(events.iter().any(|e| e.name == name), "missing '{name}' in the trace");
    }
    for lane in [0u32, 1] {
        assert!(events.iter().any(|e| e.lane == lane), "no events for lane {lane}");
    }
    let doc = chrome::chrome_trace(&events, telemetry.dropped());
    chrome::validate(&doc).expect("exported Chrome trace must be schema-valid");
}

/// The mid-flight `stats` snapshot goes through the same aggregation as
/// the final summary, so a snapshot taken after the last reply (but while
/// the router still runs) matches the shutdown summary counter for
/// counter.
#[test]
fn mid_flight_stats_match_final_summary() {
    let e = engine();
    let cfg = RouterConfig {
        models: vec![RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 2,
            disk: "unthrottled".into(),
            ..RunConfig::default()
        }],
        max_batch: 2,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    let handle = router.handle();
    let probe = std::thread::spawn(move || {
        let tickets: Vec<_> = (0..4u64)
            .map(|i| {
                handle
                    .submit(InferRequest {
                        profile: "tiny-bert".into(),
                        seed: Some(100 + i),
                        ..InferRequest::default()
                    })
                    .unwrap()
            })
            .collect();
        for t in tickets {
            assert!(t.wait().unwrap().ok);
        }
        let mid = handle.stats().unwrap();
        handle.shutdown();
        mid
    });
    let fin = router.run().unwrap();
    let mid = probe.join().unwrap();

    assert_eq!(mid.served, 4);
    assert_eq!(mid.served, fin.served);
    assert_eq!(mid.rejected, fin.rejected);
    assert_eq!(mid.batches, fin.batches);
    assert_eq!(mid.peak_bytes, fin.peak_bytes);
    assert_eq!(mid.reject_reasons.iter(), fin.reject_reasons.iter());
    assert_eq!(mid.latency.p95(), fin.latency.p95());
    assert_eq!(mid.cache_hits, fin.cache_hits);
    assert_eq!(mid.cache_misses, fin.cache_misses);
}

/// The cardinal rule: telemetry observes, it never gates.  The same
/// seeded decode generates bit-identical tokens with the bus on and off.
#[test]
fn tokens_bit_identical_with_telemetry_on() {
    let e = engine();
    let cfg = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(6),
        ..RunConfig::default()
    };

    let mut quiet = e.open_session(&cfg).unwrap();
    let (_, out_off) = quiet.run_batch(1, 4242).unwrap();
    drop(quiet);

    let telemetry = Telemetry::on();
    let mut traced = e.open_session(&cfg).unwrap();
    traced.set_telemetry(telemetry.clone());
    let (rep, out_on) = traced.run_batch(1, 4242).unwrap();
    drop(traced);

    assert_eq!(rep.tokens, 6);
    assert_eq!(out_off.generated, out_on.generated);
    assert_eq!(out_off.generated_rows, out_on.generated_rows);
    assert!(!telemetry.drain().is_empty(), "the traced run must have recorded events");
}
