//! KV-cache decode integration tests: incremental decode must be a pure
//! optimization — bit-identical tokens to the cache-off path on the golden
//! profiles, 1 full-prefix pass + (N-1) incremental passes when the cache
//! holds, graceful full-prefix fallback when blocks are denied or evicted
//! mid-decode, and per-request block lifecycle.  Needs `make artifacts`.

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn cfg(model: &str, kv: bool) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: kv,
        gen_tokens: Some(6),
        ..RunConfig::default()
    }
}

/// The acceptance contract: for every generative golden profile and batch
/// size, `--kv-cache` decode yields exactly the tokens the cache-off path
/// yields — every row — and the pass shape is 1 full + (N-1) incremental.
#[test]
fn kv_decode_matches_cache_off_bit_exactly() {
    let e = engine();
    for model in ["tiny-gpt", "tiny-gptj"] {
        for batch in [1usize, 2] {
            let mut off = e.open_session(&cfg(model, false)).unwrap();
            let (off_rep, off_out) = off.run_batch(batch, 1234).unwrap();
            drop(off);

            let mut on = e.open_session(&cfg(model, true)).unwrap();
            let (on_rep, on_out) = on.run_batch(batch, 1234).unwrap();

            assert_eq!(
                off_out.generated_rows, on_out.generated_rows,
                "{model} batch {batch}: kv decode must be bit-identical"
            );
            assert_eq!(off_out.generated, on_out.generated);
            assert_eq!(on_out.generated_rows.len(), batch);
            assert_eq!(off_rep.tokens, 6);
            assert_eq!(on_rep.tokens, 6);

            // pass shape: 1 full-prefix (prime) + 5 incremental
            assert_eq!(on_rep.kv_inc_passes, 5, "{model} batch {batch}: {on_rep:?}");
            assert_eq!(on_rep.kv_recomputes, 0);
            let (inc, rec) = on.kv_counters();
            assert_eq!((inc, rec), (5, 0));
            // cache-off decode never touches the KV counters
            assert_eq!(off_rep.kv_inc_passes, 0);

            // per-request lifecycle: every block freed at run_batch exit
            assert_eq!(on.kv_pool().unwrap().used_bytes(), 0);
            assert!(on.kv_pool_stats().allocated_blocks > 0);
        }
    }
}

/// Exhausting the KV budget mid-decode (pool cap, not accountant pressure)
/// forces full-prefix recomputes — tokens stay identical.
#[test]
fn kv_budget_exhaustion_falls_back_to_recompute_with_identical_tokens() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    // One block row covers 8 tokens/layer; prompt(4) + 6 generated = 10
    // tokens, so a cap of exactly one block row per layer (stages * block
    // bytes for batch 1) exhausts after token 8 and forces recomputes.
    let n_body = profile.stages.iter().filter(|s| s.kind == "decoder_layer").count() as u64;
    let block_bytes = 8 * profile.hidden as u64 * 4 * 2;
    let mut kv_cfg = cfg("tiny-gpt", true);
    kv_cfg.kv_budget = Some(n_body * block_bytes);

    let mut off = e.open_session(&cfg("tiny-gpt", false)).unwrap();
    let (_, off_out) = off.run_batch(1, 77).unwrap();
    drop(off);

    let mut on = e.open_session(&kv_cfg).unwrap();
    let (rep, on_out) = on.run_batch(1, 77).unwrap();
    assert_eq!(off_out.generated_rows, on_out.generated_rows, "{rep:?}");
    let (inc, rec) = on.kv_counters();
    assert!(inc > 0, "the first block row must serve incrementally: {rep:?}");
    assert!(rec > 0, "the cap must force at least one recompute: {rep:?}");
    assert_eq!(inc + rec, 5, "every non-prime token is either inc or recompute");
    assert_eq!(on.kv_pool().unwrap().used_bytes(), 0, "blocks freed at exit");
}

/// A memory budget too tight to hold weights-in-flight AND the cached KV
/// forces the gate to evict KV blocks mid-decode (`S^stop` pressure).
/// Decode must degrade to recompute, not fail, and tokens stay identical.
#[test]
fn forced_mid_decode_eviction_keeps_tokens_identical() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    // Enough for the pipeline to make progress (ordered admission needs one
    // stage at a time) but far too small to ALSO keep the KV pool resident:
    // the pool's block spans all 4 body layers and then some.
    let budget = max_stage + max_stage / 2;

    let mut off_cfg = cfg("tiny-gpt", false);
    off_cfg.budget = Some(budget);
    let mut off = e.open_session(&off_cfg).unwrap();
    let (_, off_out) = off.run_batch(1, 55).unwrap();
    drop(off);

    let mut on_cfg = cfg("tiny-gpt", true);
    on_cfg.budget = Some(budget);
    let mut on = e.open_session(&on_cfg).unwrap();
    let (rep, on_out) = on.run_batch(1, 55).unwrap();

    assert_eq!(
        off_out.generated_rows, on_out.generated_rows,
        "tokens must survive forced KV eviction: {rep:?}"
    );
    assert!(
        rep.kv_evicted_blocks > 0,
        "budget {budget} must force mid-decode KV eviction: {rep:?}"
    );
    let (_inc, rec) = on.kv_counters();
    assert!(rec > 0, "evicted sequences must recompute: {rep:?}");
    assert_eq!(on.kv_pool().unwrap().used_bytes(), 0, "blocks freed at exit");
    assert!(
        rep.peak_bytes <= budget + 2 * max_stage,
        "peak {} far above budget {}",
        rep.peak_bytes,
        budget
    );
}

/// BART is generative but ships no incremental entries: `--kv-cache` must
/// quietly fall back to full-prefix decode (identical tokens, no pool).
#[test]
fn kv_cache_on_bart_degrades_to_full_prefix() {
    let e = engine();
    let mut off_cfg = cfg("bart-base-sim", false);
    off_cfg.gen_tokens = Some(2);
    let mut off = e.open_session(&off_cfg).unwrap();
    let (_, off_out) = off.run_batch(1, 3).unwrap();
    drop(off);

    let mut on_cfg = cfg("bart-base-sim", true);
    on_cfg.gen_tokens = Some(2);
    let mut on = e.open_session(&on_cfg).unwrap();
    assert!(on.kv_pool().is_none(), "no inc entries -> no pool");
    let (rep, on_out) = on.run_batch(1, 3).unwrap();
    assert_eq!(off_out.generated_rows, on_out.generated_rows);
    assert_eq!(rep.kv_inc_passes, 0);
    assert_eq!(rep.kv_recomputes, 0);
}

/// Batched decode returns every row's own continuation (regression guard
/// for the row-0-only `RunOutput::generated`).
#[test]
fn generated_rows_differ_across_batch_rows() {
    let e = engine();
    let mut s = e.open_session(&cfg("tiny-gpt", true)).unwrap();
    let (_, out) = s.run_batch(2, 99).unwrap();
    assert_eq!(out.generated_rows.len(), 2);
    assert_eq!(out.generated_rows[0], out.generated);
    assert_eq!(out.generated_rows[0].len(), 6);
    assert_eq!(out.generated_rows[1].len(), 6);
    // different prompts per row -> (with overwhelming probability over the
    // golden weights) different continuations; equality would indicate the
    // old row-0 broadcast bug
    assert_ne!(out.generated_rows[0], out.generated_rows[1]);
}
