//! Integration tests for the PIPELOAD mechanism itself: memory discipline,
//! signal protocol, stall behaviour, failure injection.
//!
//! Needs `make artifacts` (tiny profiles) — weights are generated here.

use hermes::config::Paths;
use hermes::diskio::Disk;
use hermes::engine::{make_input, WEIGHTS_SEED};
use hermes::pipeload::{run_pipeline, ExecCtx, PipelineOpts};
use hermes::runtime::Runtime;
use hermes::signals::Signal;
use hermes::trace::Tracer;
use hermes::weights::gen::gen_profile_weights;

fn setup(profile: &str) -> (Paths, Runtime) {
    let paths = Paths::detect();
    let runtime = Runtime::new(&paths.artifacts).unwrap();
    let p = runtime.profile(profile).unwrap();
    gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false).unwrap();
    (paths, runtime)
}

fn ctx<'rt>(runtime: &'rt Runtime, paths: &Paths, profile: &str) -> ExecCtx<'rt> {
    ExecCtx::new(runtime, profile, &paths.weights, Disk::preset("unthrottled").unwrap()).unwrap()
}

#[test]
fn pipeload_respects_memory_budget() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let ctx = ctx(&runtime, &paths, "tiny-bert");
    let (input, _, _) = make_input(profile, 1, 1);
    // budget: 3 max stages + slack — far below the full model
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    let budget = 4 * max_stage;
    assert!(budget < profile.total_weight_bytes);
    let (_, stats) = run_pipeline(&ctx, &PipelineOpts::pipeload(6), Some(budget), &input).unwrap();
    assert!(
        stats.peak_bytes <= budget + 2 * max_stage, // force_add transient + acts may exceed
        "peak {} vastly above budget {budget}",
        stats.peak_bytes
    );
    // a tight budget with many agents must stall loading (S^stop fired)
    assert!(stats.mem_stall_ms >= 0.0);
}

#[test]
fn pipeload_peak_is_fraction_of_pipeswitch_peak() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let (input, _, _) = make_input(profile, 1, 1);
    // On slow storage PIPELOAD's pacing alone keeps few layers resident.
    let slow = Disk::new(hermes::diskio::DiskProfile::custom(250_000, 0, 200));
    let mut c_slow = ctx(&runtime, &paths, "tiny-bert");
    c_slow.disk = slow;
    let (_, pl) = run_pipeline(&c_slow, &PipelineOpts::pipeload(1), None, &input).unwrap();
    let c = ctx(&runtime, &paths, "tiny-bert");
    let (_, ps) = run_pipeline(&c, &PipelineOpts::pipeswitch(), None, &input).unwrap();
    // standard pipeline keeps everything resident
    assert!(ps.peak_bytes >= profile.total_weight_bytes, "ps peak {}", ps.peak_bytes);
    // PIPELOAD holds only a few layers
    assert!(
        (pl.peak_bytes as f64) < 0.8 * ps.peak_bytes as f64,
        "pipeload peak {} not below pipeswitch {}",
        pl.peak_bytes,
        ps.peak_bytes
    );
}

#[test]
fn signal_protocol_comp_before_dest_and_complete() {
    let (paths, runtime) = setup("tiny-gpt");
    let profile = runtime.profile("tiny-gpt").unwrap();
    let c = ctx(&runtime, &paths, "tiny-gpt");
    let (input, _, _) = make_input(profile, 1, 2);
    let (_, _) = run_pipeline(&c, &PipelineOpts::pipeload(3), None, &input).unwrap();
    let log = c.signals;
    log.verify_dest_after_comp().unwrap();
    // every stage got exactly one Comp and one Dest
    let mut comp = log.comp_order();
    comp.sort_unstable();
    assert_eq!(comp, (0..profile.stages.len()).collect::<Vec<_>>());
    let dest = log.dest_order();
    // Dest is emitted by the in-order inference agent: strictly ascending
    assert_eq!(dest, (0..profile.stages.len()).collect::<Vec<_>>());
    assert!(log.snapshot().iter().any(|(_, s)| matches!(s, Signal::Done)));
}

#[test]
fn tight_budget_fires_stop_signals_but_completes() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let c = ctx(&runtime, &paths, "tiny-bert");
    let (input, _, _) = make_input(profile, 1, 3);
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    // room for barely 2 stages: agents must repeatedly pause
    let budget = 2 * max_stage + max_stage / 2;
    let (_, stats) =
        run_pipeline(&c, &PipelineOpts::pipeload(4), Some(budget), &input).unwrap();
    assert!(c.signals.stop_count() > 0, "expected S^stop under tight budget");
    assert!(stats.peak_bytes <= budget + 2 * max_stage);
}

#[test]
fn trace_records_all_lanes() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let mut c = ctx(&runtime, &paths, "tiny-bert");
    c.tracer = Tracer::new(true);
    let (input, _, _) = make_input(profile, 1, 4);
    run_pipeline(&c, &PipelineOpts::pipeload(2), None, &input).unwrap();
    let spans = c.tracer.snapshot();
    use hermes::trace::{Kind, Lane};
    assert!(spans.iter().any(|s| matches!(s.lane, Lane::Loader(_)) && s.kind == Kind::Load));
    assert!(spans.iter().any(|s| s.lane == Lane::Inference && s.kind == Kind::Compute));
    assert!(spans.iter().any(|s| s.lane == Lane::Daemon && s.kind == Kind::Destroy));
    let gantt = c.tracer.ascii_gantt(60);
    assert!(gantt.contains("LA1") && gantt.contains("IA") && gantt.contains("DA"));
}

#[test]
fn corrupted_shard_fails_cleanly_with_validation() {
    let (paths, runtime) = setup("tiny-vit");
    let profile = runtime.profile("tiny-vit").unwrap();
    // copy shards to a scratch dir and corrupt one
    let src = paths.weights.join("tiny-vit");
    let dst = std::env::temp_dir().join("hermes_corrupt_test");
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(dst.join("tiny-vit")).unwrap();
    for s in &profile.stages {
        std::fs::copy(src.join(&s.shard), dst.join("tiny-vit").join(&s.shard)).unwrap();
    }
    let victim = dst.join("tiny-vit").join(&profile.stages[2].shard);
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, bytes).unwrap();

    let mut c = ExecCtx::new(&runtime, "tiny-vit", &dst, Disk::preset("unthrottled").unwrap()).unwrap();
    c.batch = 1;
    let (input, _, _) = make_input(profile, 1, 5);
    let mut opts = PipelineOpts::pipeload(2);
    opts.validate_shards = true;
    let err = match run_pipeline(&c, &opts, None, &input) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected corruption error"),
    };
    assert!(err.contains("checksum"), "{err}");
}

#[test]
fn missing_shard_file_errors() {
    let (paths, runtime) = setup("tiny-gptj");
    let profile = runtime.profile("tiny-gptj").unwrap();
    let dst = std::env::temp_dir().join("hermes_missing_test");
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(dst.join("tiny-gptj")).unwrap(); // empty dir
    let c = ExecCtx::new(&runtime, "tiny-gptj", &dst, Disk::preset("unthrottled").unwrap()).unwrap();
    let (input, _, _) = make_input(profile, 1, 6);
    assert!(run_pipeline(&c, &PipelineOpts::pipeload(2), None, &input).is_err());
    let _ = paths;
}

#[test]
fn oversized_single_layer_budget_rejected() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let c = ctx(&runtime, &paths, "tiny-bert");
    let (input, _, _) = make_input(profile, 1, 7);
    // budget below the biggest single stage can never work
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    let err = match run_pipeline(&c, &PipelineOpts::pipeload(2), Some(max_stage - 1), &input) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected budget error"),
    };
    assert!(err.contains("can never fit"), "{err}");
}

#[test]
fn pipeswitch_under_model_size_budget_rejected() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let c = ctx(&runtime, &paths, "tiny-bert");
    let (input, _, _) = make_input(profile, 1, 8);
    let err = match run_pipeline(
        &c,
        &PipelineOpts::pipeswitch(),
        Some(profile.total_weight_bytes / 2),
        &input,
    ) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected pipeswitch budget error"),
    };
    assert!(err.contains("keeps all weights resident"), "{err}");
}

#[test]
fn more_agents_reduce_wait_stalls_on_throttled_disk() {
    let (paths, runtime) = setup("tiny-bert");
    let profile = runtime.profile("tiny-bert").unwrap();
    let (input, _, _) = make_input(profile, 1, 9);
    let run = |agents: usize| {
        let mut c = ExecCtx::new(&runtime, "tiny-bert", &paths.weights,
            Disk::new(hermes::diskio::DiskProfile::custom(2_000_000, 0, 500))).unwrap();
        c.tracer = Tracer::disabled();
        let t0 = std::time::Instant::now();
        run_pipeline(&c, &PipelineOpts::pipeload(agents), None, &input).unwrap();
        t0.elapsed().as_secs_f64()
    };
    let t1 = run(1);
    let t4 = run(4);
    assert!(
        t4 < t1 * 0.75,
        "4 agents ({t4:.3}s) should be well below 1 agent ({t1:.3}s)"
    );
}
