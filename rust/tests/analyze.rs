//! Trace-analytics integration tests (PR 9): the offline analyzer's
//! numbers must *reconcile* with the counters the engine and router
//! already report (the breakdown is derived from the same spans, not a
//! second opinion), the per-pass critical-path attribution must total
//! exactly, the memory-attribution audit must balance to ZERO drift with
//! every memory owner active, a truncated trace must fail loudly, and
//! the live `DerivedSignals` / `{"op":"health"}` surface must work over
//! a real serve.  Needs `make artifacts`.

use std::net::TcpStream;
use std::time::Duration;

use hermes::analyze::{Analysis, DerivedSignals, DEFAULT_WINDOW};
use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;
use hermes::server::tcp::roundtrip;
use hermes::server::{InferRequest, Router, RouterConfig, TcpFrontend};
use hermes::telemetry::{Phase, Telemetry};
use hermes::util::json::Value;

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn close(trace_ms: f64, report_ms: f64, what: &str) {
    let tol = 0.15 * trace_ms.max(report_ms) + 10.0;
    assert!(
        (trace_ms - report_ms).abs() <= tol,
        "{what}: analyzer says {trace_ms:.2} ms, report says {report_ms:.2} ms (tol {tol:.2})"
    );
}

/// A generative continuous KV lane for the router tests.
fn kv_lane(model: &str) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(4),
        continuous: true,
        max_active: Some(1),
        ..RunConfig::default()
    }
}

/// The analyzer's whole-trace totals must reconcile with the RunReport
/// stall counters on a run engineered to produce both stall kinds, and
/// every reconstructed pass must obey the critical-path identity.
#[test]
fn analyzer_totals_reconcile_with_run_report() {
    let e = engine();
    let profile = e.runtime.profile("tiny-bert").unwrap();
    let max_stage = profile.max_stage_bytes();
    let cfg = RunConfig {
        profile: "tiny-bert".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        // two loaders against a two-stage window: the loader ahead blocks
        // on the gate (mem stalls) while the throttled disk starves the
        // inference agent (wait stalls)
        budget: Some(2 * max_stage),
        disk: "edge-sd".into(),
        ..RunConfig::default()
    };
    let telemetry = Telemetry::on();
    let mut session = e.open_session(&cfg).unwrap();
    session.set_telemetry(telemetry.clone());
    let (rep, _) = session.run().unwrap();
    drop(session);

    let analysis = Analysis::from_bus(&telemetry.drain(), telemetry.dropped());
    assert!(analysis.ok(), "clean run must analyze clean: {:?}", analysis.errors);
    assert!(rep.wait_stall_ms > 0.0 && rep.mem_stall_ms > 0.0);
    close(analysis.totals.stall_wait_ms, rep.wait_stall_ms, "wait stalls");
    close(analysis.totals.stall_mem_ms, rep.mem_stall_ms, "mem stalls");
    let pass_wall: f64 = analysis.passes.iter().map(|p| p.dur_ms).sum();
    close(pass_wall, rep.latency_ms, "pass wall vs end-to-end latency");

    assert!(!analysis.passes.is_empty(), "the run's pass must be reconstructed");
    for p in &analysis.passes {
        // the attribution is a partition of the pass window: compute +
        // bubble + residual == duration, exactly, and the per-stage
        // bubble split totals the pass bubble
        assert!(
            (p.compute_ms + p.bubble_ms + p.residual_ms - p.dur_ms).abs() < 1e-6,
            "pass {} lane {}: {:.3} + {:.3} + {:.3} != {:.3}",
            p.pass, p.lane, p.compute_ms, p.bubble_ms, p.residual_ms, p.dur_ms
        );
        let stage_sum: f64 = p.bubble_by_stage.values().sum();
        assert!(
            (stage_sum - p.bubble_ms).abs() < 1e-6,
            "pass {}: stage bubbles {:.3} != pass bubble {:.3}",
            p.pass, stage_sum, p.bubble_ms
        );
        assert!(p.residual_ms >= -1e-9, "residual can never be negative");
    }
    // whole-trace stage attribution is the sum of the per-pass splits
    let by_stage: f64 = analysis.bubble_by_stage.values().sum();
    assert!((by_stage - analysis.bubble_total_ms()).abs() < 1e-6);
    // pass-mode single session owns its accountant: audits were emitted
    // at settled pass starts and must balance exactly
    assert!(analysis.audit.samples > 0, "owned-accountant run must emit audits");
    assert_eq!(analysis.audit.max_drift_bytes, 0);
}

/// A real two-lane continuous serve on the serialized router: request
/// breakdowns must reconcile with the RouterSummary queue-wait
/// percentiles, lifecycles must be complete (shed included), and the
/// between-batches global memory audit must balance to zero drift.
#[test]
fn two_lane_continuous_router_reconciles_and_audits_clean() {
    let e = engine();
    let cfg = RouterConfig {
        models: vec![kv_lane("tiny-gpt"), kv_lane("tiny-gptj")],
        kv_budget: Some(1 << 20),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let telemetry = Telemetry::on();
    let mut router = Router::new(&e, cfg).unwrap();
    router.set_telemetry(telemetry.clone());
    let handle = router.handle();
    let producer = std::thread::spawn(move || {
        let mut tickets = Vec::new();
        for i in 0..2u64 {
            for profile in ["tiny-gpt", "tiny-gptj"] {
                tickets.push(
                    handle
                        .submit(InferRequest {
                            profile: profile.into(),
                            seed: Some(700 + i),
                            ..InferRequest::default()
                        })
                        .unwrap(),
                );
            }
        }
        // one engineered shed: the SLO is already blown when the single
        // active slot frees, so admission control drops it with a reason
        tickets.push(
            handle
                .submit(InferRequest {
                    profile: "tiny-gpt".into(),
                    seed: Some(999),
                    slo_ms: Some(0.001),
                    ..InferRequest::default()
                })
                .unwrap(),
        );
        for t in tickets {
            let _ = t.wait();
        }
        handle.shutdown();
    });
    let summary = router.run().unwrap();
    producer.join().unwrap();

    let analysis = Analysis::from_bus(&telemetry.drain(), telemetry.dropped());
    assert!(analysis.ok(), "clean serve must analyze clean: {:?}", analysis.errors);
    assert_eq!(analysis.served(), summary.served, "{:?}", summary.first_error);
    assert_eq!(analysis.shed(), summary.shed_overload as usize);
    assert!(analysis.decode_steps > 0, "continuous lanes decode token by token");

    // queue-wait percentiles come from the same enqueue->admit intervals
    // the router times itself
    close(analysis.queue_wait.p50(), summary.queue_wait_p50_ms, "queue wait p50");
    close(analysis.queue_wait.p95(), summary.queue_wait_p95_ms, "queue wait p95");

    // per-pass bubble attribution totals the pass critical path across
    // both lanes
    assert!(!analysis.passes.is_empty());
    for p in &analysis.passes {
        assert!((p.compute_ms + p.bubble_ms + p.residual_ms - p.dur_ms).abs() < 1e-6);
        let stage_sum: f64 = p.bubble_by_stage.values().sum();
        assert!((stage_sum - p.bubble_ms).abs() < 1e-6);
    }
    assert!(analysis.passes.iter().any(|p| p.lane == 0));
    assert!(analysis.passes.iter().any(|p| p.lane == 1));

    // the serialized router quiesces BOTH lanes between batches and
    // samples the shared accountant: every sample must balance exactly
    assert!(analysis.audit.samples > 0, "router must emit between-batch audits");
    assert_eq!(analysis.audit.max_drift_bytes, 0, "memory attribution must balance");
    assert!(analysis.audit.settled_used_max <= analysis.audit.high_water_max);
}

/// Zero audit drift with every memory owner active at once: hot-layer
/// pins, the device-resident cache, cross-pass prefetch, and the paged
/// KV pool all charge the same accountant the components are summed
/// against.
#[test]
fn memory_audit_balances_with_all_owners_active() {
    let e = engine();
    let total = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let cfg = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        budget: Some(4 * total),
        pin_budget: Some(total),
        prefetch_depth: 2,
        device_cache: true,
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(4),
        ..RunConfig::default()
    };
    let telemetry = Telemetry::on();
    let mut session = e.open_session(&cfg).unwrap();
    session.set_telemetry(telemetry.clone());
    let (rep, _) = session.run().unwrap();
    drop(session);

    assert!(rep.tokens > 0);
    let analysis = Analysis::from_bus(&telemetry.drain(), telemetry.dropped());
    assert!(analysis.ok(), "{:?}", analysis.errors);
    assert!(
        analysis.audit.samples >= 2,
        "settled audits across the decode passes ({} samples, {} tokens)",
        analysis.audit.samples,
        rep.tokens
    );
    assert_eq!(
        analysis.audit.max_drift_bytes, 0,
        "pins + device + prefetch + KV + live must sum to the accountant"
    );
    assert!(analysis.audit.settled_used_max > 0, "the owners were actually charged");
}

/// A deliberately truncated trace must fail loudly, never silently
/// produce a plausible-looking breakdown — and dropped events alone
/// already disqualify a trace.
#[test]
fn truncated_trace_fails_loudly() {
    let e = engine();
    let cfg = RunConfig {
        profile: "tiny-gpt".into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(4),
        ..RunConfig::default()
    };
    let telemetry = Telemetry::on();
    let mut session = e.open_session(&cfg).unwrap();
    session.set_telemetry(telemetry.clone());
    session.run().unwrap();
    drop(session);
    let events = telemetry.drain();

    // the full trace is clean ...
    assert!(Analysis::from_bus(&events, 0).ok());

    // ... the same trace cut right after a pass opens is not: the open
    // span is reported as truncation, with the cut visible in errors
    let cut = events
        .iter()
        .position(|ev| ev.name == "pass" && ev.phase == Phase::Begin)
        .expect("the decode emits pass spans");
    let truncated = Analysis::from_bus(&events[..=cut], 0);
    assert!(!truncated.ok());
    assert!(
        truncated.errors.iter().any(|e| e.contains("never closed")),
        "must call out the unclosed span: {:?}",
        truncated.errors
    );

    // ... and a trace that admits to dropped events is incomplete by
    // definition, whatever else it contains
    let dropped = Analysis::from_bus(&events, 3);
    assert!(!dropped.ok());
    assert!(
        dropped.errors.iter().any(|e| e.contains("incomplete")),
        "{:?}",
        dropped.errors
    );
}

/// The live surface: `DerivedSignals` fed by an in-process subscription
/// during a real serve, and the same aggregate over `{"op":"health"}` on
/// the TCP front-end, with drop counters in `stats` and the derived
/// gauges in `metrics`.
#[test]
fn health_op_reports_live_derived_signals() {
    let e = engine();
    let cfg = RouterConfig {
        models: vec![RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 2,
            disk: "unthrottled".into(),
            ..RunConfig::default()
        }],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let telemetry = Telemetry::on();
    // an independent in-process consumer alongside the TCP one: this is
    // the controller hook — same bus, its own bounded ring
    let own = DerivedSignals::attach(&telemetry, DEFAULT_WINDOW);
    let mut frontend = TcpFrontend::bind("127.0.0.1:0").unwrap();
    frontend.set_telemetry(telemetry.clone());
    let addr = frontend.local_addr().unwrap();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).unwrap();
        let reply = roundtrip(&mut stream, &InferRequest::new("tiny-bert").to_json()).unwrap();
        assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");

        let health =
            roundtrip(&mut stream, &Value::parse(r#"{"op":"health"}"#).unwrap()).unwrap();
        assert!(health.get("ok").unwrap().as_bool().unwrap(), "{health}");
        assert_eq!(health.get("op").unwrap().as_str().unwrap(), "health");
        assert!(health.get("enabled").unwrap().as_bool().unwrap());
        let lanes = health.get("lanes").unwrap().as_arr().unwrap();
        assert!(!lanes.is_empty(), "a served request leaves lane time in the window");
        let l0 = &lanes[0];
        assert!(l0.get("compute_ms").unwrap().as_f64().unwrap() > 0.0, "{health}");
        assert!(l0.get("stall_mem_ratio").is_some() && l0.get("stall_wait_ratio").is_some());
        assert!(health.get("high_water_slope_bps").is_some());
        assert!(health.get("events_seen").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(health.get("bus_dropped").unwrap().as_f64().unwrap(), 0.0);

        let stats = roundtrip(&mut stream, &Value::parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
        assert_eq!(stats.get("telemetry_dropped_events").unwrap().as_f64().unwrap(), 0.0);
        let subs = stats.get("subscriber_drops").unwrap();
        assert!(
            subs.get("derived-signals").is_some(),
            "the health aggregator's ring must be accounted: {stats}"
        );

        let metrics =
            roundtrip(&mut stream, &Value::parse(r#"{"op":"metrics"}"#).unwrap()).unwrap();
        let text = metrics.get("text").unwrap().as_str().unwrap().to_string();
        assert!(text.contains("hermes_lane_stall_ratio"), "{text}");
        assert!(text.contains("hermes_shed_rate"));
        assert!(text.contains("hermes_high_water_slope_bps"));
        assert!(text.contains("hermes_health_subscriber_dropped_total"));
        assert!(text.contains("hermes_subscriber_dropped_events_total"));

        let reply =
            roundtrip(&mut stream, &Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "shutdown");
    });

    let summary = frontend.run(&e, cfg).unwrap();
    client.join().unwrap();
    assert_eq!(summary.served, 1, "{:?}", summary.first_error);

    // the independent subscriber saw the same run, without ever stalling it
    let snap = own.poll();
    assert!(snap.enabled);
    assert!(snap.events_seen > 0);
    assert_eq!(snap.subscriber_dropped, 0);
    assert!(!snap.lanes.is_empty());
}
