//! Property-based tests over the coordinator invariants (DESIGN.md section 7),
//! driven by the in-repo prop framework (`hermes::util::prop`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use hermes::kvcache::{KvPool, KvSeq};
use hermes::memory::MemoryAccountant;
use hermes::model::DType;
use hermes::pipeload::assignment::{assignment, owner};
use hermes::pipeload::gate::OrderedGate;
use hermes::planner::{candidate_agents, predict_latency_ms, predict_peak_bytes};
use hermes::profiler::{LayerProfile, ModelProfile};
use hermes::prop_assert;
use hermes::util::json::Value;
use hermes::util::prop::{check, Config};
use hermes::util::rng::Rng;
use hermes::weights::{decode, encode, Shard, Tensor};

fn cfg(cases: usize) -> Config {
    Config { cases, ..Config::default() }
}

#[test]
fn prop_assignment_is_partition() {
    check("assignment partition", cfg(128), |g| {
        let stages = g.usize(1, 200);
        let agents = g.usize(1, 40);
        let plan = assignment(stages, agents);
        let mut seen = vec![0u32; stages];
        for (a, list) in plan.iter().enumerate() {
            prop_assert!(
                list.windows(2).all(|w| w[0] < w[1]),
                "agent {a} list not ascending: {list:?}"
            );
            for &s in list {
                prop_assert!(s < stages, "stage {s} out of range");
                prop_assert!(owner(s, agents) == a, "owner mismatch for {s}");
                seen[s] += 1;
            }
        }
        prop_assert!(seen.iter().all(|&c| c == 1), "not a partition: {seen:?}");
        Ok(())
    });
}

#[test]
fn prop_ordered_gate_admits_sequentially_and_never_exceeds_budget() {
    check("gate order+budget", cfg(24), |g| {
        let n_stages = g.usize(2, 24);
        let agents = g.usize(1, 5);
        let stage_bytes: Vec<u64> = (0..n_stages).map(|_| g.u64(1, 50)).collect();
        let max = *stage_bytes.iter().max().unwrap();
        let budget = max + g.u64(0, 2 * max + 1);
        let accountant = MemoryAccountant::new(Some(budget));
        let gate = OrderedGate::new(accountant.clone());
        let admitted = Arc::new(AtomicU64::new(0));
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));

        let plan = assignment(n_stages, agents);
        std::thread::scope(|scope| {
            // consumer: free in strict stage order as "computed"
            let consumer_gate = gate.clone();
            let (tx, rx) = std::sync::mpsc::channel::<(usize, u64)>();
            scope.spawn(move || {
                let mut next = 0usize;
                let mut pending = std::collections::BTreeMap::new();
                while next < n_stages {
                    let (s, b) = rx.recv().unwrap();
                    pending.insert(s, b);
                    while let Some(b) = pending.remove(&next) {
                        consumer_gate.free(b);
                        next += 1;
                    }
                }
            });
            for (_a, list) in plan.iter().enumerate() {
                let gate = gate.clone();
                let tx = tx.clone();
                let order = order.clone();
                let admitted = admitted.clone();
                let bytes = stage_bytes.clone();
                let list = list.clone();
                scope.spawn(move || {
                    for s in list {
                        gate.admit(s, bytes[s]).unwrap();
                        order.lock().unwrap().push(s);
                        admitted.fetch_add(1, Ordering::SeqCst);
                        tx.send((s, bytes[s])).unwrap();
                    }
                });
            }
            drop(tx);
        });
        // NOTE: the gate admits strictly in stage order internally, but the
        // log push below races with other threads' admissions, so only the
        // per-agent subsequences are reliably ordered observations.
        let order = order.lock().unwrap();
        for (a, list) in plan.iter().enumerate() {
            let mine: Vec<usize> =
                order.iter().copied().filter(|s| list.contains(s)).collect();
            prop_assert!(
                mine.windows(2).all(|w| w[0] < w[1]),
                "agent {a} admissions out of order: {mine:?}"
            );
        }
        prop_assert!(
            admitted.load(Ordering::SeqCst) == n_stages as u64,
            "not all stages admitted"
        );
        prop_assert!(accountant.used() == 0, "leak: {} bytes", accountant.used());
        prop_assert!(accountant.peak() <= budget, "peak {} > budget {budget}", accountant.peak());
        Ok(())
    });
}

#[test]
fn prop_accountant_never_exceeds_budget_under_try_acquire() {
    check("accountant budget", cfg(64), |g| {
        let budget = g.u64(10, 1000);
        let m = MemoryAccountant::new(Some(budget));
        let mut held: Vec<u64> = Vec::new();
        for _ in 0..g.usize(1, 100) {
            if g.bool() || held.is_empty() {
                let want = g.u64(1, budget + 10);
                if m.try_acquire(want) {
                    held.push(want);
                }
            } else {
                let i = g.usize(0, held.len());
                m.free(held.swap_remove(i));
            }
            prop_assert!(m.used() <= budget, "used {} > budget {budget}", m.used());
            prop_assert!(m.peak() <= budget, "peak {} > budget {budget}", m.peak());
        }
        Ok(())
    });
}

#[test]
fn prop_shared_budget_holds_under_concurrent_ledgers_and_resizes() {
    // PR 6 invariant: with per-pass ledgers, durable-store transfers (pins /
    // KV / device / prefetch all account this way), and elastic resizes all
    // interleaving across lanes, admitted usage never exceeds the largest
    // budget ever granted, and draining every holder returns usage to
    // exactly zero — no leak, no double-free, under any schedule.
    check("concurrent ledger budget", cfg(16), |g| {
        let base = g.u64(200, 2000);
        let max_budget = 2 * base; // resize never grants more than this
        let m = MemoryAccountant::new(Some(base));
        let lanes = g.usize(2, 5);
        let steps = g.usize(20, 80);
        let seed0 = g.u64(0, u64::MAX - 1);
        std::thread::scope(|scope| {
            // elastic controller: random shrink/grow while lanes charge
            let ctl = m.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(seed0);
                for _ in 0..steps {
                    ctl.resize(Some(rng.range(base / 4 + 1, max_budget)));
                    std::thread::yield_now();
                }
                ctl.resize(Some(base));
            });
            for lane in 0..lanes {
                let ledger = m.pass_ledger();
                let store = m.clone(); // the durable-store side of transfers
                scope.spawn(move || {
                    let mut rng = Rng::new(
                        seed0 ^ (lane as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut stored = 0u64; // bytes parked in the durable store
                    for _ in 0..steps {
                        match rng.usize(0, 5) {
                            0 => {
                                let _ = ledger.try_acquire(rng.range(1, base / 2));
                            }
                            1 => {
                                let b = ledger.balance();
                                if b > 0 {
                                    ledger.free(rng.range(1, b + 1));
                                }
                            }
                            2 => {
                                // pin/prefetch park: still accounted, no
                                // longer this pass's bytes to drain
                                let b = ledger.balance();
                                if b > 0 {
                                    let take = rng.range(1, b + 1);
                                    ledger.release(take);
                                    stored += take;
                                }
                            }
                            3 => {
                                // cache-hit adoption: store hands bytes back
                                if stored > 0 {
                                    let take = rng.range(1, stored + 1);
                                    ledger.adopt(take);
                                    stored -= take;
                                }
                            }
                            _ => {
                                let _ = ledger.drain(); // failed-pass recovery
                            }
                        }
                        std::thread::yield_now();
                    }
                    // teardown: the store evicts, then the pass drains
                    if stored > 0 {
                        store.free(stored);
                    }
                    ledger.drain();
                });
            }
        });
        prop_assert!(m.used() == 0, "leak after full drain: {} bytes", m.used());
        prop_assert!(
            m.peak() <= max_budget,
            "peak {} above the largest budget ever granted {max_budget}",
            m.peak()
        );
        prop_assert!(m.over_budget_bytes() == 0, "settled run still over budget");
        Ok(())
    });
}

#[test]
fn prop_shared_kv_blocks_never_double_free_and_drain_to_zero() {
    // PR 7 invariant: content-hashed, refcounted KV blocks under concurrent
    // open / extend / fork / close interleaved with elastic budget resizes
    // must (a) never double-free — the pool's internal `used` counter would
    // underflow-panic if any byte were returned twice, (b) release every
    // block reference exactly once as handles drop, and (c) drain both the
    // pool and the shared accountant to exactly zero bytes.
    check("shared kv blocks drain", cfg(12), |g| {
        let layers = g.usize(1, 3);
        let hidden = g.usize(2, 6);
        let block_tokens = g.usize(2, 5);
        let block_bytes = (block_tokens * hidden * 4 * 2) as u64;
        let budget = block_bytes * layers as u64 * g.u64(6, 25);
        let m = MemoryAccountant::new(None);
        let pool = KvPool::with_block_tokens(m.clone(), Some(budget), block_tokens);
        let lanes = g.usize(2, 4);
        let steps = g.usize(12, 48);
        let seed0 = g.u64(0, u64::MAX - 1);
        std::thread::scope(|scope| {
            // elastic controller: shrink/grow the pool cap while lanes run;
            // a shrink evicts whole sequences (their owners degrade to
            // recompute and must still release cleanly)
            let rp = pool.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(seed0);
                for _ in 0..steps {
                    rp.set_kv_budget(Some(rng.range(block_bytes, budget + 1)));
                    std::thread::yield_now();
                }
                rp.set_kv_budget(Some(budget));
            });
            for lane in 0..lanes {
                let pool = pool.clone();
                scope.spawn(move || {
                    let mut rng = Rng::new(
                        seed0 ^ (lane as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut seqs: Vec<KvSeq> = Vec::new();
                    for _ in 0..steps {
                        match rng.usize(0, 5) {
                            0 => seqs.push(pool.open_seq(layers, 1, hidden)),
                            1 => {
                                // extend + prime with content derived only
                                // from (layer, position): identical across
                                // lanes, so sealing triggers cross-lane dedup
                                if let Some(q) = seqs.last() {
                                    let want =
                                        q.tokens() + rng.usize(1, 2 * block_tokens + 1);
                                    if q.reserve(want) {
                                        for l in 0..layers {
                                            let buf: Vec<f32> = (0..want * hidden)
                                                .map(|i| (l * 10_000 + i) as f32)
                                                .collect();
                                            q.write_prefix(l, want, &buf, &buf);
                                        }
                                        q.set_tokens(want);
                                    }
                                }
                            }
                            2 => {
                                // share: a child adopts the sealed prefix
                                if let Some(child) = seqs.last().and_then(|q| q.fork()) {
                                    seqs.push(child);
                                }
                            }
                            3 => {
                                // diverge: write into the shared region (COW)
                                if let Some(q) = seqs.last() {
                                    if q.valid() && q.tokens() > 0 {
                                        let pos = rng.usize(0, q.tokens());
                                        let row = vec![(lane + 1) as f32; hidden];
                                        q.write_token(0, pos, &row, &row);
                                    }
                                }
                            }
                            _ => {
                                // close: sometimes invalidate first (early
                                // strip), then drop the handle either way
                                if !seqs.is_empty() {
                                    let i = rng.usize(0, seqs.len());
                                    let q = seqs.swap_remove(i);
                                    if rng.bool() {
                                        q.invalidate();
                                    }
                                }
                            }
                        }
                        std::thread::yield_now();
                    }
                    // remaining handles drop here: every ref must release
                });
            }
        });
        let st = pool.stats();
        prop_assert!(pool.used_bytes() == 0, "pool leak: {} bytes", pool.used_bytes());
        prop_assert!(m.used() == 0, "accountant leak: {} bytes", m.used());
        prop_assert!(st.sequences == 0, "sequences still registered: {}", st.sequences);
        prop_assert!(st.pool_blocks == 0, "blocks still held: {}", st.pool_blocks);
        prop_assert!(st.shared_blocks == 0, "shared refs not drained: {}", st.shared_blocks);
        Ok(())
    });
}

#[test]
fn prop_shard_roundtrip_random_tensors() {
    check("shard roundtrip", cfg(64), |g| {
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let n = g.usize(0, 8);
        let tensors: Vec<Tensor> = (0..n)
            .map(|i| {
                let ndim = rng.usize(1, 4);
                let shape: Vec<usize> = (0..ndim).map(|_| rng.usize(1, 6)).collect();
                let dtype = [DType::F32, DType::I32, DType::F16][rng.usize(0, 3)];
                let bytes: usize = shape.iter().product::<usize>() * dtype.size_bytes();
                Tensor {
                    name: format!("t{i}"),
                    dtype,
                    shape,
                    data: (0..bytes).map(|_| rng.next_u64() as u8).collect(),
                }
            })
            .collect();
        let shard = Shard { kind: "k".into(), stage: rng.next_u64() as u32, tensors };
        let rt = decode(&encode(&shard)).map_err(|e| e.to_string())?;
        prop_assert!(rt == shard, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_shard_bitflip_always_detected() {
    check("shard corruption", cfg(48), |g| {
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let shard = Shard {
            kind: "encoder_layer".into(),
            stage: 1,
            tensors: vec![Tensor {
                name: "w".into(),
                dtype: DType::F32,
                shape: vec![g.usize(1, 32)],
                data: (0..g.usize(1, 32) * 4).map(|_| rng.next_u64() as u8).collect(),
            }],
        };
        // note: shape and data len must agree; rebuild data to match
        let n = shard.tensors[0].shape[0] * 4;
        let mut shard = shard;
        shard.tensors[0].data = (0..n).map(|_| rng.next_u64() as u8).collect();
        let mut bytes = encode(&shard);
        let pos = rng.usize(0, bytes.len());
        let bit = 1u8 << rng.usize(0, 8);
        bytes[pos] ^= bit;
        prop_assert!(decode(&bytes).is_err(), "bit flip at {pos} undetected");
        Ok(())
    });
}

#[test]
fn prop_planner_latency_monotone_and_peak_linear() {
    check("planner models", cfg(128), |g| {
        let load = g.f64() * 100.0 + 0.1;
        let compute = g.f64() * 20.0 + 0.01;
        let n = g.usize(1, 64);
        let mut prev = f64::INFINITY;
        for m in 1..=12 {
            let t = predict_latency_ms(load, compute, n, m);
            prop_assert!(t <= prev + 1e-9, "latency not monotone at m={m}");
            prop_assert!(t >= load + n as f64 * compute - 1e-9, "below compute bound");
            prev = t;
        }
        let max_stage = g.u64(1, 1_000_000);
        let body = g.u64(1, max_stage + 1);
        let act = g.u64(0, max_stage);
        for m in 1..8 {
            let d = predict_peak_bytes(max_stage, body, act, m + 1)
                - predict_peak_bytes(max_stage, body, act, m);
            prop_assert!(d == body, "peak not linear in agents");
        }
        Ok(())
    });
}

#[test]
fn prop_candidate_agents_monotone_in_budget() {
    check("candidates monotone", cfg(64), |g| {
        let bytes = g.u64(100, 10_000);
        let layers: Vec<LayerProfile> = (0..g.usize(1, 30))
            .map(|i| LayerProfile {
                stage: i,
                kind: "encoder_layer".into(),
                load_ms: 1.0,
                compute_ms: 0.1,
                bytes,
            })
            .collect();
        let mp = ModelProfile { profile: "p".into(), disk: "d".into(), batch: 1, layers };
        let mut prev_len = 0;
        for mult in 1..8u64 {
            let c = candidate_agents(&mp, "encoder_layer", bytes * (2 + mult), 10);
            prop_assert!(c.len() >= prev_len, "candidates shrank with budget");
            // contiguous from 1
            prop_assert!(
                c.iter().enumerate().all(|(i, &m)| m == i + 1),
                "candidates not contiguous: {c:?}"
            );
            prev_len = c.len();
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.usize(0, 4) } else { rng.usize(0, 6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bool()),
            2 => Value::Num((rng.next_u64() % 1_000_000) as f64 / 8.0),
            3 => Value::Str(
                (0..rng.usize(0, 12))
                    .map(|_| char::from_u32(0x20 + rng.next_u64() as u32 % 0x50).unwrap())
                    .collect(),
            ),
            4 => Value::Arr((0..rng.usize(0, 4)).map(|_| gen_value(rng, depth.saturating_sub(1))).collect()),
            _ => Value::Obj(
                (0..rng.usize(0, 4))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth.saturating_sub(1))))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", cfg(200), |g| {
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let v = gen_value(&mut rng, 4);
        let compact = Value::parse(&v.compact()).map_err(|e| e.to_string())?;
        prop_assert!(compact == v, "compact roundtrip mismatch:\n{v}\n{compact}");
        let pretty = Value::parse(&v.pretty()).map_err(|e| e.to_string())?;
        prop_assert!(pretty == v, "pretty roundtrip mismatch");
        Ok(())
    });
}
