//! Integration tests for the PJRT runtime layer: manifest -> compile ->
//! execute, shapes, caching, batching.  Needs `make artifacts`.

use hermes::config::Paths;
use hermes::engine::{make_input, WEIGHTS_SEED};
use hermes::pipeload::ModelInput;
use hermes::runtime::Runtime;
use hermes::weights::gen::gen_profile_weights;
use hermes::weights::read_shard;

fn runtime() -> (Paths, Runtime) {
    let paths = Paths::detect();
    let rt = Runtime::new(&paths.artifacts).unwrap();
    (paths, rt)
}

#[test]
fn manifest_loads_all_expected_profiles() {
    let (_, rt) = runtime();
    for name in [
        "bert-large-sim",
        "gpt2-base-sim",
        "vit-large-sim",
        "gptj-sim",
        "bart-base-sim",
        "bart-large-sim",
        "tiny-bert",
        "tiny-gpt",
        "tiny-vit",
        "tiny-gptj",
    ] {
        let p = rt.profile(name).unwrap();
        assert!(!p.stages.is_empty(), "{name}");
        assert!(p.total_weight_bytes > 0);
        // every stage's kind has specs and an entry at batch 1
        for s in &p.stages {
            assert!(!p.stage_params(s).unwrap().is_empty(), "{name}/{}", s.kind);
            p.entry(&s.kind, 1).unwrap();
        }
    }
}

#[test]
fn paper_profiles_mirror_table1_structure() {
    let (_, rt) = runtime();
    let bert = rt.profile("bert-large-sim").unwrap();
    assert_eq!(bert.layers, 24);
    assert_eq!(bert.stages.len(), 26); // embedding + 24 + pooler
    let gptj = rt.profile("gptj-sim").unwrap();
    assert_eq!(gptj.layers, 28);
    assert_eq!(gptj.body_kind(), "gptj_layer");
    let vit = rt.profile("vit-large-sim").unwrap();
    assert_eq!(vit.stages[0].kind, "patch_embed");
    // Obs I: body layers dominate
    for name in ["bert-large-sim", "gpt2-base-sim", "vit-large-sim", "gptj-sim"] {
        let p = rt.profile(name).unwrap();
        let body: u64 = p
            .stages
            .iter()
            .filter(|s| s.kind == p.body_kind())
            .map(|s| p.stage_bytes(s))
            .sum();
        let share = body as f64 / p.total_weight_bytes as f64;
        assert!(share > 0.7, "{name} body share {share}");
    }
}

#[test]
fn executes_single_encoder_layer_with_expected_shapes() {
    let (paths, rt) = runtime();
    let p = rt.profile("tiny-bert").unwrap();
    gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false).unwrap();
    let stage = &p.stages[1];
    assert_eq!(stage.kind, "encoder_layer");
    let shard = read_shard(&paths.weights.join("tiny-bert").join(&stage.shard)).unwrap();
    let entry = p.entry("encoder_layer", 1).unwrap();
    let n_in: usize = entry.activations[0].num_elements();
    let x = rt.buffer_f32(&vec![0.1; n_in], &entry.activations[0].shape).unwrap();
    let out = rt.execute_entry(p, entry, &[&x], &shard).unwrap();
    let v = rt.buffer_to_f32(&out).unwrap();
    assert_eq!(v.len(), entry.output.num_elements());
    assert!(v.iter().all(|x| x.is_finite()));
}

#[test]
fn executable_cache_reuses_compiles() {
    let (_, rt) = runtime();
    let p = rt.profile("tiny-gpt").unwrap();
    let entry = p.entry("decoder_layer", 1).unwrap();
    let t0 = std::time::Instant::now();
    rt.executable(p, entry).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..50 {
        rt.executable(p, entry).unwrap();
    }
    let cached = t1.elapsed() / 50;
    assert!(cached < first / 10, "cache not effective: {cached:?} vs {first:?}");
}

#[test]
fn batch_variants_compile_and_run() {
    let (paths, rt) = runtime();
    let p = rt.profile("tiny-bert").unwrap();
    gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false).unwrap();
    for &b in &p.batches {
        let entry = p.entry("encoder_layer", b).unwrap();
        assert_eq!(entry.activations[0].shape[0], b);
        let shard =
            read_shard(&paths.weights.join("tiny-bert").join(&p.stages[1].shard)).unwrap();
        let n: usize = entry.activations[0].num_elements();
        let x = rt.buffer_f32(&vec![0.05; n], &entry.activations[0].shape).unwrap();
        let out = rt.execute_entry(p, entry, &[&x], &shard).unwrap();
        assert_eq!(rt.buffer_to_f32(&out).unwrap().len(), entry.output.num_elements());
    }
}

#[test]
fn batched_rows_with_identical_inputs_agree() {
    // batch-2 entry fed two identical rows must give two identical outputs
    let (paths, rt) = runtime();
    let p = rt.profile("tiny-bert").unwrap();
    gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false).unwrap();
    let (_, row, _) = make_input(p, 1, 11);
    let mut ids = row.clone();
    ids.extend_from_slice(&row); // duplicate the row across the batch
    let input = ModelInput::Ids(ids);
    let entry = p.entry("embedding", 2).unwrap();
    let shard = read_shard(&paths.weights.join("tiny-bert").join(&p.stages[0].shard)).unwrap();
    let l = input.to_buffer(&rt, &entry.activations[0]).unwrap();
    let out = rt.execute_entry(p, entry, &[&l], &shard).unwrap();
    let v = rt.buffer_to_f32(&out).unwrap();
    let half = v.len() / 2;
    assert_eq!(&v[..half], &v[half..], "batch rows diverged");
}

#[test]
fn wrong_activation_count_is_rejected() {
    let (paths, rt) = runtime();
    let p = rt.profile("tiny-bert").unwrap();
    gen_profile_weights(p, &paths.weights, WEIGHTS_SEED, 0.05, false).unwrap();
    let entry = p.entry("encoder_layer", 1).unwrap();
    let shard = read_shard(&paths.weights.join("tiny-bert").join(&p.stages[1].shard)).unwrap();
    let err = match rt.execute_entry(p, entry, &[], &shard) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected error"),
    };
    assert!(err.contains("expected 1 activation"), "{err}");
}

#[test]
fn prepare_compiles_everything_once() {
    let (_, rt) = runtime();
    let p = rt.profile("tiny-vit").unwrap();
    let n = rt.prepare(p).unwrap();
    assert_eq!(n, p.entries.len());
}
