//! Chaos soak for the fault-injection plane (PR 10): a deterministic
//! seeded fault plan drives disk errors, loader-agent panics, transient
//! accountant refusals, and lane deaths through a two-lane continuous
//! fleet, and the recovery plane (bounded retry, pass watchdog, lane
//! supervisor) must absorb all of it: successful requests stay
//! bit-identical to a fault-free run, the shared accountant drains to
//! exactly zero, and nothing deadlocks or aborts.  Also covers the
//! mid-decode deadline retirement and the TCP hardening satellites.
//! Needs `make artifacts`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;
use hermes::server::tcp::roundtrip;
use hermes::server::{
    ConcurrentRouter, InferRequest, InferResponse, Router, RouterConfig, RouterHandle,
    TcpFrontend,
};
use hermes::util::json::Value;

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

/// A continuous generative KV lane with the device-resident layer cache
/// OFF, so every pass streams its layers from disk and the disk-fault
/// seams (`disk_error`, `disk_slow`) stay hot for the whole run.
fn chaos_lane(model: &str) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(4),
        continuous: true,
        max_active: Some(2),
        device_cache: false,
        ..RunConfig::default()
    }
}

/// Submit `reqs` in order, wait out every ticket, then shut the router
/// down.  Responses come back in submission order.
fn drive(
    handle: RouterHandle,
    reqs: Vec<InferRequest>,
) -> std::thread::JoinHandle<Vec<InferResponse>> {
    std::thread::spawn(move || {
        let tickets: Vec<_> = reqs.into_iter().map(|r| handle.submit(r).unwrap()).collect();
        let responses: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        handle.shutdown();
        responses
    })
}

/// 12 alternating requests with explicit per-request seeds.  Explicit
/// seeds are what keeps the bit-identity contract honest under faults: a
/// crash-restart replays a requeued request from its own seed, not from a
/// lane-local batch counter that the requeue itself would have shifted.
fn soak_traffic() -> Vec<InferRequest> {
    (0..12u64)
        .map(|i| InferRequest {
            profile: if i % 2 == 0 { "tiny-gpt".into() } else { "tiny-gptj".into() },
            seed: Some(9000 + i),
            ..InferRequest::default()
        })
        .collect()
}

#[test]
fn chaos_soak_two_lane_continuous_survives_the_fault_plan() {
    // The PR 10 acceptance soak: one deterministic plan fires at least one
    // disk error (retried transparently), one loading-agent panic (costs
    // at most its pass), one transient accountant refusal (bounded retry),
    // and one lane-1 death (supervisor crash-restart).  The fleet must
    // finish every request one way or the other, keep every successful
    // request's tokens bit-identical to the fault-free baseline, and hand
    // back a shared accountant drained to exactly zero.
    let e = engine();
    let total_a = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gptj").unwrap().total_weight_bytes;
    let budget = 2 * (total_a + total_b);
    let mk_cfg = |plan: Option<&str>| RouterConfig {
        models: vec![chaos_lane("tiny-gpt"), chaos_lane("tiny-gptj")],
        budget: Some(budget),
        kv_budget: Some(1 << 20),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        fault_plan: plan.map(String::from),
        ..RouterConfig::default()
    };

    // fault-free baseline: the reference tokens
    let router = ConcurrentRouter::new(Paths::detect(), mk_cfg(None)).unwrap();
    let producer = drive(router.handle(), soak_traffic());
    let base = router.run().unwrap();
    let base_rows: Vec<Vec<Vec<i32>>> = producer
        .join()
        .unwrap()
        .into_iter()
        .map(|r| {
            assert!(r.ok, "baseline must be fault-free: {r:?}");
            r.generated_rows
        })
        .collect();
    assert_eq!(base.served, 12, "{:?}", base.first_error);
    assert_eq!(base.faults_injected, 0, "no plan, no faults");

    // chaos run: same traffic, same seeds, plus the fault plan
    let plan = "seed=42;disk_error@3;acquire_fail@4;lane_death@6:1;agent_panic@10";
    let router = ConcurrentRouter::new(Paths::detect(), mk_cfg(Some(plan))).unwrap();
    let acct = router.accountant().clone();
    let producer = drive(router.handle(), soak_traffic());
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    // every ticket resolved (no deadlock, no dropped reply channel)
    assert_eq!(responses.len(), 12);
    assert_eq!(summary.served + summary.rejected, 12, "{summary:?}");
    // the only non-transparent fault is the agent panic (one pass, at
    // most its requests); everything else self-heals
    assert!(summary.served >= 10, "{summary:?}");
    for (i, r) in responses.iter().enumerate() {
        if r.ok {
            assert_eq!(
                r.generated_rows, base_rows[i],
                "request {i} survived the chaos but its tokens drifted"
            );
        } else {
            assert!(r.error.is_some(), "rejection without a cause: {r:?}");
        }
    }

    // the plan fired end to end and the recovery counters saw it
    assert!(summary.faults_injected >= 4, "{summary:?}");
    assert!(summary.load_retries >= 1, "the disk error must be retried: {summary:?}");
    assert!(summary.lane_restarts >= 1, "lane 1 died and must restart: {summary:?}");
    assert_eq!(summary.passes_timed_out, 0, "no watchdog armed: {summary:?}");

    // the chaos-soak invariant: after the fleet exits, the shared
    // accountant holds NOTHING — crashed lanes included
    assert_eq!(acct.used(), 0, "accountant must drain to zero after the soak");
}

#[test]
fn pass_watchdog_times_out_hung_pass_and_next_requests_recover() {
    // An injected stuck medium (`disk_slow`) hangs one pass well past the
    // lane's watchdog deadline: the watchdog quiesces the gate, the pass
    // fails through the ordinary error path (counted in
    // `passes_timed_out`), and the NEXT pass re-arms everything and
    // serves normally.
    let e = engine();
    let cfg = RouterConfig {
        models: vec![RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 2,
            disk: "unthrottled".into(),
            device_cache: false,
            pass_timeout_ms: Some(150),
            ..RunConfig::default()
        }],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        fault_plan: Some("seed=5;disk_slow@2+800".into()),
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    let reqs = (0..3u64)
        .map(|i| InferRequest { profile: "tiny-bert".into(), seed: Some(i), ..InferRequest::default() })
        .collect();
    let producer = drive(router.handle(), reqs);
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    assert!(summary.passes_timed_out >= 1, "{summary:?}");
    assert_eq!(summary.served + summary.rejected, 3);
    assert!(summary.rejected >= 1, "the hung pass's request fails: {summary:?}");
    let hung = responses.iter().find(|r| !r.ok).expect("one request rode the hung pass");
    assert!(
        hung.error.as_deref().unwrap().contains("watchdog"),
        "the failure must name the watchdog: {hung:?}"
    );
    // self-healing: the request AFTER the timeout served fine
    assert!(responses.last().unwrap().ok, "{responses:?}");
}

#[test]
fn continuous_request_expiring_mid_decode_retires_at_token_boundary() {
    // Satellite regression: a continuous-batch request whose deadline
    // expires AFTER it joined the running decode used to burn passes to
    // the end; it must retire at the next token boundary with
    // `deadline_expired`, and its neighbors keep decoding.
    let cfg = RouterConfig {
        models: vec![RunConfig { gen_tokens: Some(6), ..chaos_lane("tiny-gpt") }],
        kv_budget: Some(1 << 20),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        // one pass sleeps 1.5 s mid-decode, so the 700 ms deadline is
        // comfortably alive at admission and comfortably dead at the
        // following token boundary
        fault_plan: Some("seed=2;disk_slow@4+1500".into()),
        ..RouterConfig::default()
    };
    let router = ConcurrentRouter::new(Paths::detect(), cfg).unwrap();
    let reqs = vec![
        InferRequest {
            profile: "tiny-gpt".into(),
            seed: Some(1),
            deadline: Some(Duration::from_millis(700)),
            ..InferRequest::default()
        },
        InferRequest { profile: "tiny-gpt".into(), seed: Some(2), ..InferRequest::default() },
    ];
    let producer = drive(router.handle(), reqs);
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    let expired = &responses[0];
    assert!(!expired.ok, "{expired:?}");
    assert_eq!(expired.reason.as_deref(), Some("deadline_expired"), "{expired:?}");
    assert!(
        expired.error.as_deref().unwrap().contains("mid-decode"),
        "must retire mid-decode, not before admission: {expired:?}"
    );
    assert!(responses[1].ok, "the deadline-free neighbor finishes: {:?}", responses[1]);
    assert_eq!(summary.served, 1, "{summary:?}");
    assert_eq!(summary.rejected, 1, "{summary:?}");
}

#[test]
fn serialized_router_lane_death_requeues_and_replays_bit_identically() {
    // The single-threaded router's supervisor: an injected lane death at a
    // token boundary requeues the in-flight decodes (deadlines hold),
    // restarts the lane, and the replay — driven by the requests' own
    // seeds — produces exactly the tokens a fault-free run produces.
    let e = engine();
    let mk_cfg = |plan: Option<&str>| RouterConfig {
        models: vec![chaos_lane("tiny-gpt")],
        kv_budget: Some(1 << 20),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        fault_plan: plan.map(String::from),
        ..RouterConfig::default()
    };
    let traffic = || -> Vec<InferRequest> {
        (0..4u64)
            .map(|i| InferRequest {
                profile: "tiny-gpt".into(),
                seed: Some(100 + i),
                ..InferRequest::default()
            })
            .collect()
    };

    let router = Router::new(&e, mk_cfg(None)).unwrap();
    let producer = drive(router.handle(), traffic());
    let base = router.run().unwrap();
    let base_rows: Vec<_> = producer
        .join()
        .unwrap()
        .into_iter()
        .map(|r| {
            assert!(r.ok, "{r:?}");
            r.generated_rows
        })
        .collect();
    assert_eq!(base.served, 4);

    let router = Router::new(&e, mk_cfg(Some("seed=9;lane_death@2:0"))).unwrap();
    let acct = router.accountant().clone();
    let producer = drive(router.handle(), traffic());
    let summary = router.run().unwrap();
    let rows: Vec<_> = producer
        .join()
        .unwrap()
        .into_iter()
        .map(|r| {
            assert!(r.ok, "a requeued request must still be served: {r:?}");
            r.generated_rows
        })
        .collect();

    assert_eq!(summary.served, 4, "{:?}", summary.first_error);
    assert_eq!(summary.lane_restarts, 1, "{summary:?}");
    assert!(summary.requeued >= 1, "the crash caught decodes in flight: {summary:?}");
    assert_eq!(summary.faults_injected, 1, "{summary:?}");
    assert_eq!(rows, base_rows, "replayed decodes must match the fault-free run bit for bit");
    assert_eq!(acct.used(), 0, "accountant must drain after the run");
}

#[test]
fn serialized_router_sheds_lane_dead_once_restart_budget_exhausted() {
    // A lane that keeps dying burns its crash-restart budget and then
    // stays dead: queued and newly arriving requests are shed with the
    // `lane_dead` reason instead of hanging, and the router still exits
    // cleanly.
    let e = engine();
    let cfg = RouterConfig {
        models: vec![RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 2,
            disk: "unthrottled".into(),
            ..RunConfig::default()
        }],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        fault_plan: Some("seed=3;lane_death@1x5:0".into()),
        max_lane_restarts: 1,
        ..RouterConfig::default()
    };
    let router = Router::new(&e, cfg).unwrap();
    let reqs = (0..4u64)
        .map(|i| InferRequest { profile: "tiny-bert".into(), seed: Some(i), ..InferRequest::default() })
        .collect();
    let producer = drive(router.handle(), reqs);
    let summary = router.run().unwrap();
    let responses = producer.join().unwrap();

    assert_eq!(summary.lane_restarts, 1, "{summary:?}");
    assert_eq!(summary.served, 1, "only the request before the first death: {summary:?}");
    assert_eq!(summary.rejected, 3, "{summary:?}");
    assert!(responses[0].ok, "{responses:?}");
    for r in &responses[1..] {
        assert!(!r.ok, "{r:?}");
        assert_eq!(r.reason.as_deref(), Some("lane_dead"), "{r:?}");
    }
}

fn bert_router_cfg(fault_plan: Option<&str>) -> RouterConfig {
    RouterConfig {
        models: vec![RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 2,
            disk: "unthrottled".into(),
            ..RunConfig::default()
        }],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        fault_plan: fault_plan.map(String::from),
        ..RouterConfig::default()
    }
}

fn infer_line(profile: &str) -> String {
    format!("{}\n", InferRequest::new(profile).to_json().compact())
}

#[test]
fn tcp_client_dropping_after_submit_leaks_nothing() {
    // Satellite: a client that submits a request and vanishes before the
    // reply must not wedge anything — the request is still served (its
    // ticket resolves; the unwritable reply is discarded with the
    // connection) and the server keeps serving other clients.
    let e = engine();
    let frontend = TcpFrontend::bind("127.0.0.1:0").unwrap();
    let addr = frontend.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        {
            let mut s1 = TcpStream::connect(addr).unwrap();
            s1.write_all(infer_line("tiny-bert").as_bytes()).unwrap();
            s1.flush().unwrap();
            // dropped here: the reply has nowhere to go
        }
        let mut s2 = TcpStream::connect(addr).unwrap();
        let reply = roundtrip(&mut s2, &InferRequest::new("tiny-bert").to_json()).unwrap();
        assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");
        // give the vanished client's request time to finish serving
        std::thread::sleep(Duration::from_millis(400));
        let reply = roundtrip(&mut s2, &Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "shutdown");
    });
    let summary = frontend.run(&e, bert_router_cfg(None)).unwrap();
    client.join().unwrap();
    assert_eq!(summary.served, 2, "the dropped client's request still served: {summary:?}");
    assert_eq!(summary.rejected, 0, "{summary:?}");
}

#[test]
fn tcp_malformed_partial_json_rejects_as_validation_and_serving_continues() {
    // Satellite: a truncated JSON line is a graceful `validation` reject,
    // not a dead connection — the same socket then serves a well-formed
    // request.
    let e = engine();
    let frontend = TcpFrontend::bind("127.0.0.1:0").unwrap();
    let addr = frontend.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"{\"op\":\"infer\",\"profile\":\"tiny-b\n").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        let v = Value::parse(line.trim()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap(), "{v}");
        assert_eq!(v.get("reason").unwrap().as_str().unwrap(), "validation", "{v}");

        let reply = roundtrip(&mut s, &InferRequest::new("tiny-bert").to_json()).unwrap();
        assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");
        let reply = roundtrip(&mut s, &Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "shutdown");
    });
    let summary = frontend.run(&e, bert_router_cfg(None)).unwrap();
    client.join().unwrap();
    assert_eq!(summary.served, 1, "{summary:?}");
}

#[test]
fn tcp_injected_conn_drop_hits_one_connection_only() {
    // `conn_drop` probes through the ROUTER's injector (one shared plan,
    // one set of counters): the victim connection sees a silent EOF, the
    // reconnect serves normally, and the fired fault shows up in the
    // summary counters.
    let e = engine();
    let frontend = TcpFrontend::bind("127.0.0.1:0").unwrap();
    let addr = frontend.local_addr().unwrap();
    let client = std::thread::spawn(move || {
        let mut s1 = TcpStream::connect(addr).unwrap();
        s1.write_all(infer_line("tiny-bert").as_bytes()).unwrap();
        s1.flush().unwrap();
        let mut line = String::new();
        let n = BufReader::new(s1.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert_eq!(n, 0, "the dropped connection must see EOF, not a reply: {line:?}");

        let mut s2 = TcpStream::connect(addr).unwrap();
        let reply = roundtrip(&mut s2, &InferRequest::new("tiny-bert").to_json()).unwrap();
        assert!(reply.get("ok").unwrap().as_bool().unwrap(), "{reply}");
        let reply = roundtrip(&mut s2, &Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert_eq!(reply.get("op").unwrap().as_str().unwrap(), "shutdown");
    });
    let summary = frontend.run(&e, bert_router_cfg(Some("seed=1;conn_drop@0"))).unwrap();
    client.join().unwrap();
    assert_eq!(summary.served, 1, "the dropped line was never submitted: {summary:?}");
    assert_eq!(summary.faults_injected, 1, "{summary:?}");
}
