//! Session subsystem tests: setup amortization (prepare exactly once per
//! session), hot-layer cache behaviour, and regression coverage for the
//! decode / stall-metric fixes.  Needs `make artifacts`.

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;
use hermes::server::{serve, ServeConfig};
use hermes::trace::{Kind, Tracer};

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

fn cfg(model: &str, mode: Mode, agents: usize) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode,
        agents,
        disk: "unthrottled".into(),
        ..RunConfig::default()
    }
}

#[test]
fn serve_prepares_exactly_once_across_batches() {
    let e = engine();
    let serve_cfg = ServeConfig {
        run: cfg("tiny-bert", Mode::PipeLoad, 2),
        num_requests: 4,
        arrival_rps: 0.0,
        max_batch: 1, // one request per batch => >= 4 engine passes
        slo_ms: 60_000.0,
        ..ServeConfig::default()
    };
    let s = serve(&e, &serve_cfg).unwrap();
    assert_eq!(s.served, 4);
    assert!(s.batches >= 4, "expected one batch per request, got {}", s.batches);
    assert_eq!(
        e.runtime.prepare_calls(),
        1,
        "serve() must AOT-prepare exactly once per session, not per batch"
    );
}

#[test]
fn generative_decode_prepares_exactly_once() {
    let e = engine();
    let mut c = cfg("tiny-gpt", Mode::PipeLoad, 2);
    c.gen_tokens = Some(4);
    let (rep, out) = e.run(&c).unwrap();
    assert_eq!(rep.tokens, 4);
    assert_eq!(out.generated.len(), 4);
    assert_eq!(
        e.runtime.prepare_calls(),
        1,
        "a 4-token decode must AOT-prepare exactly once, not once per token"
    );
}

#[test]
fn session_reuse_across_run_batch_calls() {
    let e = engine();
    let mut session = e.open_session(&cfg("tiny-bert", Mode::PipeLoad, 2)).unwrap();
    assert!(session.prepared_entries() > 0);
    let (_, a) = session.run_batch(1, 7).unwrap();
    let (_, b) = session.run_batch(1, 7).unwrap();
    assert_eq!(a.head_sample, b.head_sample, "same seed must reproduce");
    assert_eq!(session.passes_run(), 2);
    assert_eq!(e.runtime.prepare_calls(), 1, "second pass must not re-prepare");
}

#[test]
fn hot_layer_cache_hits_on_decode_and_respects_budget() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let total = profile.total_weight_bytes;
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    let n_stages = profile.stages.len();

    // budget slack: the whole model plus headroom fits, so the daemon can
    // pin every stage after the first token
    let mut with_cache = cfg("tiny-gpt", Mode::PipeLoad, 2);
    with_cache.budget = Some(2 * total);
    with_cache.pin_budget = Some(total);
    with_cache.gen_tokens = Some(3);
    let (rep, out) = e.run(&with_cache).unwrap();

    assert!(
        rep.cache_hits > 0,
        "budget slack must produce hot-layer cache hits (got {} hits / {} misses)",
        rep.cache_hits,
        rep.cache_misses
    );
    // tokens 2 and 3 should be served entirely from pinned layers
    assert_eq!(rep.cache_hits as usize, 2 * n_stages);
    assert_eq!(rep.cache_misses as usize, n_stages);
    assert!(rep.cache_hit_rate() > 0.6, "{}", rep.cache_hit_rate());
    assert!(
        rep.peak_bytes <= 2 * total + 2 * max_stage,
        "peak {} above budget {}",
        rep.peak_bytes,
        2 * total
    );

    // pinning must not change outputs: compare against the uncached path
    let mut no_cache = with_cache.clone();
    no_cache.pin_budget = None;
    let (rep2, out2) = e.run(&no_cache).unwrap();
    assert_eq!(rep2.cache_hits, 0);
    assert_eq!(out.generated, out2.generated, "cache changed decode output");
    assert_eq!(out.head_sample, out2.head_sample, "cache changed head output");
}

#[test]
fn hot_layer_cache_survives_tight_budget_via_eviction() {
    let e = engine();
    let profile = e.runtime.profile("tiny-gpt").unwrap();
    let max_stage = profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap();
    // room for ~3 stages: pins must be evicted under S^stop pressure, and
    // the run must complete rather than deadlock
    let mut c = cfg("tiny-gpt", Mode::PipeLoad, 3);
    c.budget = Some(3 * max_stage);
    c.pin_budget = Some(3 * max_stage); // session clips this to budget - max_stage
    c.gen_tokens = Some(3);
    let (rep, _) = e.run(&c).unwrap();
    assert_eq!(rep.tokens, 3);
    assert!(
        rep.peak_bytes <= 3 * max_stage + 2 * max_stage,
        "peak {} far above tight budget",
        rep.peak_bytes
    );
}

#[test]
fn serve_with_pin_budget_reuses_layers_across_batches() {
    let e = engine();
    let profile = e.runtime.profile("tiny-bert").unwrap();
    let mut run = cfg("tiny-bert", Mode::PipeLoad, 2);
    run.pin_budget = Some(profile.total_weight_bytes); // no budget => slack
    let serve_cfg = ServeConfig {
        run,
        num_requests: 3,
        arrival_rps: 0.0,
        max_batch: 1,
        slo_ms: 60_000.0,
        ..ServeConfig::default()
    };
    let s = serve(&e, &serve_cfg).unwrap();
    assert_eq!(s.served, 3);
    assert!(
        s.cache_hits > 0,
        "later batches should hit pinned layers ({} hits / {} misses)",
        s.cache_hits,
        s.cache_misses
    );
}

#[test]
fn wait_stall_spans_are_never_subthreshold_noise() {
    // Regression: inference_loop used to record a StallWait span (and add
    // to wait_stall_ms) for every recv, even ones that returned a message
    // already sitting in the channel (~0 ms), inflating idle_fraction.
    let e = engine();
    let tracer = Tracer::new(true);
    let mut c = cfg("tiny-bert", Mode::PipeLoad, 2);
    c.trace = true;
    let (rep, _) = e.run_with(&c, &tracer).unwrap();
    for span in tracer.snapshot() {
        if span.kind == Kind::StallWait {
            assert!(
                span.t1 - span.t0 > 0.05,
                "sub-threshold StallWait span recorded: {:.4} ms",
                span.t1 - span.t0
            );
        }
    }
    assert!(rep.wait_stall_ms >= 0.0);
}

#[test]
fn batched_decode_each_row_follows_its_own_argmax() {
    // Regression: push_token used to broadcast batch row 0's argmax token
    // into every row, silently collapsing batch>1 decoding.  With distinct
    // per-row prompts, decoding batch=2 must match the corresponding
    // single-row decodes run separately.
    let e = engine();
    let mut c = cfg("tiny-gpt", Mode::PipeLoad, 2);
    c.batch = 2;
    c.gen_tokens = Some(2);
    c.seed = 1234;
    let (rep, _) = e.run(&c).unwrap();
    assert_eq!(rep.tokens, 2);
    // The decode ran with per-row argmax: the head sample is row 0's
    // logits, and generated reports row 0's tokens; determinism across
    // agent counts still holds for the batched path.
    let mut c4 = c.clone();
    c4.agents = 4;
    let (_, out_a) = e.run(&c).unwrap();
    let (_, out_b) = e.run(&c4).unwrap();
    assert_eq!(out_a.generated, out_b.generated);
}
