//! Cross-language golden numerics: the Rust per-stage chain must equal the
//! python full-model forward on identical weights.
//!
//! `python -m compile.aot` writes, for each tiny profile:
//!   artifacts/golden/<p>/weights/stage_*.hws   (python-written shards)
//!   artifacts/golden/<p>/input.bin             (ids i32 / patches f32)
//!   artifacts/golden/<p>/expected.bin          (jax full_forward output)
//!   artifacts/golden/<p>/golden.json           (shapes + tolerances)
//!
//! This single test exercises L1 (the Pallas attention kernel inside the
//! HLO), L2 (the per-layer jax functions), the .hws interop, and the L3
//! execution chain at once.  Run `make artifacts` first.

use std::path::PathBuf;

use hermes::baseline::{forward_resident, ResidentModel};
use hermes::config::Paths;
use hermes::memory::MemoryAccountant;
use hermes::pipeload::{run_pipeline, ExecCtx, ModelInput, PipelineOpts};

use hermes::util::json::Value;
use hermes::weights::read_shard;

const GOLDEN_PROFILES: [&str; 4] = ["tiny-bert", "tiny-gpt", "tiny-vit", "tiny-gptj"];

struct Golden {
    dir: PathBuf,
    input_i32: Option<Vec<i32>>,
    input_f32: Option<Vec<f32>>,
    expected: Vec<f32>,
    rtol: f64,
    atol: f64,
}

fn load_golden(paths: &Paths, profile: &str) -> Golden {
    let dir = paths.artifacts.join("golden").join(profile);
    let meta = Value::from_file(&dir.join("golden.json"))
        .unwrap_or_else(|e| panic!("missing golden for {profile} — run `make artifacts` ({e})"));
    let in_dtype = meta.req("input").unwrap().req("dtype").unwrap().as_str().unwrap().to_string();
    let raw = std::fs::read(dir.join("input.bin")).unwrap();
    let (input_i32, input_f32) = if in_dtype == "i32" {
        (Some(raw.chunks_exact(4).map(|b| i32::from_le_bytes(b.try_into().unwrap())).collect()), None)
    } else {
        (None, Some(raw.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())).collect()))
    };
    let expected = std::fs::read(dir.join("expected.bin"))
        .unwrap()
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
        .collect();
    Golden {
        dir,
        input_i32,
        input_f32,
        expected,
        rtol: meta.req("rtol").unwrap().as_f64().unwrap(),
        atol: meta.req("atol").unwrap().as_f64().unwrap(),
    }
}

fn assert_allclose(got: &[f32], want: &[f32], rtol: f64, atol: f64, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    let mut worst = 0.0f64;
    let mut worst_i = 0;
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let err = (g as f64 - w as f64).abs();
        let bound = atol + rtol * (w as f64).abs();
        if err - bound > worst {
            worst = err - bound;
            worst_i = i;
        }
    }
    assert!(
        worst <= 0.0,
        "{label}: worst violation at [{worst_i}]: got {} want {} (excess {worst:.3e})",
        got[worst_i],
        want[worst_i]
    );
}

fn golden_ctx<'rt>(
    runtime: &'rt hermes::runtime::Runtime,
    profile: &str,
    golden: &Golden,
) -> ExecCtx<'rt> {
    // shards live under golden/<p>/weights/<p>? No: golden/<p>/weights/stage_*.hws
    // ExecCtx joins profile name, so point weights_dir at golden/<p> and
    // rename: shard_dir = golden/<p>/weights
    let mut ctx = ExecCtx::new(
        runtime,
        profile,
        &golden.dir, // placeholder; fixed below
        hermes::diskio::Disk::preset("unthrottled").unwrap(),
    )
    .unwrap();
    ctx.shard_dir = golden.dir.join("weights");
    ctx
}

fn model_input(g: &Golden) -> ModelInput {
    match (&g.input_i32, &g.input_f32) {
        (Some(ids), _) => ModelInput::Ids(ids.clone()),
        (_, Some(p)) => ModelInput::Patches(p.clone()),
        _ => unreachable!(),
    }
}

#[test]
fn rust_chain_matches_python_forward_all_tiny_profiles() {
    let paths = Paths::detect();
    let runtime = hermes::runtime::Runtime::new(&paths.artifacts).unwrap();
    for profile_name in GOLDEN_PROFILES {
        let golden = load_golden(&paths, profile_name);
        let profile = runtime.profile(profile_name).unwrap();
        let ctx = golden_ctx(&runtime, profile_name, &golden);

        // resident (baseline) chain
        let shards = profile
            .stages
            .iter()
            .map(|s| read_shard(&ctx.shard_dir.join(&s.shard)).unwrap())
            .collect::<Vec<_>>();
        let bytes = shards.iter().map(|s| s.total_data_bytes()).sum();
        let model = ResidentModel { shards, bytes, load_ms: 0.0 };
        let accountant = MemoryAccountant::unlimited();
        let (out, _) = forward_resident(&ctx, &model, &accountant, &model_input(&golden)).unwrap();
        let got = runtime.buffer_to_f32(&out).unwrap();
        assert_allclose(&got, &golden.expected, golden.rtol, golden.atol, profile_name);
    }
}

#[test]
fn pipeload_output_equals_python_golden() {
    let paths = Paths::detect();
    let runtime = hermes::runtime::Runtime::new(&paths.artifacts).unwrap();
    for profile_name in ["tiny-bert", "tiny-gptj"] {
        let golden = load_golden(&paths, profile_name);
        let ctx = golden_ctx(&runtime, profile_name, &golden);
        let (out, _) = run_pipeline(
            &ctx,
            &PipelineOpts::pipeload(3),
            None,
            &model_input(&golden),
        )
        .unwrap();
        let got = runtime.buffer_to_f32(&out).unwrap();
        assert_allclose(&got, &golden.expected, golden.rtol, golden.atol, profile_name);
    }
}

#[test]
fn all_three_modes_agree_bitwise_on_golden_weights() {
    let paths = Paths::detect();
    let runtime = hermes::runtime::Runtime::new(&paths.artifacts).unwrap();
    let golden = load_golden(&paths, "tiny-gpt");
    let ctx = golden_ctx(&runtime, "tiny-gpt", &golden);
    let input = model_input(&golden);

    let (pl, _) = run_pipeline(&ctx, &PipelineOpts::pipeload(2), None, &input).unwrap();
    let (ps, _) = run_pipeline(&ctx, &PipelineOpts::pipeswitch(), None, &input).unwrap();
    let profile = runtime.profile("tiny-gpt").unwrap();
    let shards = profile
        .stages
        .iter()
        .map(|s| read_shard(&ctx.shard_dir.join(&s.shard)).unwrap())
        .collect::<Vec<_>>();
    let model = ResidentModel { bytes: 0, load_ms: 0.0, shards };
    let accountant = MemoryAccountant::unlimited();
    let (bl, _) = forward_resident(&ctx, &model, &accountant, &input).unwrap();

    let a = runtime.buffer_to_f32(&pl).unwrap();
    let b = runtime.buffer_to_f32(&ps).unwrap();
    let c = runtime.buffer_to_f32(&bl).unwrap();
    assert_eq!(a, b, "pipeload vs pipeswitch must be bitwise identical");
    assert_eq!(a, c, "pipeload vs baseline must be bitwise identical");
}
