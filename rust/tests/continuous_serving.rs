//! Continuous-batching serving integration tests (PR 7): iteration-level
//! scheduling through the `BatchComposer` on both routers, cross-request
//! KV prefix sharing, SLO-driven overload shedding, and the whole-queue
//! deadline sweep.  The acceptance bar: under join/leave churn the tokens
//! are bit-identical to the fixed-batch path, the shared budget holds
//! with shared blocks charged once, and dedup is observable in the
//! summary counters.  Needs `make artifacts`.

use std::time::Duration;

use hermes::config::{Mode, Paths, RunConfig};
use hermes::engine::Engine;
use hermes::server::{
    ConcurrentRouter, InferRequest, InferResponse, Router, RouterConfig, RouterHandle,
};

fn engine() -> Engine {
    Engine::new(Paths::detect()).unwrap()
}

/// A generative KV lane: small blocks so the prompt seals (and dedups)
/// whole blocks even on the tiny test profiles.
fn kv_lane(model: &str, continuous: bool) -> RunConfig {
    RunConfig {
        profile: model.into(),
        mode: Mode::PipeLoad,
        agents: 2,
        disk: "unthrottled".into(),
        kv_cache: true,
        kv_block_tokens: Some(2),
        gen_tokens: Some(4),
        continuous,
        max_active: if continuous { Some(2) } else { None },
        ..RunConfig::default()
    }
}

/// Submit 12 alternating requests with explicit seeds; pairs of requests
/// landing in the SAME lane share a seed (i and i+2 -> `9000 + i/4`), so
/// the continuous scheduler has two identical prompts resident at once —
/// the cross-request prefix-sharing case.  Returns responses in
/// submission order.
fn drive_churn(
    handle: RouterHandle,
    lane_a: &'static str,
    lane_b: &'static str,
) -> std::thread::JoinHandle<Vec<InferResponse>> {
    std::thread::spawn(move || {
        let tickets: Vec<_> = (0..12u64)
            .map(|i| {
                let profile = if i % 2 == 0 { lane_a } else { lane_b };
                handle
                    .submit(InferRequest {
                        profile: profile.into(),
                        seed: Some(9000 + i / 4),
                        ..InferRequest::default()
                    })
                    .unwrap()
            })
            .collect();
        let responses: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        handle.shutdown();
        responses
    })
}

fn rows_of(responses: Vec<InferResponse>) -> Vec<(String, Vec<Vec<i32>>)> {
    responses
        .into_iter()
        .map(|r| {
            assert!(r.ok, "{r:?}");
            (r.profile, r.generated_rows)
        })
        .collect()
}

#[test]
fn continuous_two_lanes_bit_identical_with_kv_prefix_sharing() {
    // PR 7 acceptance: two continuous KV lanes on the concurrent router
    // under join/leave churn (max_active 2, 6 requests per lane) must
    // (a) emit tokens bit-identical to the fixed-batch path for the same
    // traffic, (b) stay under the ONE shared budget with shared blocks
    // charged once, and (c) show cross-request dedup in the counters.
    let e = engine();
    let total_a = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gptj").unwrap().total_weight_bytes;
    let budget = 2 * (total_a + total_b);
    let mk_cfg = |continuous: bool| RouterConfig {
        models: vec![kv_lane("tiny-gpt", continuous), kv_lane("tiny-gptj", continuous)],
        budget: Some(budget),
        kv_budget: Some(1 << 20),
        // max_batch 1 keeps the fixed reference from folding the
        // same-seed pairs, so both schedulers decode every request at
        // batch 1 with its own seed — the bit-identity contract
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };

    // fixed-batch reference, same traffic
    let router = ConcurrentRouter::new(Paths::detect(), mk_cfg(false)).unwrap();
    let producer = drive_churn(router.handle(), "tiny-gpt", "tiny-gptj");
    let fixed = router.run().unwrap();
    let fixed_rows = rows_of(producer.join().unwrap());
    assert_eq!(fixed.served, 12, "{:?}", fixed.first_error);
    assert_eq!(fixed.joins, 0, "fixed lanes never touch the composer");

    // continuous run
    let router = ConcurrentRouter::new(Paths::detect(), mk_cfg(true)).unwrap();
    let producer = drive_churn(router.handle(), "tiny-gpt", "tiny-gptj");
    let summary = router.run().unwrap();
    let cont_rows = rows_of(producer.join().unwrap());

    assert_eq!(summary.served, 12, "{:?}", summary.first_error);
    assert_eq!(summary.rejected, 0);
    assert_eq!(cont_rows, fixed_rows, "continuous tokens must match the fixed path bit for bit");

    // (c) scheduler ledger: every request joined and left; nothing shed
    assert_eq!(summary.joins, 12, "{summary:?}");
    assert_eq!(summary.leaves, 12, "{summary:?}");
    assert_eq!(summary.shed_overload, 0);
    assert_eq!(summary.slo_attained_pct, 100.0, "no SLO targets -> vacuously attained");
    assert!(summary.tokens_per_sec > 0.0, "{summary:?}");

    // cross-request prefix sharing: the same-seed pairs resident together
    // must dedup their sealed prompt blocks (charged once)
    assert!(summary.shared_kv_blocks > 0, "no block was ever shared: {summary:?}");
    assert!(summary.kv_dedup_bytes > 0, "dedup freed no bytes: {summary:?}");

    // (b) shared blocks counted once keeps the fleet under the budget
    assert!(
        summary.peak_bytes <= budget,
        "peak {} above shared budget {budget}",
        summary.peak_bytes
    );
    for m in &summary.per_model {
        assert_eq!(m.served, 6, "lane {} served {}", m.profile, m.served);
        assert!(m.kv_inc_passes > 0, "decode must stay incremental: {m:?}");
        assert_eq!(m.joins, 6, "{m:?}");
        assert_eq!(m.leaves, 6, "{m:?}");
    }
}

#[test]
fn serialized_router_continuous_matches_fixed() {
    // Both routers route through the composer: the single-threaded Router
    // interleaves its continuous lanes under a weighted-fair clock and
    // must keep the same bit-identity contract.
    let e = engine();
    let total_a = e.runtime.profile("tiny-gpt").unwrap().total_weight_bytes;
    let total_b = e.runtime.profile("tiny-gptj").unwrap().total_weight_bytes;
    let mk_cfg = |continuous: bool| RouterConfig {
        models: vec![kv_lane("tiny-gpt", continuous), kv_lane("tiny-gptj", continuous)],
        budget: Some(2 * (total_a + total_b)),
        kv_budget: Some(1 << 20),
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };

    let router = Router::new(&e, mk_cfg(false)).unwrap();
    let producer = drive_churn(router.handle(), "tiny-gpt", "tiny-gptj");
    let fixed = router.run().unwrap();
    let fixed_rows = rows_of(producer.join().unwrap());
    assert_eq!(fixed.served, 12, "{:?}", fixed.first_error);

    let router = Router::new(&e, mk_cfg(true)).unwrap();
    let producer = drive_churn(router.handle(), "tiny-gpt", "tiny-gptj");
    let summary = router.run().unwrap();
    let cont_rows = rows_of(producer.join().unwrap());

    assert_eq!(summary.served, 12, "{:?}", summary.first_error);
    assert_eq!(summary.rejected, 0);
    assert_eq!(cont_rows, fixed_rows, "serialized continuous tokens must match fixed");
    assert_eq!(summary.joins, 12);
    assert_eq!(summary.leaves, 12);
    assert!(summary.kv_dedup_bytes > 0, "same-seed pairs must share prefixes: {summary:?}");
}

#[test]
fn continuous_lane_sheds_slo_blown_requests() {
    // Explicit overload shedding: with max_active 1, a request whose
    // per-request SLO is microscopic is guaranteed to have blown it by
    // the time the running request frees the slot — the composer sheds it
    // at admission instead of burning a decode it cannot win.
    let cfg = RouterConfig {
        models: vec![RunConfig {
            gen_tokens: Some(6),
            max_active: Some(1),
            ..kv_lane("tiny-gpt", true)
        }],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        ..RouterConfig::default()
    };
    let router = ConcurrentRouter::new(Paths::detect(), cfg).unwrap();
    let handle = router.handle();
    let t_head = handle
        .submit(InferRequest { profile: "tiny-gpt".into(), seed: Some(1), ..InferRequest::default() })
        .unwrap();
    let t_shed = handle
        .submit(InferRequest {
            profile: "tiny-gpt".into(),
            seed: Some(2),
            slo_ms: Some(0.001),
            ..InferRequest::default()
        })
        .unwrap();
    handle.shutdown();
    drop(handle);
    let summary = router.run().unwrap();

    assert!(t_head.wait().unwrap().ok);
    let shed = t_shed.wait().unwrap();
    assert!(!shed.ok, "{shed:?}");
    assert!(shed.error.as_deref().unwrap().contains("shed"), "{shed:?}");
    assert_eq!(summary.served, 1);
    assert_eq!(summary.rejected, 1);
    assert_eq!(summary.shed_overload, 1, "{summary:?}");
    assert_eq!(summary.joins, 1, "the shed request never joined");
    assert_eq!(summary.slo_attained_pct, 100.0, "the served request carried no target");
}

#[test]
fn fixed_lane_sweeps_expired_request_behind_live_head() {
    // Satellite regression: the fixed-batch lane used to check only the
    // queue head at dequeue, so an expired request parked BEHIND a live
    // head waited out the whole head decode before its rejection.  The
    // wake-up sweep now rejects it from anywhere in the queue.
    let cfg = RouterConfig {
        models: vec![kv_lane("tiny-gpt", false)],
        max_batch: 1,
        batch_window: Duration::from_millis(1),
        concurrent: true,
        ..RouterConfig::default()
    };
    let router = ConcurrentRouter::new(Paths::detect(), cfg).unwrap();
    let handle = router.handle();
    let t_head = handle
        .submit(InferRequest { profile: "tiny-gpt".into(), seed: Some(3), ..InferRequest::default() })
        .unwrap();
    let t_expired = handle
        .submit(InferRequest {
            profile: "tiny-gpt".into(),
            deadline: Some(Duration::ZERO),
            ..InferRequest::default()
        })
        .unwrap();
    handle.shutdown();
    drop(handle);
    let summary = router.run().unwrap();

    assert!(t_head.wait().unwrap().ok, "the live head is served");
    let exp = t_expired.wait().unwrap();
    assert!(!exp.ok);
    assert!(
        exp.error.as_deref().unwrap().contains("deadline exceeded before admission"),
        "{exp:?}"
    );
    assert_eq!(summary.served, 1);
    assert_eq!(summary.rejected, 1);
}
