//! Line-delimited-JSON TCP front-end over the [`Router`] queue.
//!
//! `hermes serve --listen <addr>` binds a std [`TcpListener`]; each
//! accepted connection gets a thread that parses one JSON object per line
//! (`util::json`, no serde in the offline crate set), submits it through a
//! cloned [`RouterHandle`], blocks on the [`Ticket`], and writes the JSON
//! response line back.  The router loop itself stays on the caller's
//! thread (the PJRT runtime is not `Send`), exactly as the original
//! serving loop promised: "a TCP front-end would feed the same queue
//! without touching this loop".
//!
//! Protocol (one JSON object per line, both directions):
//!
//! ```text
//! -> {"op":"infer","profile":"tiny-bert","batch_hint":1,"deadline_ms":5000,"seed":7}
//! <- {"ok":true,"id":0,"profile":"tiny-bert","latency_ms":12.3,"batch":1,"tokens":0,"peak_bytes":1048576}
//! -> {"op":"infer","profile":"tiny-gpt","batch_hint":2}
//! <- {"ok":true,"id":1,...,"tokens":2,"generated_rows":[[17,202],[65,9]]}
//! -> {"op":"ping"}
//! <- {"ok":true,"op":"pong"}
//! -> {"op":"stats"}           # mid-flight RouterSummary snapshot
//! <- {"ok":true,"op":"stats","served":3,...,"telemetry_dropped_events":0,"subscriber_drops":{...}}
//! -> {"op":"metrics"}         # Prometheus-style text under "text"
//! <- {"ok":true,"op":"metrics","text":"# HELP hermes_served_total ..."}
//! -> {"op":"health"}          # rolling-window derived signals (see analyze::signals)
//! <- {"ok":true,"op":"health","lanes":[{"lane":0,"stall_mem_ratio":0.1,...}],...}
//! -> {"op":"shutdown"}        # drains queued work, stops the server
//! <- {"ok":true,"op":"shutdown"}
//! ```
//!
//! Rejections and protocol errors carry a structured `reason` slug
//! (`deadline_expired`, `shed_overload`, `validation`, `lane_dead`,
//! `internal`) next to the human-readable `error` text.
//!
//! Generative profiles answer with `generated_rows`: one token list per
//! requested row (`batch_hint` rows, each row's own argmax).
//!
//! [`Ticket`]: super::router::Ticket

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::lanes::ConcurrentRouter;
use super::router::{
    reject_reason, InferRequest, Router, RouterConfig, RouterHandle, RouterSummary,
};
use crate::analyze::{DerivedSignals, DEFAULT_WINDOW};
use crate::engine::Engine;
use crate::faults::{FaultInjector, FaultKind};
use crate::telemetry::Telemetry;
use crate::util::json::Value;

/// A bound-but-not-yet-serving TCP front-end.  Binding is split from
/// running so callers (and tests) can learn the ephemeral port before the
/// blocking serve loop starts.
pub struct TcpFrontend {
    listener: TcpListener,
    telemetry: Telemetry,
    signals: Arc<DerivedSignals>,
}

impl TcpFrontend {
    /// Bind the listen address (e.g. `127.0.0.1:7070`, or port 0 for an
    /// ephemeral port).
    pub fn bind(addr: &str) -> Result<TcpFrontend> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding TCP listener on {addr}"))?;
        let telemetry = Telemetry::off();
        let signals = Arc::new(DerivedSignals::attach(&telemetry, DEFAULT_WINDOW));
        Ok(TcpFrontend { listener, telemetry, signals })
    }

    /// Attach a telemetry bus: the router (and every lane/session under
    /// it) records lifecycle spans on it, `{"op":"health"}` aggregates it
    /// into rolling-window derived signals, and `{"op":"metrics"}` reports
    /// its dropped-event counters.
    pub fn set_telemetry(&mut self, t: Telemetry) {
        // re-attach the health aggregator so its subscription rides the
        // bus that will actually carry the run's events
        self.signals = Arc::new(DerivedSignals::attach(&t, DEFAULT_WINDOW));
        self.telemetry = t;
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until a client sends `{"op":"shutdown"}`.  The router loop
    /// (and every engine pass) runs on this thread; the accept loop and
    /// the per-connection readers run on background threads feeding the
    /// router's queue.  With `cfg.concurrent` the serialized router is
    /// swapped for a [`ConcurrentRouter`] (per-lane executor threads, the
    /// caller's engine unused — each lane builds its own); the wire
    /// protocol and summary are identical.
    pub fn run(self, engine: &Engine, cfg: RouterConfig) -> Result<RouterSummary> {
        let telemetry = self.telemetry.clone();
        if cfg.concurrent {
            let mut router = ConcurrentRouter::new(engine.paths.clone(), cfg)?;
            router.set_telemetry(telemetry);
            let handle = router.handle();
            let faults = router.fault_injector();
            let (stop, accept) = self.spawn_accept_loop(handle, faults)?;
            let summary = router.run();
            stop.store(true, Ordering::Relaxed);
            let _ = accept.join();
            return summary;
        }
        let mut router = Router::new(engine, cfg)?;
        router.set_telemetry(telemetry);
        let handle = router.handle();
        let faults = router.fault_injector();
        let (stop, accept) = self.spawn_accept_loop(handle, faults)?;
        let summary = router.run();
        stop.store(true, Ordering::Relaxed);
        let _ = accept.join();
        summary
    }

    /// Background accept loop feeding `handle`'s queue; returns the stop
    /// flag and the join handle.  The accept thread owns the listener and
    /// the last `RouterHandle` clone, so flipping the flag lets the
    /// router drain and exit.
    fn spawn_accept_loop(
        self,
        handle: RouterHandle,
        faults: FaultInjector,
    ) -> Result<(Arc<AtomicBool>, std::thread::JoinHandle<()>)> {
        let stop = Arc::new(AtomicBool::new(false));

        // Non-blocking accept + stop flag: once the router exits, the
        // accept thread notices and unbinds instead of lingering forever.
        self.listener.set_nonblocking(true)?;
        let listener = self.listener;
        let telemetry = self.telemetry;
        let signals = self.signals;
        let accept_stop = stop.clone();
        let active = Arc::new(AtomicUsize::new(0));
        let accept = std::thread::spawn(move || {
            loop {
                if accept_stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((mut stream, _peer)) => {
                        // bound the thread-per-connection model: past the
                        // cap, answer "busy" and close instead of letting a
                        // connection flood exhaust threads/queue memory
                        // (the line-length cap alone doesn't cover that)
                        if active.load(Ordering::Relaxed) >= MAX_CONNECTIONS {
                            let reply = Value::obj()
                                .set("ok", false)
                                .set("reason", reject_reason::SHED_OVERLOAD)
                                .set("error", "server busy: too many connections");
                            let _ = stream.write_all(reply.compact().as_bytes());
                            let _ = stream.write_all(b"\n");
                            // FIN before close: dropping with the client's
                            // request unread would RST and may discard the
                            // reply before the peer reads it
                            let _ = stream.shutdown(std::net::Shutdown::Write);
                            continue;
                        }
                        active.fetch_add(1, Ordering::Relaxed);
                        let h = handle.clone();
                        let tel = telemetry.clone();
                        let sig = signals.clone();
                        let fl = faults.clone();
                        let done = active.clone();
                        std::thread::spawn(move || {
                            let _ = client_loop(stream, h, tel, sig, fl);
                            done.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    Err(_) => {
                        // transient accept errors (ECONNABORTED from a
                        // client RST, EMFILE during a burst) must not kill
                        // the listener; the stop flag bounds this loop
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            // dropping `handle`'s last clone here lets the router drain
        });

        Ok((stop, accept))
    }
}

/// Longest request line a client may send (a valid request is well under
/// 1 KiB; anything bigger is a protocol violation, and an unbounded read
/// would let one peer grow a String until the whole server is OOM-killed).
const MAX_LINE_BYTES: usize = 64 * 1024;

/// Concurrent connection cap (thread-per-connection model).  Each
/// connection has at most one request in flight, so this also bounds the
/// router queue's growth from TCP clients.
const MAX_CONNECTIONS: usize = 64;

/// Idle-read timeout per connection.  Without one, 64 silent peers would
/// hold the connection cap forever (a standing lock-out), and reader
/// threads would outlive the server.  A peer idle this long is dropped.
const CLIENT_IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// Read one `\n`-terminated line with a hard length cap.  `Ok(None)` on a
/// clean EOF; `Err` on I/O failure or an oversized line.
fn read_bounded_line<R: BufRead>(reader: &mut R) -> std::io::Result<Option<String>> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                return Ok(if buf.is_empty() {
                    None
                } else {
                    Some(String::from_utf8_lossy(&buf).into_owned())
                });
            }
            match available.iter().position(|&b| b == b'\n') {
                Some(pos) => {
                    buf.extend_from_slice(&available[..pos]);
                    (true, pos + 1)
                }
                None => {
                    buf.extend_from_slice(available);
                    (false, available.len())
                }
            }
        };
        reader.consume(used);
        if buf.len() > MAX_LINE_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("request line exceeds {MAX_LINE_BYTES} bytes"),
            ));
        }
        if done {
            return Ok(Some(String::from_utf8_lossy(&buf).into_owned()));
        }
    }
}

/// One connection: read JSON lines, route them, write JSON lines back.
/// Any error (bad JSON, oversized line, dead router, closed socket)
/// answers or ends the connection gracefully — library code must not
/// panic or balloon on a bad peer.
fn client_loop(
    stream: TcpStream,
    handle: RouterHandle,
    telemetry: Telemetry,
    signals: Arc<DerivedSignals>,
    faults: FaultInjector,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(CLIENT_IDLE_TIMEOUT)).ok();
    let mut writer = stream.try_clone().context("cloning TCP stream")?;
    let mut reader = BufReader::new(stream);
    loop {
        let line = match read_bounded_line(&mut reader) {
            Ok(Some(l)) => l,
            Ok(None) => break, // peer closed the connection
            Err(e) => {
                // oversized/broken line: answer once, then drop the peer
                // (the stream can no longer be resynchronized to lines)
                let reply = Value::obj().set("ok", false).set("error", e.to_string());
                let _ = writer.write_all(reply.compact().as_bytes());
                let _ = writer.write_all(b"\n");
                let _ = writer.flush();
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        // injected connection drop: vanish without a reply — the client
        // sees EOF mid-conversation; the server (and every other peer)
        // keeps serving, which is exactly what the chaos plan asserts
        if faults.fire(FaultKind::ConnDrop) {
            break;
        }
        let (reply, shutdown) = handle_line(&line, &handle, &telemetry, &signals);
        writer.write_all(reply.compact().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        // the ack is on the wire before the router is told to stop, so a
        // client's shutdown reply can never race the process exiting
        if shutdown {
            handle.shutdown();
            break;
        }
    }
    Ok(())
}

/// Dispatch one request line; returns the reply and whether the peer
/// asked for a server shutdown (performed by the caller *after* the reply
/// is flushed).
fn handle_line(
    line: &str,
    handle: &RouterHandle,
    telemetry: &Telemetry,
    signals: &DerivedSignals,
) -> (Value, bool) {
    // protocol-level failures are validation errors in the reject taxonomy
    let err = |msg: String| {
        (
            Value::obj()
                .set("ok", false)
                .set("reason", reject_reason::VALIDATION)
                .set("error", msg),
            false,
        )
    };
    let parsed = match Value::parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("bad json: {e:#}")),
    };
    let op = parsed.get("op").and_then(|o| o.as_str().ok()).unwrap_or("infer");
    match op {
        "ping" => (Value::obj().set("ok", true).set("op", "pong"), false),
        "shutdown" => (Value::obj().set("ok", true).set("op", "shutdown"), true),
        // mid-flight counters, same aggregation code path as the final
        // summary (a snapshot taken at shutdown matches it field for field)
        "stats" => match handle.stats() {
            Ok(s) => {
                let mut subs = Value::obj();
                for (label, n) in telemetry.subscriber_drops() {
                    subs = subs.set(&label, n);
                }
                (
                    s.to_json()
                        .set("ok", true)
                        .set("op", "stats")
                        .set("telemetry_dropped_events", telemetry.dropped())
                        .set("subscriber_drops", subs),
                    false,
                )
            }
            Err(e) => err(format!("{e:#}")),
        },
        // Prometheus-style text exposition, wrapped in the line protocol's
        // one-JSON-object-per-line framing under the "text" key
        "metrics" => match handle.stats() {
            Ok(s) => {
                let mut text = s.to_prometheus(telemetry.dropped());
                signals.poll().to_prometheus(&mut text);
                for (label, n) in telemetry.subscriber_drops() {
                    text.push_str(&format!(
                        "hermes_subscriber_dropped_events_total{{subscriber=\"{label}\"}} {n}\n"
                    ));
                }
                (Value::obj().set("ok", true).set("op", "metrics").set("text", text), false)
            }
            Err(e) => err(format!("{e:#}")),
        },
        // live derived signals over the rolling health window — the same
        // aggregate an in-process controller consumes via DerivedSignals
        "health" => (signals.poll().to_json().set("ok", true).set("op", "health"), false),
        "infer" => {
            let req = match InferRequest::from_json(&parsed) {
                Ok(r) => r,
                Err(e) => return err(format!("bad request: {e:#}")),
            };
            match handle.submit(req).and_then(|t| t.wait()) {
                Ok(resp) => (resp.to_json(), false),
                Err(e) => (
                    Value::obj()
                        .set("ok", false)
                        .set("reason", reject_reason::LANE_DEAD)
                        .set("error", format!("{e:#}")),
                    false,
                ),
            }
        }
        other => err(format!("unknown op '{other}'")),
    }
}

/// Client-side convenience for tests/tools: one blocking round-trip on an
/// existing connection.
pub fn roundtrip(stream: &mut TcpStream, request: &Value) -> Result<Value> {
    let mut line = request.compact();
    line.push('\n');
    stream.write_all(line.as_bytes())?;
    stream.flush()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut reply = String::new();
    reader.read_line(&mut reply)?;
    if reply.trim().is_empty() {
        anyhow::bail!("server closed the connection without replying");
    }
    Value::parse(reply.trim()).context("parsing server reply")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_ephemeral_reports_port() {
        let f = TcpFrontend::bind("127.0.0.1:0").unwrap();
        let addr = f.local_addr().unwrap();
        assert_ne!(addr.port(), 0);
    }

    #[test]
    fn bounded_line_reader_caps_hostile_input() {
        use std::io::Cursor;
        let mut ok = Cursor::new(b"{\"op\":\"ping\"}\nrest".to_vec());
        assert_eq!(read_bounded_line(&mut ok).unwrap().unwrap(), "{\"op\":\"ping\"}");
        assert_eq!(read_bounded_line(&mut ok).unwrap().unwrap(), "rest"); // EOF-terminated
        assert!(read_bounded_line(&mut ok).unwrap().is_none());

        // a newline-free flood errors out instead of growing without bound
        let mut flood = Cursor::new(vec![b'x'; MAX_LINE_BYTES + 2]);
        assert!(read_bounded_line(&mut flood).is_err());
    }
}
