//! Multi-model request router: N model sessions, one memory budget.
//!
//! The [`Router`] is the serving core.  [`Router::new`] opens one
//! long-lived [`Session`] per configured model profile, **all against a
//! single shared [`MemoryAccountant`]** whose budget is the device-wide
//! memory limit — cross-model contention flows through the same `S^stop`
//! admission machinery as intra-model contention, and every session's
//! hot-layer pins are eviction victims for every other session's pressure.
//!
//! Requests enter through a cloneable, mpsc-backed [`RouterHandle`]:
//! producers on any thread [`RouterHandle::submit`] a typed
//! [`InferRequest`] and get back a [`Ticket`] (a receiver for the
//! [`InferResponse`]).  The router loop itself runs on the thread that
//! built the engine — the PJRT runtime is not `Send`, so sessions cannot
//! migrate; scheduling work moves to the requests instead of the models.
//!
//! Per-profile scheduling: requests queue per model; the loop serves the
//! queue whose head has the earliest deadline (absent deadlines last,
//! FIFO tie-break), fills a batch within [`RouterConfig::batch_window`],
//! and rejects requests whose deadline already passed before admission
//! (deadline-aware admission) without spending a pass on them.
//!
//! [`Session`]: crate::engine::Session

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
use crate::elastic::{BudgetController, PressureTrace};
use crate::engine::{DecodeState, Engine, Session};
use crate::faults::{FaultInjector, FaultKind};
use crate::memory::MemoryAccountant;
use crate::metrics::{
    prometheus_counter, prometheus_gauge, prometheus_histogram, LatencyRecorder,
};
use crate::planner::Schedule;
use crate::sched::{
    scaled_active_cap, BatchComposer, DropReason, Entry, FairClock, SchedConfig, SchedStats,
    DEFAULT_MAX_ACTIVE,
};
use crate::telemetry::{worker, EvArgs, Telemetry};
use crate::util::json::Value;

/// Wire values of the structured `reason` field carried by rejected
/// responses (and counted per-reason in the summaries).
pub mod reject_reason {
    /// the request's hard deadline passed before admission
    pub const DEADLINE_EXPIRED: &str = "deadline_expired";
    /// shed at admission: queue wait alone already blew the SLO target
    pub const SHED_OVERLOAD: &str = "shed_overload";
    /// the request itself is unservable (unknown profile, oversized
    /// `batch_hint`)
    pub const VALIDATION: &str = "validation";
    /// the serving lane / router was gone before the request ran
    pub const LANE_DEAD: &str = "lane_dead";
    /// an engine pass failed underneath an admitted request
    pub const INTERNAL: &str = "internal";
}

/// Per-reason rejection counters (the structured shed/reject taxonomy).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RejectReasons {
    pub deadline_expired: u64,
    pub shed_overload: u64,
    pub validation: u64,
    pub lane_dead: u64,
    pub internal: u64,
}

impl RejectReasons {
    /// Count one rejection under its wire slug (unknown slugs fold into
    /// `internal` rather than silently vanishing).
    pub fn note(&mut self, reason: &str) {
        match reason {
            reject_reason::DEADLINE_EXPIRED => self.deadline_expired += 1,
            reject_reason::SHED_OVERLOAD => self.shed_overload += 1,
            reject_reason::VALIDATION => self.validation += 1,
            reject_reason::LANE_DEAD => self.lane_dead += 1,
            _ => self.internal += 1,
        }
    }

    pub fn merge(&mut self, other: &RejectReasons) {
        self.deadline_expired += other.deadline_expired;
        self.shed_overload += other.shed_overload;
        self.validation += other.validation;
        self.lane_dead += other.lane_dead;
        self.internal += other.internal;
    }

    pub fn total(&self) -> u64 {
        self.deadline_expired
            + self.shed_overload
            + self.validation
            + self.lane_dead
            + self.internal
    }

    /// (slug, count) pairs in stable order (JSON + Prometheus rendering).
    pub fn iter(&self) -> [(&'static str, u64); 5] {
        [
            (reject_reason::DEADLINE_EXPIRED, self.deadline_expired),
            (reject_reason::SHED_OVERLOAD, self.shed_overload),
            (reject_reason::VALIDATION, self.validation),
            (reject_reason::LANE_DEAD, self.lane_dead),
            (reject_reason::INTERNAL, self.internal),
        ]
    }

    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        for (slug, n) in self.iter() {
            v = v.set(slug, n);
        }
        v
    }
}

/// Router policy + the model fleet.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// One entry per model profile (profiles must be distinct).  Each
    /// entry's `budget` is overridden by the shared [`RouterConfig::budget`].
    pub models: Vec<RunConfig>,
    /// Global memory budget shared by every session (None = unconstrained).
    pub budget: Option<u64>,
    /// Global KV allocation, split evenly across the lanes that run with
    /// `kv_cache` (a lane's own `RunConfig::kv_budget` wins if set).  The
    /// per-lane grant is what keeps one model's long generations from
    /// starving another lane's weights or attention state.
    pub kv_budget: Option<u64>,
    /// Max requests folded into one batch (capped by AOT batch sizes).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch for one profile.
    pub batch_window: Duration,
    /// Memory-pressure trace applied to the SHARED accountant between
    /// batches (`at_pass` counts engine passes across all lanes).  Each
    /// step resizes the one device-wide budget, drives every lane's
    /// eviction chain, rebalances the per-lane KV shares proportionally,
    /// and re-plans the agent count of lanes given a schedule through
    /// [`Router::set_lane_schedule`] — so the EDF scheduler's next
    /// admission sees the new headroom.
    pub memory_trace: Option<PressureTrace>,
    /// Run lanes concurrently: one executor thread + engine per model,
    /// passes overlapping against the one shared budget (see
    /// [`super::lanes::ConcurrentRouter`]).  The serialized [`Router`]
    /// ignores this flag — front-ends branch on it when choosing which
    /// router to build.
    pub concurrent: bool,
    /// Per-lane admission weights for the concurrent governor (one entry
    /// per model; default all-equal).  A lane twice another's weight may
    /// start twice the batches while both are backlogged.
    pub lane_weights: Option<Vec<f64>>,
    /// Total Loading-Agent threads split across PIPELOAD lanes
    /// (weight-proportional, min 1 each) by the concurrent router; elastic
    /// budget steps rebalance the split in proportion to the budget move.
    /// None = every lane keeps its own configured `RunConfig::agents`.
    pub worker_allotment: Option<usize>,
    /// Deterministic fault-injection plan (`--fault-plan` syntax: inline
    /// JSON, a JSON file path, or a compact `kind@pass[xN][:lane][+ms]`
    /// spec).  One plan is shared by the whole fleet — lane-scoped steps
    /// match the lane index — and armed on the shared accountant, every
    /// session's disk, and the loader pools.  None = no injection.
    pub fault_plan: Option<String>,
    /// Crash-restart budget per lane: a lane that dies (injected
    /// `lane_death`, or a supervised worker panic under the concurrent
    /// router) is restarted — recoverable in-flight requests re-queued —
    /// at most this many times; after that the lane is dead and sheds
    /// everything with `lane_dead`.
    pub max_lane_restarts: u32,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            models: Vec::new(),
            budget: None,
            kv_budget: None,
            max_batch: 4,
            batch_window: Duration::from_millis(20),
            memory_trace: None,
            concurrent: false,
            lane_weights: None,
            worker_allotment: None,
            fault_plan: None,
            max_lane_restarts: 2,
        }
    }
}

/// A typed inference request submitted through a [`RouterHandle`].
#[derive(Debug, Clone)]
pub struct InferRequest {
    /// Target model profile (must be one of the router's configured models).
    pub profile: String,
    /// Logical rows this request needs (>= 1); the router sums the folded
    /// requests' hints and picks the smallest AOT batch covering the sum
    /// (folding stops before the sum would overflow the largest AOT batch).
    pub batch_hint: usize,
    /// Deadline relative to submission; a request still queued when its
    /// deadline passes is rejected instead of executed.
    pub deadline: Option<Duration>,
    /// Input seed (None = the session's configured seed stream).
    pub seed: Option<u64>,
    /// Per-request SLO target in ms (continuous lanes): overrides the
    /// lane's `--slo-ms` for overload shedding and attainment scoring.
    pub slo_ms: Option<f64>,
}

impl Default for InferRequest {
    fn default() -> Self {
        InferRequest {
            profile: String::new(),
            batch_hint: 1,
            deadline: None,
            seed: None,
            slo_ms: None,
        }
    }
}

impl InferRequest {
    pub fn new(profile: impl Into<String>) -> InferRequest {
        InferRequest { profile: profile.into(), ..InferRequest::default() }
    }

    /// Wire format (the TCP front-end's line protocol).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj().set("op", "infer").set("profile", self.profile.clone());
        v = v.set("batch_hint", self.batch_hint);
        if let Some(d) = self.deadline {
            v = v.set("deadline_ms", d.as_secs_f64() * 1000.0);
        }
        if let Some(s) = self.seed {
            v = v.set("seed", s);
        }
        if let Some(slo) = self.slo_ms {
            v = v.set("slo_ms", slo);
        }
        v
    }

    pub fn from_json(v: &Value) -> Result<InferRequest> {
        Ok(InferRequest {
            profile: v.req("profile")?.as_str()?.to_string(),
            batch_hint: v.get("batch_hint").map(|b| b.as_usize()).transpose()?.unwrap_or(1),
            deadline: v
                .get("deadline_ms")
                .map(|d| d.as_f64())
                .transpose()?
                // clamp: a hostile/huge value must not panic the server
                .filter(|ms| ms.is_finite())
                .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 1e12) / 1000.0)),
            seed: v.get("seed").map(|s| s.as_f64()).transpose()?.map(|s| s as u64),
            // same hostile-value discipline as deadline_ms: non-finite or
            // non-positive targets are dropped, not panicked on
            slo_ms: v
                .get("slo_ms")
                .map(|s| s.as_f64())
                .transpose()?
                .filter(|ms| ms.is_finite() && *ms > 0.0),
        })
    }
}

/// Outcome of one routed request.
#[derive(Debug, Clone)]
pub struct InferResponse {
    pub id: u64,
    pub profile: String,
    pub ok: bool,
    pub error: Option<String>,
    /// structured rejection taxonomy slug (see [`reject_reason`]); None on
    /// success
    pub reason: Option<String>,
    /// queue + execution latency, submission to response
    pub latency_ms: f64,
    /// AOT batch size the request was folded into (0 on rejection)
    pub batch: usize,
    /// generated tokens (generative profiles)
    pub tokens: usize,
    /// generated token ids for THIS request's rows (generative profiles;
    /// row count = the request's `batch_hint`)
    pub generated_rows: Vec<Vec<i32>>,
    /// shared-accountant peak during the batch's pass window
    pub peak_bytes: u64,
}

impl InferResponse {
    pub(crate) fn rejected(
        id: u64,
        profile: &str,
        enqueued: Instant,
        reason: &'static str,
        err: impl Into<String>,
    ) -> Self {
        InferResponse {
            id,
            profile: profile.to_string(),
            ok: false,
            error: Some(err.into()),
            reason: Some(reason.to_string()),
            latency_ms: enqueued.elapsed().as_secs_f64() * 1000.0,
            batch: 0,
            tokens: 0,
            generated_rows: Vec::new(),
            peak_bytes: 0,
        }
    }

    /// Wire format (the TCP front-end's line protocol).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj()
            .set("ok", self.ok)
            .set("id", self.id)
            .set("profile", self.profile.clone())
            .set("latency_ms", self.latency_ms)
            .set("batch", self.batch)
            .set("tokens", self.tokens)
            .set("peak_bytes", self.peak_bytes);
        if !self.generated_rows.is_empty() {
            let rows: Vec<Value> = self
                .generated_rows
                .iter()
                .map(|row| {
                    Value::Arr(row.iter().map(|&t| Value::int(t as i64)).collect())
                })
                .collect();
            v = v.set("generated_rows", rows);
        }
        if let Some(e) = &self.error {
            v = v.set("error", e.clone());
        }
        if let Some(r) = &self.reason {
            v = v.set("reason", r.clone());
        }
        v
    }

    pub fn from_json(v: &Value) -> Result<InferResponse> {
        Ok(InferResponse {
            id: v.get("id").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0) as u64,
            profile: v
                .get("profile")
                .map(|p| p.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_default(),
            ok: v.req("ok")?.as_bool()?,
            error: v.get("error").map(|e| e.as_str().map(str::to_string)).transpose()?,
            reason: v.get("reason").map(|r| r.as_str().map(str::to_string)).transpose()?,
            latency_ms: v.get("latency_ms").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0),
            batch: v.get("batch").map(|x| x.as_usize()).transpose()?.unwrap_or(0),
            tokens: v.get("tokens").map(|x| x.as_usize()).transpose()?.unwrap_or(0),
            generated_rows: match v.get("generated_rows") {
                Some(rows) => rows
                    .as_arr()?
                    .iter()
                    .map(|row| {
                        row.as_arr()?
                            .iter()
                            .map(|t| Ok(t.as_i64()? as i32))
                            .collect::<Result<Vec<i32>>>()
                    })
                    .collect::<Result<Vec<Vec<i32>>>>()?,
                None => Vec::new(),
            },
            peak_bytes: v.get("peak_bytes").map(|x| x.as_f64()).transpose()?.unwrap_or(0.0)
                as u64,
        })
    }
}

pub(crate) enum Envelope {
    Infer(PendingReq),
    /// live stats snapshot: the router answers with a mid-flight
    /// [`RouterSummary`] built by the SAME code path as the final summary
    Stats(mpsc::Sender<RouterSummary>),
    Shutdown,
}

pub(crate) struct PendingReq {
    pub(crate) id: u64,
    pub(crate) req: InferRequest,
    pub(crate) enqueued: Instant,
    pub(crate) deadline: Option<Instant>,
    pub(crate) reply: mpsc::Sender<InferResponse>,
}

/// Cloneable, `Send` submission handle to a [`Router`]'s queue.  All clones
/// feed the same router; dropping every handle ends the router loop.
#[derive(Clone)]
pub struct RouterHandle {
    pub(crate) tx: mpsc::Sender<Envelope>,
    pub(crate) ids: Arc<AtomicU64>,
}

/// Receiver for one request's [`InferResponse`].
pub struct Ticket {
    pub id: u64,
    rx: mpsc::Receiver<InferResponse>,
}

impl Ticket {
    /// Block until the router responds.  Errors if the router exited
    /// (shutdown or crash) before serving this request.
    pub fn wait(self) -> Result<InferResponse> {
        self.rx.recv().map_err(|_| anyhow!("router exited before responding"))
    }

    /// Non-blocking poll; `Ok(None)` while the request is still
    /// queued/running, `Err` once the router has exited without serving it
    /// (so poll loops terminate instead of spinning forever).
    pub fn poll(&self) -> Result<Option<InferResponse>> {
        match self.rx.try_recv() {
            Ok(r) => Ok(Some(r)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => {
                Err(anyhow!("router exited before responding"))
            }
        }
    }
}

impl RouterHandle {
    /// Enqueue a request; returns a [`Ticket`] for its response.  Errors
    /// only if the router has already exited (a dropped consumer must be a
    /// graceful error, never a panic).
    pub fn submit(&self, req: InferRequest) -> Result<Ticket> {
        let id = self.ids.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let enqueued = Instant::now();
        // checked: Duration::MAX-style deadlines mean "no deadline", not a panic
        let deadline = req.deadline.and_then(|d| enqueued.checked_add(d));
        self.tx
            .send(Envelope::Infer(PendingReq { id, req, enqueued, deadline, reply }))
            .map_err(|_| anyhow!("router is no longer running"))?;
        Ok(Ticket { id, rx })
    }

    /// Submit and block for the response (convenience for benches/tests).
    pub fn submit_wait(&self, req: InferRequest) -> Result<InferResponse> {
        self.submit(req)?.wait()
    }

    /// Mid-flight counters snapshot.  Blocks until the router's loop next
    /// drains its queue (between batches / token boundaries); the snapshot
    /// is produced by the same `summarize()` that builds the final
    /// summary, so live numbers always reconcile with shutdown numbers.
    pub fn stats(&self) -> Result<RouterSummary> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Envelope::Stats(tx))
            .map_err(|_| anyhow!("router is no longer running"))?;
        rx.recv().map_err(|_| anyhow!("router exited before answering stats"))
    }

    /// Ask the router to finish queued work and exit its loop.  Best-effort:
    /// a router that already exited is not an error.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Envelope::Shutdown);
    }
}

/// Per-model serving counters inside a [`RouterSummary`].
#[derive(Debug, Clone)]
pub struct ModelStats {
    pub profile: String,
    pub served: usize,
    pub rejected: usize,
    /// per-reason breakdown of `rejected` (the shed/reject taxonomy)
    pub reject_reasons: RejectReasons,
    pub batches: usize,
    pub latency: LatencyRecorder,
    /// submission-to-admission wait per request (the time a request sat in
    /// this lane's queue before its batch started; rejected requests are
    /// not recorded)
    pub queue_wait: LatencyRecorder,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// decode tokens served by incremental KV passes
    pub kv_inc_passes: u64,
    /// decode tokens recomputed full-prefix after priming
    pub kv_recomputes: u64,
    /// KV blocks reclaimed under `S^stop` pressure
    pub kv_evicted_blocks: u64,
    /// pins + KV blocks this lane lost to elastic budget shrinks
    pub elastic_evictions: u64,
    /// elastic epoch re-plans that changed this lane's agent count
    pub replans: u64,
    /// stages this lane prefetched ahead of their pass / lost unused
    pub prefetched_stages: u64,
    pub prefetch_wasted: u64,
    /// stages this lane executed from device-resident weights
    pub device_cache_hits: u64,
    /// thread spawn/joins this lane's worker pool avoided
    pub spawns_avoided: u64,
    /// continuous batching: requests that joined a running decode
    pub joins: u64,
    /// continuous batching: requests retired from the active set
    pub leaves: u64,
    /// continuous batching: requests shed at admission (SLO already blown)
    pub shed_overload: u64,
    /// % of SLO-targeted served requests that met their target (100 when
    /// nothing carried a target)
    pub slo_attained_pct: f64,
    /// KV prefix sharing: cross-request block share events in this lane
    pub shared_kv_blocks: u64,
    /// KV prefix sharing: bytes deduplicated away in this lane's pool
    pub kv_dedup_bytes: u64,
}

/// Summary of one router run (all models, shared budget).
#[derive(Debug, Clone)]
pub struct RouterSummary {
    pub served: usize,
    /// deadline-expired, unknown-profile, or failed-pass requests
    pub rejected: usize,
    /// per-reason breakdown of `rejected` across all lanes + unroutables
    pub reject_reasons: RejectReasons,
    pub batches: usize,
    pub latency: LatencyRecorder,
    pub throughput_rps: f64,
    /// max per-pass peak of the shared accountant across all batches
    pub peak_bytes: u64,
    pub budget_bytes: Option<u64>,
    pub mean_batch_size: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub kv_inc_passes: u64,
    pub kv_recomputes: u64,
    pub kv_evicted_blocks: u64,
    /// elastic budget steps applied to the shared accountant
    pub budget_steps: u64,
    /// pins + KV blocks evicted by those steps, across all lanes
    pub elastic_evictions: u64,
    /// elastic re-plans that changed some lane's agent count
    pub replans: u64,
    /// cross-pass prefetch totals across lanes
    pub prefetched_stages: u64,
    pub prefetch_wasted: u64,
    /// device-resident cache hits across lanes
    pub device_cache_hits: u64,
    /// worker-pool spawn/joins avoided across lanes
    pub spawns_avoided: u64,
    /// continuous batching: joins/leaves/sheds summed across lanes
    pub joins: u64,
    pub leaves: u64,
    pub shed_overload: u64,
    /// % of SLO-targeted served requests that met their target, across all
    /// continuous lanes (100 when nothing carried a target)
    pub slo_attained_pct: f64,
    /// KV prefix sharing: cross-request block share events across lanes
    pub shared_kv_blocks: u64,
    /// KV prefix sharing: bytes deduplicated away across lanes
    pub kv_dedup_bytes: u64,
    /// generated tokens per wall-clock second across the whole run — the
    /// number continuous batching moves vs the fixed-batch baseline
    pub tokens_per_sec: f64,
    /// queue-wait percentiles across every served request (all lanes)
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p95_ms: f64,
    /// most engine batches in flight at once (1 for the serialized
    /// [`Router`]; >= 2 proves lanes overlapped under the concurrent one)
    pub concurrent_passes_peak: u64,
    /// faults the injection plan fired, fleet-wide (0 without a plan)
    pub faults_injected: u64,
    /// transient load failures absorbed by bounded retry-with-backoff
    pub load_retries: u64,
    /// passes the per-pass watchdog timed out and quiesced
    pub passes_timed_out: u64,
    /// lane crash-restarts performed by the supervisor
    pub lane_restarts: u64,
    /// in-flight requests re-queued across lane restarts (deadlines held)
    pub requeued: u64,
    pub per_model: Vec<ModelStats>,
    /// first engine-pass failure, if any batch failed (full error chain —
    /// individual responses carry their own copies, but callers that drop
    /// their tickets still get the root cause from here)
    pub first_error: Option<String>,
}

impl RouterSummary {
    /// Machine-readable summary (the `serve --json` output).
    pub fn to_json(&self) -> Value {
        let models: Vec<Value> = self
            .per_model
            .iter()
            .map(|m| {
                Value::obj()
                    .set("profile", m.profile.clone())
                    .set("served", m.served)
                    .set("rejected", m.rejected)
                    .set("reject_reasons", m.reject_reasons.to_json())
                    .set("batches", m.batches)
                    .set("latency", m.latency.to_json())
                    .set("queue_wait_p50_ms", m.queue_wait.p50())
                    .set("queue_wait_p95_ms", m.queue_wait.p95())
                    .set("cache_hits", m.cache_hits)
                    .set("cache_misses", m.cache_misses)
                    .set("kv_inc_passes", m.kv_inc_passes)
                    .set("kv_recomputes", m.kv_recomputes)
                    .set("kv_evicted_blocks", m.kv_evicted_blocks)
                    .set("elastic_evictions", m.elastic_evictions)
                    .set("replans", m.replans)
                    .set("prefetched_stages", m.prefetched_stages)
                    .set("prefetch_wasted", m.prefetch_wasted)
                    .set("device_cache_hits", m.device_cache_hits)
                    .set("spawns_avoided", m.spawns_avoided)
                    .set("joins", m.joins)
                    .set("leaves", m.leaves)
                    .set("shed_overload", m.shed_overload)
                    .set("slo_attained_pct", m.slo_attained_pct)
                    .set("shared_kv_blocks", m.shared_kv_blocks)
                    .set("kv_dedup_bytes", m.kv_dedup_bytes)
            })
            .collect();
        let mut v = Value::obj()
            .set("served", self.served)
            .set("rejected", self.rejected)
            .set("reject_reasons", self.reject_reasons.to_json())
            .set("batches", self.batches)
            .set("throughput_rps", self.throughput_rps)
            .set("latency", self.latency.to_json())
            .set("peak_bytes", self.peak_bytes)
            .set("mean_batch_size", self.mean_batch_size)
            .set("cache_hits", self.cache_hits)
            .set("cache_misses", self.cache_misses)
            .set("kv_inc_passes", self.kv_inc_passes)
            .set("kv_recomputes", self.kv_recomputes)
            .set("kv_evicted_blocks", self.kv_evicted_blocks)
            .set("budget_steps", self.budget_steps)
            .set("elastic_evictions", self.elastic_evictions)
            .set("replans", self.replans)
            .set("prefetched_stages", self.prefetched_stages)
            .set("prefetch_wasted", self.prefetch_wasted)
            .set("device_cache_hits", self.device_cache_hits)
            .set("spawns_avoided", self.spawns_avoided)
            .set("joins", self.joins)
            .set("leaves", self.leaves)
            .set("shed_overload", self.shed_overload)
            .set("slo_attained_pct", self.slo_attained_pct)
            .set("shared_kv_blocks", self.shared_kv_blocks)
            .set("kv_dedup_bytes", self.kv_dedup_bytes)
            .set("tokens_per_sec", self.tokens_per_sec)
            .set("queue_wait_p50_ms", self.queue_wait_p50_ms)
            .set("queue_wait_p95_ms", self.queue_wait_p95_ms)
            .set("concurrent_passes_peak", self.concurrent_passes_peak)
            .set("faults_injected", self.faults_injected)
            .set("load_retries", self.load_retries)
            .set("passes_timed_out", self.passes_timed_out)
            .set("lane_restarts", self.lane_restarts)
            .set("requeued", self.requeued)
            .set("models", models);
        if let Some(b) = self.budget_bytes {
            v = v.set("budget_bytes", b);
        }
        if let Some(e) = &self.first_error {
            v = v.set("first_error", e.clone());
        }
        v
    }

    /// Prometheus text exposition of the summary counters (the
    /// `{"op":"metrics"}` TCP surface).  `dropped_events` is the telemetry
    /// bus's drop counter (0 when tracing is off).
    pub fn to_prometheus(&self, dropped_events: u64) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        prometheus_counter(
            &mut out,
            "hermes_served_total",
            "requests served successfully",
            self.served as u64,
        );
        let _ = writeln!(out, "# HELP hermes_rejected_total requests rejected, by reason");
        let _ = writeln!(out, "# TYPE hermes_rejected_total counter");
        for (slug, n) in self.reject_reasons.iter() {
            let _ = writeln!(out, "hermes_rejected_total{{reason=\"{slug}\"}} {n}");
        }
        prometheus_counter(
            &mut out,
            "hermes_batches_total",
            "engine batches run",
            self.batches as u64,
        );
        prometheus_counter(&mut out, "hermes_joins_total", "continuous joins", self.joins);
        prometheus_counter(&mut out, "hermes_leaves_total", "continuous retires", self.leaves);
        prometheus_counter(
            &mut out,
            "hermes_cache_hits_total",
            "hot-layer cache hits",
            self.cache_hits,
        );
        prometheus_counter(
            &mut out,
            "hermes_cache_misses_total",
            "hot-layer cache misses",
            self.cache_misses,
        );
        prometheus_counter(
            &mut out,
            "hermes_kv_inc_passes_total",
            "incremental KV decode passes",
            self.kv_inc_passes,
        );
        prometheus_counter(
            &mut out,
            "hermes_kv_evicted_blocks_total",
            "KV blocks reclaimed under pressure",
            self.kv_evicted_blocks,
        );
        prometheus_counter(
            &mut out,
            "hermes_budget_steps_total",
            "elastic budget steps applied",
            self.budget_steps,
        );
        prometheus_counter(
            &mut out,
            "hermes_elastic_evictions_total",
            "pins + KV blocks evicted by budget steps",
            self.elastic_evictions,
        );
        prometheus_counter(
            &mut out,
            "hermes_prefetched_stages_total",
            "stages prefetched ahead of their pass",
            self.prefetched_stages,
        );
        prometheus_counter(
            &mut out,
            "hermes_device_cache_hits_total",
            "stages served from device-resident weights",
            self.device_cache_hits,
        );
        prometheus_counter(
            &mut out,
            "hermes_kv_dedup_bytes_total",
            "bytes deduplicated by cross-request KV sharing",
            self.kv_dedup_bytes,
        );
        prometheus_counter(
            &mut out,
            "hermes_telemetry_dropped_events_total",
            "telemetry events dropped on full shards",
            dropped_events,
        );
        prometheus_counter(
            &mut out,
            "hermes_faults_injected_total",
            "faults fired by the injection plan",
            self.faults_injected,
        );
        prometheus_counter(
            &mut out,
            "hermes_load_retries_total",
            "transient load failures retried with backoff",
            self.load_retries,
        );
        prometheus_counter(
            &mut out,
            "hermes_passes_timed_out_total",
            "passes quiesced by the per-pass watchdog",
            self.passes_timed_out,
        );
        prometheus_counter(
            &mut out,
            "hermes_lane_restarts_total",
            "lane crash-restarts by the supervisor",
            self.lane_restarts,
        );
        prometheus_counter(
            &mut out,
            "hermes_requeued_total",
            "in-flight requests re-queued across lane restarts",
            self.requeued,
        );
        prometheus_gauge(
            &mut out,
            "hermes_throughput_rps",
            "served requests per second",
            self.throughput_rps,
        );
        prometheus_gauge(
            &mut out,
            "hermes_tokens_per_sec",
            "generated tokens per second",
            self.tokens_per_sec,
        );
        prometheus_gauge(
            &mut out,
            "hermes_peak_bytes",
            "max per-pass peak of the shared accountant",
            self.peak_bytes as f64,
        );
        prometheus_gauge(
            &mut out,
            "hermes_slo_attained_pct",
            "percent of SLO-targeted requests on time",
            self.slo_attained_pct,
        );
        prometheus_gauge(
            &mut out,
            "hermes_queue_wait_p95_ms",
            "p95 submission-to-admission wait",
            self.queue_wait_p95_ms,
        );
        prometheus_histogram(
            &mut out,
            "hermes_latency_ms",
            "end-to-end request latency",
            &self.latency,
        );
        out
    }
}

/// Split a global KV allocation across `lanes` share-taking lanes: an even
/// share each, with the integer-division remainder granted to the first
/// lane, so the granted total always equals the configured budget (a
/// remainder silently dropped would be bytes nobody may use).
pub fn kv_shares(total: Option<u64>, lanes: usize) -> Vec<Option<u64>> {
    let Some(total) = total else { return vec![None; lanes] };
    if lanes == 0 {
        return Vec::new();
    }
    let share = total / lanes as u64;
    let remainder = total % lanes as u64;
    (0..lanes).map(|i| Some(if i == 0 { share + remainder } else { share })).collect()
}

/// Proportional rebalance of one lane's KV share when the shared budget
/// moves from `orig_budget` to `new_budget` (u128 intermediate: byte
/// products overflow u64 for GB-scale budgets).
pub(crate) fn scaled_share(orig_share: u64, orig_budget: u64, new_budget: u64) -> u64 {
    ((orig_share as u128 * new_budget as u128) / (orig_budget.max(1) as u128)) as u64
}

/// Pick the smallest AOT-compiled batch size that fits `n` requests (or
/// the largest available if none fit).
pub fn pick_batch(available: &[usize], n: usize) -> usize {
    let mut sorted: Vec<usize> = available.to_vec();
    sorted.sort_unstable();
    for &b in &sorted {
        if b >= n {
            return b;
        }
    }
    sorted.last().copied().unwrap_or(1)
}

struct ModelLane<'e> {
    profile: String,
    session: Session<'e>,
    queue: VecDeque<PendingReq>,
    /// continuous lanes: iteration-level admission + its pending queue
    /// (fixed lanes queue in `queue` instead)
    composer: Option<BatchComposer<PendingReq>>,
    /// continuous lanes: requests currently decoding, one state each
    active: Vec<ActiveReq>,
    /// configured active cap — the base elastic budget steps scale from
    orig_max_active: usize,
    served: usize,
    rejected: usize,
    /// per-reason breakdown of `rejected`
    reject_reasons: RejectReasons,
    batches: usize,
    /// generated tokens across everything this lane served
    tokens: u64,
    latency: LatencyRecorder,
    queue_wait: LatencyRecorder,
    /// lane-tagged probe into the shared fault plan
    faults: FaultInjector,
    /// crash-restarts consumed (capped by [`RouterConfig::max_lane_restarts`])
    restarts: u32,
    /// restart budget exhausted: everything sheds, new arrivals rejected
    dead: bool,
}

/// One request resident in a continuous lane's active set.
struct ActiveReq {
    id: u64,
    enqueued: Instant,
    /// absolute deadline, enforced at every token boundary (not just at
    /// admission): an expired request retires mid-decode
    deadline: Option<Instant>,
    slo_ms: Option<f64>,
    batch_hint: usize,
    batch: usize,
    reply: mpsc::Sender<InferResponse>,
    /// original request, kept so a lane restart can re-queue it verbatim
    req: InferRequest,
    st: DecodeState,
}

/// The multi-model serving loop.  Owns one session per model; runs on the
/// engine's thread (see module docs).  Build handles before calling
/// [`Router::run`], which consumes the router.
pub struct Router<'e> {
    lanes: Vec<ModelLane<'e>>,
    accountant: MemoryAccountant,
    cfg: RouterConfig,
    /// Some until [`Router::run`] starts; dropped there so the queue
    /// disconnects once every external handle is gone.
    tx: Option<mpsc::Sender<Envelope>>,
    rx: mpsc::Receiver<Envelope>,
    ids: Arc<AtomicU64>,
    /// requests for profiles this router does not serve
    unroutable: usize,
    /// per-reason breakdown of the unroutable rejections (validation /
    /// lane-dead) — lanes keep their own breakdowns
    unroutable_reasons: RejectReasons,
    /// telemetry bus (default off: one atomic load per emit site)
    telemetry: Telemetry,
    /// set when [`Router::run`] starts; `summarize()` measures wall time
    /// from here for both mid-flight and final summaries
    run_started: Option<Instant>,
    /// running aggregates the loop maintains so `summarize()` can be
    /// called mid-flight with the same numbers the final summary sees
    peak: u64,
    total_batches: usize,
    batch_sizes: usize,
    first_error: Option<String>,
    /// per-lane KV share granted from [`RouterConfig::kv_budget`] (None
    /// for non-KV lanes and lanes with their own explicit cap) — the base
    /// the elastic rebalance scales from
    kv_lane_shares: Vec<Option<u64>>,
    /// elastic controller over the shared accountant
    elastic: Option<BudgetController>,
    /// budget steps applied to the shared accountant
    budget_steps: u64,
    /// weighted-fair iteration clock across continuous lanes (one entry
    /// per lane, weights from [`RouterConfig::lane_weights`])
    fair: FairClock,
    /// un-laned base injector for the shared fault plan; lane probes are
    /// `with_lane` clones of this, and its stats aggregate the fleet
    faults: FaultInjector,
}

impl<'e> Router<'e> {
    /// Open one session per configured model, all sharing one accountant
    /// budgeted at [`RouterConfig::budget`], and wire every session's
    /// hot-layer cache as an eviction victim of every other session.
    pub fn new(engine: &'e Engine, cfg: RouterConfig) -> Result<Router<'e>> {
        if cfg.models.is_empty() {
            bail!("router needs at least one model entry");
        }
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        let accountant = MemoryAccountant::new(cfg.budget);
        let faults = match &cfg.fault_plan {
            Some(plan) => FaultInjector::from_arg(plan)?,
            None => FaultInjector::off(),
        };
        // the shared accountant gets the un-laned base injector: an
        // `acquire_fail` step trips whichever lane acquires next
        accountant.set_faults(faults.clone());
        // Per-lane KV grants: the router's kv_budget is divided evenly
        // among the lanes that decode with a KV cache and don't carry
        // their own explicit cap; the division remainder goes to the
        // first such lane so granted bytes always sum to the configured
        // budget.  The per-lane grant is what keeps one lane's long
        // generations from starving another's weights or attention state.
        let share_takers =
            cfg.models.iter().filter(|m| m.kv_cache && m.kv_budget.is_none()).count();
        let mut shares = kv_shares(cfg.kv_budget, share_takers).into_iter();
        let mut kv_lane_shares: Vec<Option<u64>> = Vec::with_capacity(cfg.models.len());
        let mut lanes: Vec<ModelLane<'e>> = Vec::with_capacity(cfg.models.len());
        for model in &cfg.models {
            if lanes.iter().any(|l| l.profile == model.profile) {
                bail!("duplicate model entry '{}'", model.profile);
            }
            // the shared budget outranks any per-entry budget
            let mut run = model.clone();
            run.budget = cfg.budget;
            if run.kv_cache && run.kv_budget.is_none() {
                let share = shares.next().flatten();
                run.kv_budget = share;
                kv_lane_shares.push(share);
            } else {
                kv_lane_shares.push(None);
            }
            let li = lanes.len() as u32;
            let session = engine.open_session_shared(&run, &accountant)?;
            // continuous lanes admit through an iteration-level composer
            let max_active = model.max_active.unwrap_or(DEFAULT_MAX_ACTIVE).max(1);
            let composer = model.continuous.then(|| {
                BatchComposer::new(SchedConfig { max_active, slo_ms: model.slo_ms })
            });
            lanes.push(ModelLane {
                profile: model.profile.clone(),
                session,
                queue: VecDeque::new(),
                composer,
                active: Vec::new(),
                orig_max_active: max_active,
                served: 0,
                rejected: 0,
                reject_reasons: RejectReasons::default(),
                batches: 0,
                tokens: 0,
                latency: LatencyRecorder::new(),
                queue_wait: LatencyRecorder::new(),
                faults: faults.with_lane(li),
                restarts: 0,
                dead: false,
            });
        }
        // cross-model eviction: each session may reclaim the others' pins
        // and, as a last resort, the others' KV blocks
        let caches: Vec<(usize, crate::pipeload::cache::LayerCache)> = lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.session.layer_cache().map(|c| (i, c.clone())))
            .collect();
        let kv_pools: Vec<(usize, crate::kvcache::KvPool)> = lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.session.kv_pool().map(|p| (i, p.clone())))
            .collect();
        let ledgers: Vec<(usize, crate::pipeload::device::DeviceLedger)> = lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.session.device_ledger().map(|d| (i, d)))
            .collect();
        for (i, lane) in lanes.iter_mut().enumerate() {
            for (j, cache) in &caches {
                if *j != i {
                    lane.session.add_eviction_victim(cache.clone());
                }
            }
            for (j, pool) in &kv_pools {
                if *j != i {
                    lane.session.add_kv_eviction_victim(pool.clone());
                }
            }
            // one lane's S^stop pressure may also reclaim another lane's
            // device-resident weight copies (it re-uploads on its next pass)
            for (j, ledger) in &ledgers {
                if *j != i {
                    lane.session.add_device_eviction_victim(ledger.clone());
                }
            }
            // arm the session's own fault seams (disk, loader pool, retry
            // seed) with a lane-tagged probe
            lane.session.set_faults(lane.faults.clone());
        }
        let (tx, rx) = mpsc::channel();
        let elastic = cfg.memory_trace.clone().map(BudgetController::new);
        let mut weights = cfg.lane_weights.clone().unwrap_or_default();
        weights.resize(lanes.len(), 1.0);
        let fair = FairClock::new(&weights);
        Ok(Router {
            lanes,
            accountant,
            cfg,
            tx: Some(tx),
            rx,
            ids: Arc::new(AtomicU64::new(0)),
            unroutable: 0,
            unroutable_reasons: RejectReasons::default(),
            telemetry: Telemetry::off(),
            run_started: None,
            peak: 0,
            total_batches: 0,
            batch_sizes: 0,
            first_error: None,
            kv_lane_shares,
            elastic,
            budget_steps: 0,
            fair,
            faults,
        })
    }

    /// A cloneable submission handle.  Clone freely across threads; the
    /// router exits when every handle is dropped (or on
    /// [`RouterHandle::shutdown`]).  Call before [`Router::run`] (which
    /// consumes the router).
    pub fn handle(&self) -> RouterHandle {
        let tx = self.tx.as_ref().expect("handle() after run()").clone();
        RouterHandle { tx, ids: self.ids.clone() }
    }

    /// Attach a telemetry bus: the router stamps lifecycle events on it
    /// and every lane's session gets a lane-tagged clone (so engine spans
    /// land on the right Chrome `pid`).  Call before [`Router::run`].
    pub fn set_telemetry(&mut self, t: Telemetry) {
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            lane.session.set_telemetry(t.with_lane(i as u32));
        }
        // last writer wins on the shared plan's bus: store the un-laned
        // base (lane-tagged probes re-tag per fire), not a lane clone
        self.faults.set_telemetry(t.clone());
        self.telemetry = t;
    }

    /// The shared accountant (inspect budget/usage/peak from outside).
    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// A clone of the un-laned base fault injector — the TCP front-end
    /// probes connection-drop faults through it, sharing the plan's step
    /// budgets and counters with the lanes.
    pub(crate) fn fault_injector(&self) -> FaultInjector {
        self.faults.clone()
    }

    /// Per-lane KV pool caps currently in force (None for lanes without a
    /// pool or cap).  Useful for asserting that every byte of
    /// [`RouterConfig::kv_budget`] was granted to some lane.
    pub fn lane_kv_budgets(&self) -> Vec<Option<u64>> {
        self.lanes.iter().map(|l| l.session.kv_pool().and_then(|p| p.kv_budget())).collect()
    }

    /// Attach a planner [`Schedule`] to one lane: elastic budget steps
    /// ([`RouterConfig::memory_trace`]) then re-plan that lane's
    /// Loading-Agent count through `Schedule::pick` at every step.  Call
    /// before [`Router::run`] (which consumes the router).  Errors on a
    /// profile this router does not serve.
    pub fn set_lane_schedule(&mut self, profile: &str, schedule: Schedule) -> Result<()> {
        let li = self
            .lane_index(profile)
            .ok_or_else(|| anyhow!("unknown profile '{profile}' (no such lane)"))?;
        self.lanes[li].session.set_schedule(schedule);
        Ok(())
    }

    /// Apply any due memory-trace step (between batches).  `at_pass` is
    /// measured in engine passes summed across all lanes, so a trace means
    /// the same thing whether one lane or five are busy.
    fn poll_elastic(&mut self) {
        if self.elastic.is_none() {
            return;
        }
        let passes: usize = self.lanes.iter().map(|l| l.session.passes_run()).sum();
        if let Some(step) = self.elastic.as_mut().and_then(|e| e.poll(passes)) {
            self.apply_budget_step(step.budget_bytes);
        }
    }

    /// Resize the shared accountant and push the new constraint through
    /// every lane: eviction chains settle (`used <= budget` again), pin
    /// caps re-derive under the liveness rule, KV shares rebalance
    /// proportionally to the budget move, and lanes with schedules
    /// ([`Router::set_lane_schedule`]) re-plan their agent count.  The
    /// next pick/admission — the EDF scheduler's world — runs against the
    /// new headroom.
    fn apply_budget_step(&mut self, new_budget: u64) {
        // fleet-wide feasibility clamp: the shared budget must stay above
        // every lane's floor (largest stage / resident model — see
        // [`Session::budget_floor`]) or the next admission bails instead
        // of adapting
        let floor = self.lanes.iter().map(|l| l.session.budget_floor()).max().unwrap_or(0);
        let new_budget = new_budget.max(floor);
        self.accountant.resize(Some(new_budget));
        self.budget_steps += 1;
        let orig_budget = self.cfg.budget;
        // continuous lanes shrink their active-set cap FIRST: fewer future
        // joiners is the cheap lever, so the eviction chains below only
        // reclaim shared KV blocks for pressure the smaller active set
        // still generates (a grow restores the configured cap)
        if let Some(orig) = orig_budget {
            for lane in &mut self.lanes {
                if let Some(c) = lane.composer.as_mut() {
                    c.set_max_active(scaled_active_cap(lane.orig_max_active, orig, new_budget));
                }
            }
        }
        // per-lane own-eviction baselines: lane A's reclaim chain may take
        // lane B's pins/KV through the victim wiring, and B's own apply
        // window cannot see that
        let before: Vec<u64> =
            self.lanes.iter().map(|l| l.session.own_eviction_count()).collect();
        let mut in_window: Vec<u64> = Vec::with_capacity(self.lanes.len());
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let epoch_evictions = match (self.kv_lane_shares[i], orig_budget) {
                (Some(share), Some(orig)) => {
                    // proportional on shrink, but a grow past the original
                    // budget never raises a lane above its configured share
                    // (`--kv-budget-mb` stays a hard global cap, matching
                    // the single-model path's `orig.min(...)` rule)
                    let cap = scaled_share(share, orig, new_budget).min(share);
                    lane.session.apply_budget_with_kv(new_budget, Some(cap)).evictions
                }
                (Some(share), None) => {
                    lane.session.apply_budget_with_kv(new_budget, Some(share)).evictions
                }
                (None, _) => lane.session.apply_budget(new_budget).evictions,
            };
            in_window.push(epoch_evictions);
        }
        // reconcile: anything a lane lost to the step beyond its own apply
        // window was taken by another lane's chain — credit the owner, so
        // per-model `elastic_evictions` stays truthful lane by lane
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let total = lane.session.own_eviction_count().saturating_sub(before[i]);
            let missed = total.saturating_sub(in_window[i]);
            if missed > 0 {
                lane.session.note_elastic_evictions(missed);
            }
        }
    }

    fn lane_index(&self, profile: &str) -> Option<usize> {
        self.lanes.iter().position(|l| l.profile == profile)
    }

    /// Effective batch cap for a lane: the configured max, clipped to the
    /// largest AOT-compiled batch of that lane's profile.
    fn lane_cap(&self, lane: &ModelLane<'_>) -> usize {
        let largest = lane.session.profile().batches.iter().copied().max().unwrap_or(1);
        self.cfg.max_batch.min(largest).max(1)
    }

    /// Does any lane already hold a full effective batch?  (If so, the
    /// batch-fill window is pointless and the scheduler should run now.)
    fn any_lane_full(&self) -> bool {
        self.lanes.iter().any(|l| l.queue.len() >= self.lane_cap(l))
    }

    /// Earliest deadline among all queued requests, if any.
    fn earliest_deadline(&self) -> Option<Instant> {
        self.lanes.iter().flat_map(|l| l.queue.iter()).filter_map(|p| p.deadline).min()
    }

    /// Drive the serving loop on this thread until every handle is dropped
    /// or a shutdown arrives, then summarize.  Engine passes happen here.
    pub fn run(mut self) -> Result<RouterSummary> {
        self.tx.take(); // only external handles keep the queue open now
        self.run_started = Some(Instant::now());
        let mut open = true;

        loop {
            let backlog = self.lanes.iter().any(|l| {
                !l.queue.is_empty()
                    || !l.active.is_empty()
                    || l.composer.as_ref().map(|c| !c.is_idle()).unwrap_or(false)
            });
            if !backlog {
                if !open {
                    break;
                }
                // idle: park until the next request (or the end of input)
                match self.rx.recv() {
                    Ok(env) => {
                        if !self.enqueue(env) {
                            open = false;
                        }
                        continue;
                    }
                    Err(_) => break,
                }
            }

            // admit everything already queued in the channel (free), then
            // wait out the batch window only if no lane can fill a batch yet
            if open {
                loop {
                    match self.rx.try_recv() {
                        Ok(env) => {
                            if !self.enqueue(env) {
                                open = false;
                                break;
                            }
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            // wake-up sweep (whole queue, not just heads): expired requests
            // parked behind a live head are rejected promptly instead of
            // distorting `earliest_deadline()` windows and wait percentiles
            self.sweep_expired(Instant::now());
            // continuous work never waits out a fill window — joins happen
            // at the next token boundary, and active decodes must not stall
            if open && !self.any_lane_full() && !self.continuous_work() {
                // the window never waits past a queued request's deadline —
                // otherwise any deadline shorter than the window could never
                // be served, even on an idle server
                let mut fill_deadline = Instant::now() + self.cfg.batch_window;
                if let Some(d) = self.earliest_deadline() {
                    fill_deadline = fill_deadline.min(d);
                }
                loop {
                    let now = Instant::now();
                    if now >= fill_deadline {
                        break;
                    }
                    match self.rx.recv_timeout(fill_deadline - now) {
                        Ok(env) => {
                            if !self.enqueue(env) {
                                open = false;
                                break;
                            }
                            // a full batch ends the window early — no point
                            // sleeping out the remainder (the old serve()
                            // fill loop had the same cut-off)
                            if self.any_lane_full() {
                                break;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }

            // memory-pressure steps land here, between batches (and between
            // token boundaries of the continuous lanes)
            self.poll_elastic();

            // continuous lanes run one token-boundary iteration per loop
            // turn, weighted-fair across lanes; fixed lanes only proceed
            // when no continuous lane is runnable this turn
            if let Some(li) = self.pick_continuous_lane() {
                // supervised lane death: the crash surfaces at the token
                // boundary, never inside a pass
                if self.lanes[li].faults.fire(FaultKind::LaneDeath) {
                    self.lane_crash(li, "injected lane death (fault plan)");
                    self.emit_mem_audit();
                    continue;
                }
                self.continuous_iteration(li);
                self.fair.charge(li);
                self.emit_mem_audit();
                continue;
            }

            // earliest-deadline-first across lane heads (FIFO tie-break)
            let Some(li) = self.pick_lane() else { continue };
            if self.lanes[li].faults.fire(FaultKind::LaneDeath) {
                self.lane_crash(li, "injected lane death (fault plan)");
                continue;
            }
            let cap = self.lane_cap(&self.lanes[li]);
            let tel = self.telemetry.with_lane(li as u32);
            let lane = &mut self.lanes[li];
            let avail = lane.session.profile().batches.clone();
            let largest_avail = avail.iter().copied().max().unwrap_or(1);

            // deadline-aware admission: expired requests are rejected
            // without costing a pass.  A batch shares one engine pass (and
            // one input seed), so requests with conflicting explicit seeds
            // are never folded together, and folding stops once the summed
            // batch hints would overflow the largest AOT batch (each
            // request's hint is logical rows it must be granted, not a
            // suggestion to be max()-ed away).
            let mut batch: Vec<PendingReq> = Vec::new();
            let mut hint_rows = 0usize;
            let now = Instant::now();
            while batch.len() < cap {
                let Some(p) = lane.queue.pop_front() else { break };
                if p.deadline.map(|d| d <= now).unwrap_or(false) {
                    lane.rejected += 1;
                    lane.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
                    tel.instant(
                        "shed",
                        worker::DRIVER,
                        EvArgs::req(p.id).with_reason(reject_reason::DEADLINE_EXPIRED),
                    );
                    let resp = InferResponse::rejected(
                        p.id,
                        &lane.profile,
                        p.enqueued,
                        reject_reason::DEADLINE_EXPIRED,
                        "deadline exceeded before admission",
                    );
                    let _ = p.reply.send(resp);
                    continue;
                }
                let rows = p.req.batch_hint.max(1);
                if rows > largest_avail {
                    // a hint is rows the caller must be granted; serving
                    // fewer silently would be a lie — reject like an
                    // expired deadline, without spending a pass
                    lane.rejected += 1;
                    lane.reject_reasons.note(reject_reason::VALIDATION);
                    tel.instant(
                        "shed",
                        worker::DRIVER,
                        EvArgs::req(p.id).with_reason(reject_reason::VALIDATION),
                    );
                    let resp = InferResponse::rejected(
                        p.id,
                        &lane.profile,
                        p.enqueued,
                        reject_reason::VALIDATION,
                        format!("batch_hint {rows} exceeds largest AOT batch {largest_avail}"),
                    );
                    let _ = p.reply.send(resp);
                    continue;
                }
                if let Some(first) = batch.first() {
                    if first.req.seed != p.req.seed || hint_rows + rows > largest_avail {
                        lane.queue.push_front(p);
                        break;
                    }
                }
                hint_rows += rows;
                batch.push(p);
            }
            if batch.is_empty() {
                continue;
            }
            for p in &batch {
                lane.queue_wait.record(now.saturating_duration_since(p.enqueued));
                tel.instant("admit", worker::DRIVER, EvArgs::req(p.id));
            }

            let b = pick_batch(&avail, hint_rows);
            let seed = batch[0]
                .req
                .seed
                .unwrap_or_else(|| lane.session.run_config().seed.wrapping_add(lane.batches as u64));

            // cross-batch prefetch: with more requests queued behind this
            // batch, the final decode pass keeps its loaders prefetching
            // into the NEXT request instead of going idle
            lane.session.set_expect_more(!lane.queue.is_empty());
            // router-level aggregates collect into turn-locals while `lane`
            // mutably borrows `self.lanes`; folded into the `self` fields
            // (where `summarize()` reads them) once the borrow ends
            let mut turn_peak = 0u64;
            let mut turn_folded = 0usize;
            let mut turn_err: Option<String> = None;
            tel.begin("batch", worker::DRIVER, EvArgs::default());
            match lane.session.run_batch(b, seed) {
                Ok((report, out)) => {
                    turn_peak = report.peak_bytes;
                    lane.batches += 1;
                    turn_folded = batch.len();
                    // KV blocks are per-request state: the sequence died
                    // with the pass, so nothing may stay accounted now
                    debug_assert_eq!(
                        lane.session.kv_pool().map(|p| p.used_bytes()).unwrap_or(0),
                        0,
                        "KV blocks must be freed when the ticket resolves"
                    );
                    // each folded request gets its own rows, in fold order
                    let mut row_off = 0usize;
                    for p in &batch {
                        let rows = p.req.batch_hint.max(1);
                        let generated_rows: Vec<Vec<i32>> = out
                            .generated_rows
                            .iter()
                            .skip(row_off)
                            .take(rows)
                            .cloned()
                            .collect();
                        row_off += rows;
                        let latency = p.enqueued.elapsed();
                        lane.latency.record(latency);
                        lane.served += 1;
                        lane.tokens += report.tokens as u64;
                        tel.instant("retire", worker::DRIVER, EvArgs::req(p.id));
                        let _ = p.reply.send(InferResponse {
                            id: p.id,
                            profile: lane.profile.clone(),
                            ok: true,
                            error: None,
                            reason: None,
                            latency_ms: latency.as_secs_f64() * 1000.0,
                            batch: b,
                            tokens: report.tokens,
                            generated_rows,
                            peak_bytes: report.peak_bytes,
                        });
                    }
                }
                Err(e) => {
                    // the session recovered its accounting; fail the batch's
                    // requests and keep serving (no panic, no poisoned loop)
                    turn_err = Some(format!("{e:#}"));
                    for p in &batch {
                        lane.rejected += 1;
                        lane.reject_reasons.note(reject_reason::INTERNAL);
                        tel.instant(
                            "retire",
                            worker::DRIVER,
                            EvArgs::req(p.id).with_reason(reject_reason::INTERNAL),
                        );
                        let _ = p.reply.send(InferResponse::rejected(
                            p.id,
                            &lane.profile,
                            p.enqueued,
                            reject_reason::INTERNAL,
                            format!("pass failed: {e:#}"),
                        ));
                    }
                }
            }
            tel.end("batch", worker::DRIVER);
            self.peak = self.peak.max(turn_peak);
            if turn_folded > 0 {
                self.total_batches += 1;
                self.batch_sizes += turn_folded;
            }
            if self.first_error.is_none() {
                self.first_error = turn_err;
            }
            self.emit_mem_audit();
        }

        // reject anything still sitting in the channel after shutdown
        // (pending stats requests just see their sender dropped)
        while let Ok(env) = self.rx.try_recv() {
            if let Envelope::Infer(p) = env {
                self.unroutable += 1;
                self.unroutable_reasons.note(reject_reason::LANE_DEAD);
                let _ = p.reply.send(InferResponse::rejected(
                    p.id,
                    &p.req.profile,
                    p.enqueued,
                    reject_reason::LANE_DEAD,
                    "router shut down",
                ));
            }
        }

        let summary = self.summarize();
        // settle every lane before reporting: all held bytes (pins,
        // prefetched stages, device copies, KV blocks, resident models)
        // go back to the shared accountant, so `used()` drains to exactly
        // zero — the invariant the chaos soak asserts after recovery
        for lane in &mut self.lanes {
            lane.session.release_all();
        }
        Ok(summary)
    }

    /// Memory-attribution audit sample, emitted between batches and token
    /// boundaries (the serialized loop's quiesced points).  Every lane's
    /// speculative loads are settled first — no in-flight prefetch may
    /// straddle the buffer/ledger hand-off mid-sample — then the lanes'
    /// component sums (pins / device / prefetch / KV / ledger-live) must
    /// equal the shared accountant exactly.  One self-contained event:
    /// `value` = accountant.used(), `bytes` = component sum; the offline
    /// analyzer reports any difference as drift.  Lane sessions skip their
    /// own pass-start audit under a shared accountant, so this is the only
    /// audit source in a serialized multi-lane serve.
    fn emit_mem_audit(&self) {
        if !self.telemetry.is_on() {
            return;
        }
        for lane in &self.lanes {
            lane.session.quiesce_speculative();
        }
        let total: u64 =
            self.lanes.iter().map(|l| l.session.emit_mem_components().total()).sum();
        self.telemetry.counter(
            "mem_audit",
            worker::DRIVER,
            self.accountant.used() as f64,
            EvArgs::default().with_bytes(total),
        );
    }

    /// Snapshot the run's counters into a [`RouterSummary`].  One code
    /// path serves both consumers — the final summary when [`Router::run`]
    /// exits and mid-flight `{"op":"stats"}` snapshots — so live counters
    /// always reconcile with the shutdown numbers.
    fn summarize(&self) -> RouterSummary {
        let wall = self.run_started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        // the shared stats aggregate every lane probe and loader pool
        let fsnap = self.faults.snapshot();
        let mut latency = LatencyRecorder::new();
        let mut queue_wait = LatencyRecorder::new();
        let (mut served, mut rejected) = (0usize, self.unroutable);
        let mut reject_reasons = self.unroutable_reasons;
        let (mut hits, mut misses) = (0u64, 0u64);
        let (mut kv_inc, mut kv_rec, mut kv_evicted) = (0u64, 0u64, 0u64);
        let (mut elastic_ev, mut replans) = (0u64, 0u64);
        let (mut prefetched, mut pf_wasted) = (0u64, 0u64);
        let (mut dev_hits, mut spawns_avoided) = (0u64, 0u64);
        let (mut shared_blocks, mut dedup_bytes, mut total_tokens) = (0u64, 0u64, 0u64);
        let mut sched_total = SchedStats::default();
        let per_model: Vec<ModelStats> = self
            .lanes
            .iter()
            .map(|l| {
                served += l.served;
                rejected += l.rejected;
                reject_reasons.merge(&l.reject_reasons);
                for &ms in l.latency.samples_ms() {
                    latency.record_ms(ms);
                }
                for &ms in l.queue_wait.samples_ms() {
                    queue_wait.record_ms(ms);
                }
                let cs = l.session.cache_stats();
                hits += cs.hits;
                misses += cs.misses;
                let (inc, rec) = l.session.kv_counters();
                let kvp = l.session.kv_pool_stats();
                let es = l.session.elastic_stats();
                kv_inc += inc;
                kv_rec += rec;
                kv_evicted += kvp.evicted_blocks;
                elastic_ev += es.elastic_evictions;
                replans += es.replans;
                let pf = l.session.prefetch_stats();
                let dev = l.session.device_stats();
                let pool_stats = l.session.pool_stats();
                prefetched += pf.prefetched;
                pf_wasted += pf.wasted;
                dev_hits += dev.hits;
                spawns_avoided += pool_stats.spawns_avoided();
                let sc = l.composer.as_ref().map(|c| c.stats()).unwrap_or_default();
                sched_total.merge(&sc);
                shared_blocks += kvp.shared_total;
                dedup_bytes += kvp.dedup_bytes;
                total_tokens += l.tokens;
                ModelStats {
                    profile: l.profile.clone(),
                    served: l.served,
                    rejected: l.rejected,
                    reject_reasons: l.reject_reasons,
                    batches: l.batches,
                    latency: l.latency.clone(),
                    queue_wait: l.queue_wait.clone(),
                    cache_hits: cs.hits,
                    cache_misses: cs.misses,
                    kv_inc_passes: inc,
                    kv_recomputes: rec,
                    kv_evicted_blocks: kvp.evicted_blocks,
                    elastic_evictions: es.elastic_evictions,
                    replans: es.replans,
                    prefetched_stages: pf.prefetched,
                    prefetch_wasted: pf.wasted,
                    device_cache_hits: dev.hits,
                    spawns_avoided: pool_stats.spawns_avoided(),
                    joins: sc.joins,
                    leaves: sc.leaves,
                    shed_overload: sc.shed_overload,
                    slo_attained_pct: sc.slo_attained_pct(),
                    shared_kv_blocks: kvp.shared_total,
                    kv_dedup_bytes: kvp.dedup_bytes,
                }
            })
            .collect();
        RouterSummary {
            served,
            rejected,
            reject_reasons,
            batches: self.total_batches,
            latency,
            throughput_rps: served as f64 / wall.max(1e-9),
            peak_bytes: self.peak,
            budget_bytes: self.cfg.budget,
            mean_batch_size: self.batch_sizes as f64 / self.total_batches.max(1) as f64,
            cache_hits: hits,
            cache_misses: misses,
            kv_inc_passes: kv_inc,
            kv_recomputes: kv_rec,
            kv_evicted_blocks: kv_evicted,
            budget_steps: self.budget_steps,
            elastic_evictions: elastic_ev,
            replans,
            prefetched_stages: prefetched,
            prefetch_wasted: pf_wasted,
            device_cache_hits: dev_hits,
            spawns_avoided,
            joins: sched_total.joins,
            leaves: sched_total.leaves,
            shed_overload: sched_total.shed_overload,
            slo_attained_pct: sched_total.slo_attained_pct(),
            shared_kv_blocks: shared_blocks,
            kv_dedup_bytes: dedup_bytes,
            tokens_per_sec: total_tokens as f64 / wall.max(1e-9),
            queue_wait_p50_ms: queue_wait.p50(),
            queue_wait_p95_ms: queue_wait.p95(),
            // one dispatch thread = at most one pass in flight, ever
            concurrent_passes_peak: if self.total_batches > 0 { 1 } else { 0 },
            faults_injected: fsnap.faults_injected,
            load_retries: fsnap.load_retries,
            passes_timed_out: fsnap.passes_timed_out,
            lane_restarts: fsnap.lane_restarts,
            requeued: fsnap.requeued,
            per_model,
            first_error: self.first_error.clone(),
        }
    }

    /// Queue an envelope; false = shutdown requested.  Unknown profiles are
    /// rejected immediately (graceful error, not a panic).
    fn enqueue(&mut self, env: Envelope) -> bool {
        match env {
            Envelope::Shutdown => false,
            Envelope::Stats(reply) => {
                // dropped receivers are fine: the snapshot is best-effort
                let _ = reply.send(self.summarize());
                true
            }
            Envelope::Infer(p) => {
                match self.lane_index(&p.req.profile) {
                    Some(li) => {
                        if self.lanes[li].dead {
                            let lane = &mut self.lanes[li];
                            lane.rejected += 1;
                            lane.reject_reasons.note(reject_reason::LANE_DEAD);
                            self.telemetry.with_lane(li as u32).instant(
                                "shed",
                                worker::DRIVER,
                                EvArgs::req(p.id).with_reason(reject_reason::LANE_DEAD),
                            );
                            let _ = p.reply.send(InferResponse::rejected(
                                p.id,
                                &lane.profile,
                                p.enqueued,
                                reject_reason::LANE_DEAD,
                                format!(
                                    "lane '{}' is dead (restart budget exhausted)",
                                    lane.profile
                                ),
                            ));
                            return true;
                        }
                        if self.telemetry.is_on() {
                            self.telemetry.with_lane(li as u32).instant(
                                "enqueue",
                                worker::DRIVER,
                                EvArgs::req(p.id),
                            );
                        }
                        let lane = &mut self.lanes[li];
                        match lane.composer.as_mut() {
                            // continuous lanes queue in their composer
                            Some(c) => c.push(Entry {
                                enqueued: p.enqueued,
                                deadline: p.deadline,
                                slo_ms: p.req.slo_ms,
                                payload: p,
                            }),
                            None => lane.queue.push_back(p),
                        }
                    }
                    None => {
                        self.unroutable += 1;
                        self.unroutable_reasons.note(reject_reason::VALIDATION);
                        self.telemetry.instant(
                            "shed",
                            worker::DRIVER,
                            EvArgs::req(p.id).with_reason(reject_reason::VALIDATION),
                        );
                        let resp = InferResponse::rejected(
                            p.id,
                            &p.req.profile,
                            p.enqueued,
                            reject_reason::VALIDATION,
                            format!("unknown profile '{}'", p.req.profile),
                        );
                        let _ = p.reply.send(resp);
                    }
                }
                true
            }
        }
    }

    /// Earliest-deadline-first over non-empty lane heads; requests without
    /// a deadline come after deadlined ones, FIFO by arrival within a tie.
    fn pick_lane(&self) -> Option<usize> {
        self.lanes
            .iter()
            .enumerate()
            .filter_map(|(i, l)| l.queue.front().map(|p| (i, p)))
            .min_by_key(|(_, p)| (p.deadline.is_none(), p.deadline, p.enqueued))
            .map(|(i, _)| i)
    }

    /// Any continuous lane with requests decoding or queued?  (If so the
    /// batch-fill window is skipped — token boundaries must not stall.)
    fn continuous_work(&self) -> bool {
        self.lanes.iter().any(|l| {
            !l.active.is_empty() || l.composer.as_ref().map(|c| !c.is_idle()).unwrap_or(false)
        })
    }

    /// The runnable continuous lane with the smallest weighted virtual
    /// time (see [`FairClock`]); `None` when no continuous lane has work.
    fn pick_continuous_lane(&self) -> Option<usize> {
        let runnable: Vec<bool> = self
            .lanes
            .iter()
            .map(|l| {
                l.composer.is_some()
                    && (!l.active.is_empty()
                        || l.composer.as_ref().map(|c| !c.is_idle()).unwrap_or(false))
            })
            .collect();
        self.fair.pick(&runnable)
    }

    /// Reject every queued request whose deadline has already passed — the
    /// WHOLE queue, not just the head, matching the composer's sweep.
    fn sweep_expired(&mut self, now: Instant) {
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let tel = self.telemetry.with_lane(i as u32);
            let mut kept = VecDeque::with_capacity(lane.queue.len());
            for p in lane.queue.drain(..) {
                if p.deadline.map(|d| d <= now).unwrap_or(false) {
                    lane.rejected += 1;
                    lane.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
                    tel.instant(
                        "shed",
                        worker::DRIVER,
                        EvArgs::req(p.id).with_reason(reject_reason::DEADLINE_EXPIRED),
                    );
                    let _ = p.reply.send(InferResponse::rejected(
                        p.id,
                        &lane.profile,
                        p.enqueued,
                        reject_reason::DEADLINE_EXPIRED,
                        "deadline exceeded before admission",
                    ));
                } else {
                    kept.push_back(p);
                }
            }
            lane.queue = kept;
            if let Some(c) = lane.composer.as_mut() {
                for e in c.sweep_expired(now) {
                    lane.rejected += 1;
                    lane.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
                    tel.instant(
                        "shed",
                        worker::DRIVER,
                        EvArgs::req(e.payload.id).with_reason(reject_reason::DEADLINE_EXPIRED),
                    );
                    let _ = e.payload.reply.send(InferResponse::rejected(
                        e.payload.id,
                        &lane.profile,
                        e.payload.enqueued,
                        reject_reason::DEADLINE_EXPIRED,
                        "deadline exceeded before admission",
                    ));
                }
            }
        }
    }

    /// One continuous-batching iteration for lane `li`: admit joiners at
    /// the token boundary (each primed by its first [`Session::decode_step`]
    /// prefix pass), advance every active request one token, and retire
    /// finished rows immediately — their slot is free at the very next
    /// boundary, and their KV blocks go back to the budget.
    fn continuous_iteration(&mut self, li: usize) {
        let tel = self.telemetry.with_lane(li as u32);
        // router-level aggregates collect into turn-locals while `lane`
        // mutably borrows `self.lanes`; folded back once the borrow ends
        let mut turn_peak = 0u64;
        let mut turn_err: Option<String> = None;
        let now = Instant::now();
        let lane = &mut self.lanes[li];
        let composer = lane.composer.as_mut().expect("continuous lane has a composer");
        let (joins, drops) = composer.admit(now, lane.active.len());
        for (e, why) in drops {
            lane.rejected += 1;
            lane.reject_reasons.note(why.slug());
            tel.instant(
                "shed",
                worker::DRIVER,
                EvArgs::req(e.payload.id).with_reason(why.slug()),
            );
            let msg = match why {
                DropReason::Expired => "deadline exceeded before admission".to_string(),
                DropReason::Overload => format!(
                    "shed: overload (queued {:.1} ms, past the SLO target)",
                    now.duration_since(e.enqueued).as_secs_f64() * 1000.0
                ),
            };
            let _ = e.payload.reply.send(InferResponse::rejected(
                e.payload.id,
                &lane.profile,
                e.payload.enqueued,
                why.slug(),
                msg,
            ));
        }
        let avail = lane.session.profile().batches.clone();
        let largest_avail = avail.iter().copied().max().unwrap_or(1);
        for e in joins {
            let p = e.payload;
            let rows = p.req.batch_hint.max(1);
            if rows > largest_avail {
                composer.unjoin();
                lane.rejected += 1;
                lane.reject_reasons.note(reject_reason::VALIDATION);
                tel.instant(
                    "shed",
                    worker::DRIVER,
                    EvArgs::req(p.id).with_reason(reject_reason::VALIDATION),
                );
                let _ = p.reply.send(InferResponse::rejected(
                    p.id,
                    &lane.profile,
                    p.enqueued,
                    reject_reason::VALIDATION,
                    format!("batch_hint {rows} exceeds largest AOT batch {largest_avail}"),
                ));
                continue;
            }
            lane.queue_wait.record(now.saturating_duration_since(p.enqueued));
            tel.instant("admit", worker::DRIVER, EvArgs::req(p.id));
            // same batch/seed derivation as the fixed path, so a request's
            // tokens are bit-identical between the two schedulers
            let b = pick_batch(&avail, rows);
            let seed = p.req.seed.unwrap_or_else(|| {
                lane.session.run_config().seed.wrapping_add(lane.batches as u64)
            });
            lane.batches += 1;
            tel.instant("prime", worker::DRIVER, EvArgs::req(p.id));
            let st = lane.session.begin_decode(b, seed);
            tel.instant("join", worker::DRIVER, EvArgs::req(p.id));
            lane.active.push(ActiveReq {
                id: p.id,
                enqueued: p.enqueued,
                deadline: p.deadline,
                slo_ms: e.slo_ms,
                batch_hint: rows,
                batch: b,
                reply: p.reply,
                req: p.req,
                st,
            });
        }
        // one token boundary: every active request advances one iteration
        let tok_now = Instant::now();
        let mut i = 0;
        while i < lane.active.len() {
            // hard deadlines bind mid-decode too: a request that expires
            // while decoding retires at this token boundary instead of
            // riding (and charging KV blocks) all the way to done()
            if lane.active[i].deadline.is_some_and(|d| d <= tok_now) {
                let a = lane.active.swap_remove(i);
                composer.retire(a.enqueued, a.slo_ms, tok_now, false);
                lane.rejected += 1;
                lane.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
                tel.instant(
                    "retire",
                    worker::DRIVER,
                    EvArgs::req(a.id).with_reason(reject_reason::DEADLINE_EXPIRED),
                );
                let _ = a.reply.send(InferResponse::rejected(
                    a.id,
                    &lane.profile,
                    a.enqueued,
                    reject_reason::DEADLINE_EXPIRED,
                    "deadline exceeded mid-decode (retired at token boundary)",
                ));
                // `a.st` drops here: the dead decode's KV blocks free
                continue;
            }
            // keep cross-pass prefetch alive while ANY work will follow
            let expect_next = lane.active.len() > 1
                || composer.pending_len() > 0
                || !lane.active[i].st.last_step();
            tel.instant("decode_step", worker::DRIVER, EvArgs::req(lane.active[i].id));
            match lane.session.decode_step(&mut lane.active[i].st, expect_next) {
                Err(e) => {
                    if turn_err.is_none() {
                        turn_err = Some(format!("{e:#}"));
                    }
                    let a = lane.active.swap_remove(i);
                    composer.retire(a.enqueued, a.slo_ms, Instant::now(), false);
                    lane.rejected += 1;
                    lane.reject_reasons.note(reject_reason::INTERNAL);
                    tel.instant(
                        "retire",
                        worker::DRIVER,
                        EvArgs::req(a.id).with_reason(reject_reason::INTERNAL),
                    );
                    let _ = a.reply.send(InferResponse::rejected(
                        a.id,
                        &lane.profile,
                        a.enqueued,
                        reject_reason::INTERNAL,
                        format!("pass failed: {e:#}"),
                    ));
                }
                Ok(()) if lane.active[i].st.done() => {
                    let a = lane.active.swap_remove(i);
                    let (report, out) = lane.session.finish_decode(a.st);
                    turn_peak = turn_peak.max(report.peak_bytes);
                    let done = Instant::now();
                    composer.retire(a.enqueued, a.slo_ms, done, true);
                    let latency = done.duration_since(a.enqueued);
                    lane.latency.record(latency);
                    lane.served += 1;
                    lane.tokens += report.tokens as u64;
                    tel.instant("retire", worker::DRIVER, EvArgs::req(a.id));
                    tel.instant("leave", worker::DRIVER, EvArgs::req(a.id));
                    let generated_rows: Vec<Vec<i32>> =
                        out.generated_rows.iter().take(a.batch_hint).cloned().collect();
                    let _ = a.reply.send(InferResponse {
                        id: a.id,
                        profile: lane.profile.clone(),
                        ok: true,
                        error: None,
                        reason: None,
                        latency_ms: latency.as_secs_f64() * 1000.0,
                        batch: a.batch,
                        tokens: report.tokens,
                        generated_rows,
                        peak_bytes: report.peak_bytes,
                    });
                }
                Ok(()) => i += 1,
            }
        }
        composer.note_iteration();
        self.peak = self.peak.max(turn_peak);
        if self.first_error.is_none() {
            self.first_error = turn_err;
        }
    }

    /// Supervise a crashed lane (an injected `lane_death` here; the
    /// concurrent router routes real worker panics through the same
    /// policy).  In-flight decode states are dropped first — their KV
    /// sequences release while the pool still knows them — then the
    /// session's accounting is settled (`recover_after_abort`).  With
    /// restart budget left the lane restarts: requests whose deadlines
    /// still hold are re-queued through normal admission (original
    /// enqueue time and deadline ride along, keeping EDF order and expiry
    /// honest), the rest shed `lane_dead`.  Once the budget is exhausted
    /// the lane is dead: everything in flight and queued sheds, and
    /// `enqueue` rejects new arrivals for this profile from then on.
    fn lane_crash(&mut self, li: usize, why: &str) {
        let tel = self.telemetry.with_lane(li as u32);
        let max_restarts = self.cfg.max_lane_restarts;
        let now = Instant::now();
        let lane = &mut self.lanes[li];
        let restart = lane.restarts < max_restarts;
        let actives: Vec<ActiveReq> = lane.active.drain(..).collect();
        let mut requeue: Vec<PendingReq> = Vec::new();
        for a in actives {
            // the decode died with the lane either way
            if let Some(c) = lane.composer.as_mut() {
                c.retire(a.enqueued, a.slo_ms, now, false);
            }
            let holds = a.deadline.map(|d| d > now).unwrap_or(true);
            if restart && holds {
                lane.faults.stats().note_requeued();
                requeue.push(PendingReq {
                    id: a.id,
                    req: a.req,
                    enqueued: a.enqueued,
                    deadline: a.deadline,
                    reply: a.reply,
                });
            } else {
                lane.rejected += 1;
                lane.reject_reasons.note(reject_reason::LANE_DEAD);
                tel.instant(
                    "shed",
                    worker::DRIVER,
                    EvArgs::req(a.id).with_reason(reject_reason::LANE_DEAD),
                );
                let _ = a.reply.send(InferResponse::rejected(
                    a.id,
                    &lane.profile,
                    a.enqueued,
                    reject_reason::LANE_DEAD,
                    format!("{why}; in-flight decode lost"),
                ));
            }
            // `a.st` (the dead decode state) drops here
        }
        // the crash aborted whatever the session held mid-flight: reset
        // its stores and bring the shared accounting back to truth
        lane.session.recover_after_abort();
        if restart {
            lane.restarts += 1;
            lane.faults.stats().note_lane_restart();
            tel.instant(
                "lane_restart",
                worker::DRIVER,
                EvArgs::default().with_reason("supervisor"),
            );
            for p in requeue {
                match lane.composer.as_mut() {
                    Some(c) => c.push(Entry {
                        enqueued: p.enqueued,
                        deadline: p.deadline,
                        slo_ms: p.req.slo_ms,
                        payload: p,
                    }),
                    None => lane.queue.push_back(p),
                }
            }
        } else {
            lane.dead = true;
            let mut shed: Vec<PendingReq> = lane.queue.drain(..).collect();
            if let Some(c) = lane.composer.as_mut() {
                shed.extend(c.drain_pending().into_iter().map(|e| e.payload));
            }
            for p in shed {
                lane.rejected += 1;
                lane.reject_reasons.note(reject_reason::LANE_DEAD);
                tel.instant(
                    "shed",
                    worker::DRIVER,
                    EvArgs::req(p.id).with_reason(reject_reason::LANE_DEAD),
                );
                let _ = p.reply.send(InferResponse::rejected(
                    p.id,
                    &lane.profile,
                    p.enqueued,
                    reject_reason::LANE_DEAD,
                    format!("{why}; lane restart budget exhausted"),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_shares_pin_total_granted_to_budget() {
        // the old even split dropped `total % lanes` bytes on the floor;
        // the remainder now lands on the first lane so the sum is exact
        for (total, lanes) in [(1001u64, 2usize), (10, 3), (7, 7), (5, 8), (1 << 20, 3)] {
            let shares = kv_shares(Some(total), lanes);
            assert_eq!(shares.len(), lanes);
            let granted: u64 = shares.iter().map(|s| s.unwrap()).sum();
            assert_eq!(granted, total, "total={total} lanes={lanes}");
            // even up to the remainder: no lane beats lane 0
            for s in &shares[1..] {
                assert!(s.unwrap() <= shares[0].unwrap());
            }
        }
        assert_eq!(kv_shares(None, 3), vec![None, None, None]);
        assert!(kv_shares(Some(10), 0).is_empty());
    }

    #[test]
    fn scaled_share_is_proportional_and_overflow_safe() {
        assert_eq!(scaled_share(512, 1024, 512), 256);
        assert_eq!(scaled_share(512, 1024, 2048), 1024);
        // GB-scale products must not overflow u64
        let gb = 1u64 << 30;
        assert_eq!(scaled_share(40 * gb, 80 * gb, 60 * gb), 30 * gb);
        // degenerate original budget: no division by zero
        assert_eq!(scaled_share(100, 0, 50), 5000);
    }

    #[test]
    fn pick_batch_smallest_fitting() {
        assert_eq!(pick_batch(&[1, 4], 1), 1);
        assert_eq!(pick_batch(&[1, 4], 2), 4);
        assert_eq!(pick_batch(&[1, 4], 4), 4);
        assert_eq!(pick_batch(&[1, 4], 9), 4); // overflow -> largest
        assert_eq!(pick_batch(&[], 3), 1);
    }

    #[test]
    fn request_json_roundtrip() {
        let req = InferRequest {
            profile: "tiny-bert".into(),
            batch_hint: 2,
            deadline: Some(Duration::from_millis(1500)),
            seed: Some(7),
            slo_ms: Some(250.0),
        };
        let v = req.to_json();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "infer");
        let back = InferRequest::from_json(&v).unwrap();
        assert_eq!(back.profile, "tiny-bert");
        assert_eq!(back.batch_hint, 2);
        assert_eq!(back.seed, Some(7));
        assert_eq!(back.slo_ms, Some(250.0));
        assert!((back.deadline.unwrap().as_secs_f64() - 1.5).abs() < 1e-9);
        // hostile SLO targets are dropped, not panicked on
        let hostile = Value::obj()
            .set("op", "infer")
            .set("profile", "m")
            .set("slo_ms", f64::NAN);
        assert_eq!(InferRequest::from_json(&hostile).unwrap().slo_ms, None);
    }

    #[test]
    fn response_json_roundtrip() {
        let resp = InferResponse {
            id: 3,
            profile: "tiny-gpt".into(),
            ok: true,
            error: None,
            reason: None,
            latency_ms: 12.5,
            batch: 4,
            tokens: 8,
            generated_rows: vec![vec![7, 9], vec![3, 5]],
            peak_bytes: 1024,
        };
        let back = InferResponse::from_json(&resp.to_json()).unwrap();
        assert!(back.ok);
        assert_eq!(back.id, 3);
        assert_eq!(back.batch, 4);
        assert_eq!(back.tokens, 8);
        assert_eq!(back.peak_bytes, 1024);
        assert_eq!(back.generated_rows, vec![vec![7, 9], vec![3, 5]]);
        let rej =
            InferResponse::rejected(9, "m", Instant::now(), reject_reason::VALIDATION, "nope");
        let back = InferResponse::from_json(&rej.to_json()).unwrap();
        assert!(!back.ok);
        assert_eq!(back.error.as_deref(), Some("nope"));
        assert_eq!(back.reason.as_deref(), Some("validation"));
        assert!(back.generated_rows.is_empty());
    }

    #[test]
    fn reject_reasons_note_merge_total() {
        let mut a = RejectReasons::default();
        a.note(reject_reason::DEADLINE_EXPIRED);
        a.note(reject_reason::SHED_OVERLOAD);
        a.note(reject_reason::SHED_OVERLOAD);
        a.note("something-unknown"); // folds into internal
        let mut b = RejectReasons::default();
        b.note(reject_reason::VALIDATION);
        b.note(reject_reason::LANE_DEAD);
        a.merge(&b);
        assert_eq!(a.deadline_expired, 1);
        assert_eq!(a.shed_overload, 2);
        assert_eq!(a.validation, 1);
        assert_eq!(a.lane_dead, 1);
        assert_eq!(a.internal, 1);
        assert_eq!(a.total(), 6);
        let j = a.to_json();
        assert_eq!(j.get("shed_overload").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("validation").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn default_router_config_sane() {
        let c = RouterConfig::default();
        assert!(c.max_batch >= 1);
        assert!(c.batch_window > Duration::ZERO);
    }
}
