//! Concurrent multi-lane serving: per-lane executor threads over ONE
//! shared memory budget.
//!
//! The serialized [`Router`](super::Router) runs every lane's passes on a
//! single thread — under multi-tenant traffic, each model's latency is
//! every other model's queue.  [`ConcurrentRouter`] splits that dispatch
//! thread into **one executor per model lane**: the PJRT runtime is not
//! `Send`, so each lane builds its own [`Engine`] and opens its session
//! against the one shared [`MemoryAccountant`] on its own thread, and
//! passes from different lanes overlap.
//!
//! What keeps the overlap sound (the PR 6 refactor spine):
//!
//! * every in-flight pass charges a per-pass [`PassLedger`] on its lane's
//!   [`OrderedGate`], so a failed pass drains exactly its own bytes while
//!   peers keep flying (`crate::memory`);
//! * cross-lane eviction chains (pins / KV blocks / device copies)
//!   serialize on one fleet-wide [`ReclaimToken`], and every gate is
//!   peered with every other so a free on lane A wakes an admission
//!   parked on lane B (`crate::pipeload::gate`);
//! * a [`LaneGovernor`] applies weighted fair admission across backlogged
//!   lanes (start-time virtual clocks) and records
//!   `concurrent_passes_peak`;
//! * elastic budget steps are fleet-wide: whichever lane's pass crosses
//!   the trace boundary resizes the shared accountant once and
//!   broadcasts per-lane KV caps + worker-pool slices, which each lane
//!   applies at its own next pass boundary — no lane ever stops.
//!
//! Tokens stay bit-identical per lane versus the serialized router: the
//! batch-folding rules, seeds (`cfg.seed + lane_batches`), and the argmax
//! funnel are unchanged — concurrency only moves *when* a lane's batch
//! runs, never what it computes.
//!
//! [`PassLedger`]: crate::memory::PassLedger

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicU64;
use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::router::{
    kv_shares, pick_batch, reject_reason, scaled_share, Envelope, InferRequest, InferResponse,
    ModelStats, PendingReq, RejectReasons, RouterConfig, RouterHandle, RouterSummary,
};
use crate::config::{Mode, Paths, RunConfig};
use crate::elastic::BudgetController;
use crate::engine::{DecodeState, Engine, Session};
use crate::faults::{FaultInjector, FaultKind, FaultStatsSnapshot};
use crate::kvcache::KvPool;
use crate::memory::MemoryAccountant;
use crate::metrics::LatencyRecorder;
use crate::sched::{
    scaled_active_cap, BatchComposer, DropReason, Entry, SchedConfig, SchedStats,
    DEFAULT_MAX_ACTIVE,
};
use crate::pipeload::cache::LayerCache;
use crate::pipeload::device::DeviceLedger;
use crate::pipeload::gate::{OrderedGate, ReclaimToken};
use crate::telemetry::{worker, EvArgs, Telemetry};

/// Virtual-time slack for the weighted admission check: a lane may start
/// while it is at most this many weighted batches ahead of the most
/// behind *backlogged* peer.  1.0 keeps equal-weight lanes fully
/// concurrent (neither ever waits a whole batch on the other) while still
/// throttling a lane that races ahead of a backlogged peer.
const FAIR_SLACK: f64 = 1.0;

/// Weighted fair admission across concurrently serving lanes.
///
/// Each lane keeps a start-time virtual clock advanced by `1/weight` per
/// batch it starts.  [`LaneGovernor::admit`] blocks while this lane's
/// clock is more than [`FAIR_SLACK`] ahead of the slowest *waiting* peer
/// — the peer with the smallest clock among waiters is always admissible,
/// so the scheme cannot deadlock (a timeout backstops stale flags
/// anyway).  Idle lanes never throttle busy ones: only lanes currently
/// blocked in `admit` count as backlogged.
pub(crate) struct LaneGovernor {
    weights: Vec<f64>,
    state: Mutex<GovState>,
    cv: Condvar,
}

struct GovState {
    /// weighted batches started per lane (the virtual clock)
    vtime: Vec<f64>,
    /// lane is currently blocked in `admit` (backlogged)
    waiting: Vec<bool>,
    in_flight: usize,
    peak: usize,
    total_batches: u64,
}

/// May a lane with clock `me` start ahead of the most behind waiting
/// peer at clock `min_waiting_other` (infinity when no peer waits)?
fn may_start(me: f64, min_waiting_other: f64) -> bool {
    me <= min_waiting_other + FAIR_SLACK
}

impl LaneGovernor {
    fn new(weights: Vec<f64>) -> LaneGovernor {
        let n = weights.len();
        LaneGovernor {
            weights,
            state: Mutex::new(GovState {
                vtime: vec![0.0; n],
                waiting: vec![false; n],
                in_flight: 0,
                peak: 0,
                total_batches: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until this lane may start a batch, then charge its clock.
    /// Poison-tolerant: a lane that panicked mid-batch must not wedge its
    /// siblings' fair-share admission.
    fn admit(&self, lane: usize) {
        let mut s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        s.waiting[lane] = true;
        loop {
            let mut min_other = f64::INFINITY;
            for j in 0..s.vtime.len() {
                if j != lane && s.waiting[j] {
                    min_other = min_other.min(s.vtime[j]);
                }
            }
            if may_start(s.vtime[lane], min_other) {
                break;
            }
            // timeout backstop: a peer that left `admit` without a
            // wakeup (shutdown) must not park this lane forever
            let (guard, _) = self
                .cv
                .wait_timeout(s, Duration::from_millis(2))
                .unwrap_or_else(PoisonError::into_inner);
            s = guard;
        }
        s.waiting[lane] = false;
        s.vtime[lane] += 1.0 / self.weights[lane];
        s.in_flight += 1;
        if s.in_flight > s.peak {
            s.peak = s.in_flight;
        }
        s.total_batches += 1;
        drop(s);
        self.cv.notify_all();
    }

    /// The lane's batch finished (success or failure).  Saturating: a
    /// supervisor-restarted lane may settle a batch the crash already
    /// unwound past.
    fn done(&self) {
        let mut s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        s.in_flight = s.in_flight.saturating_sub(1);
        drop(s);
        self.cv.notify_all();
    }

    /// Most batches in flight at once over the run.
    fn peak(&self) -> usize {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).peak
    }

    #[cfg(test)]
    fn snapshot(&self) -> (usize, usize, u64) {
        let s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        (s.in_flight, s.peak, s.total_batches)
    }
}

/// Split a worker-pool allotment across lanes proportionally to their
/// weights, at least 1 each; any floor-division remainder goes to the
/// heaviest lanes first so granted threads sum to (at least) the target.
fn split_allotment(total: usize, weights: &[f64]) -> Vec<usize> {
    let n = weights.len();
    let sum: f64 = weights.iter().sum();
    let mut slices: Vec<usize> = weights
        .iter()
        .map(|w| ((total as f64 * w / sum.max(f64::MIN_POSITIVE)).floor() as usize).max(1))
        .collect();
    let used: usize = slices.iter().sum();
    if used < total {
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            weights[b].partial_cmp(&weights[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut left = total - used;
        let mut k = 0usize;
        while left > 0 {
            slices[order[k % n]] += 1;
            left -= 1;
            k += 1;
        }
    }
    slices
}

/// Control/request messages feeding one lane's executor.  Requests and
/// the final `Quit` come from the dispatcher; `Budget` broadcasts come
/// from whichever lane detected a due elastic step.
enum LaneMsg {
    Req(PendingReq),
    /// fleet budget step: the shared accountant is already resized; this
    /// lane re-derives its caps (and agent slice) at its pass boundary
    Budget { budget: u64, kv_cap: Option<u64>, agents: Option<usize> },
    /// live stats probe: the lane answers with a mid-flight snapshot at
    /// its next pass / token boundary
    Stats(mpsc::Sender<LaneSnapshot>),
    Quit,
}

/// Mid-flight (or exit-time) per-lane serving snapshot — everything the
/// fleet aggregation needs beyond the per-model counters themselves.
struct LaneSnapshot {
    batch_sizes: usize,
    peak: u64,
    tokens: u64,
    sched: SchedStats,
    first_error: Option<String>,
    stats: ModelStats,
}

/// The `Send` handles one lane publishes so every other lane can wire it
/// as an eviction victim and a gate peer.
#[derive(Clone)]
struct LaneWiring {
    gate: OrderedGate,
    cache: Option<LayerCache>,
    kv: Option<KvPool>,
    device: Option<DeviceLedger>,
    floor: u64,
}

/// Peer handles delivered to a lane once every session has opened.
struct WirePack {
    peers: Vec<LaneWiring>,
}

/// Everything one lane's executor thread needs at spawn.
struct LaneSeed {
    idx: usize,
    run: RunConfig,
    rx: mpsc::Receiver<LaneMsg>,
    up_tx: mpsc::Sender<Result<LaneWiring>>,
    down_rx: mpsc::Receiver<WirePack>,
    ready_tx: mpsc::Sender<()>,
    telemetry: Telemetry,
    /// lane-tagged probe into the shared fault plan; stats aggregate
    /// fleet-wide through the shared counters
    faults: FaultInjector,
    /// crash-restarts this lane's supervisor may spend before declaring
    /// the lane dead and shedding its backlog
    max_restarts: u32,
}

/// Fleet-wide elastic control shared by every lane executor.  The lane
/// whose pass crosses a trace boundary applies the step: one accountant
/// resize (clamped to the fleet feasibility floor), then a per-lane
/// broadcast of rebalanced KV caps and worker slices.
struct FleetElastic {
    accountant: MemoryAccountant,
    orig_budget: Option<u64>,
    kv_shares: Vec<Option<u64>>,
    weights: Vec<f64>,
    worker_allotment: Option<usize>,
    txs: Vec<mpsc::Sender<LaneMsg>>,
    state: Mutex<FleetState>,
}

struct FleetState {
    ctrl: Option<BudgetController>,
    /// engine passes summed across all lanes (the trace's `at_pass` unit,
    /// same meaning as the serialized router's)
    passes: usize,
    steps: u64,
    /// max per-lane budget floor — set once every session has opened
    floor: u64,
}

impl FleetElastic {
    fn set_floor(&self, floor: u64) {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).floor = floor;
    }

    fn steps(&self) -> u64 {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).steps
    }

    /// Count a lane's finished batch (`pass_delta` engine passes) and
    /// apply any due trace step fleet-wide.
    fn after_batch(&self, pass_delta: usize) {
        let mut s = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        if s.ctrl.is_none() {
            return;
        }
        s.passes += pass_delta;
        let passes = s.passes;
        let Some(step) = s.ctrl.as_mut().and_then(|c| c.poll(passes)) else { return };
        let new_budget = step.budget_bytes.max(s.floor);
        // one resize for the whole fleet; every lane's next admission
        // sees the new headroom immediately, caps re-derive per lane at
        // its own pass boundary (the Budget broadcast below)
        self.accountant.resize(Some(new_budget));
        s.steps += 1;
        for (i, tx) in self.txs.iter().enumerate() {
            let kv_cap = match (self.kv_shares[i], self.orig_budget) {
                // proportional on shrink; a grow past the original budget
                // never raises a lane above its configured share (same
                // rule as the serialized router)
                (Some(share), Some(orig)) => {
                    Some(scaled_share(share, orig, new_budget).min(share))
                }
                (Some(share), None) => Some(share),
                (None, _) => None,
            };
            let agents = self.agent_slices(new_budget).map(|sl| sl[i]);
            // a lane that already exited just drops the message
            let _ = tx.send(LaneMsg::Budget { budget: new_budget, kv_cap, agents });
        }
    }

    /// Worker-pool slices under the new budget: the allotment scales with
    /// the budget move (never below one thread per lane), split by weight.
    fn agent_slices(&self, new_budget: u64) -> Option<Vec<usize>> {
        let total = self.worker_allotment?;
        let scaled = match self.orig_budget {
            Some(orig) if orig > 0 => {
                (((total as u128 * new_budget as u128) / orig as u128) as usize)
                    .max(self.weights.len())
            }
            _ => total,
        };
        Some(split_allotment(scaled, &self.weights))
    }
}

/// What one lane's executor hands back when it exits.
struct LaneOutcome {
    profile: String,
    /// construction aborted before serving (session open failed here or
    /// in a peer lane)
    aborted: bool,
    served: usize,
    rejected: usize,
    reject_reasons: RejectReasons,
    batches: usize,
    batch_sizes: usize,
    peak: u64,
    /// generated tokens across everything this lane served
    tokens: u64,
    /// continuous-batching ledger (zero for fixed-batch lanes)
    sched: SchedStats,
    latency: LatencyRecorder,
    queue_wait: LatencyRecorder,
    first_error: Option<String>,
    stats: Option<ModelStats>,
}

impl LaneOutcome {
    fn new(profile: String) -> LaneOutcome {
        LaneOutcome {
            profile,
            aborted: false,
            served: 0,
            rejected: 0,
            reject_reasons: RejectReasons::default(),
            batches: 0,
            batch_sizes: 0,
            peak: 0,
            tokens: 0,
            sched: SchedStats::default(),
            latency: LatencyRecorder::new(),
            queue_wait: LatencyRecorder::new(),
            first_error: None,
            stats: None,
        }
    }

    fn aborted(mut self) -> LaneOutcome {
        self.aborted = true;
        self
    }
}

/// The concurrent multi-model router: one executor thread + [`Engine`]
/// per lane, one shared budget, overlapping passes.  Submission-side API
/// matches the serialized [`Router`](super::Router): build, take
/// [`ConcurrentRouter::handle`]s, then [`ConcurrentRouter::run`].
///
/// Unlike the serialized router, sessions open inside [`ConcurrentRouter::run`]
/// (on their executor threads — the PJRT runtime cannot migrate), so
/// per-model config errors surface from `run()`, not `new()`.
pub struct ConcurrentRouter {
    cfg: RouterConfig,
    paths: Paths,
    runs: Vec<RunConfig>,
    kv_lane_shares: Vec<Option<u64>>,
    weights: Vec<f64>,
    accountant: MemoryAccountant,
    tx: Option<mpsc::Sender<Envelope>>,
    rx: mpsc::Receiver<Envelope>,
    ids: Arc<AtomicU64>,
    telemetry: Telemetry,
    /// un-laned base injector for the fleet's fault plan; lane executors
    /// probe through `with_lane` clones, the shared accountant through
    /// this base (an `acquire_fail` step trips whichever lane acquires
    /// next), and the shared counters aggregate fleet-wide
    faults: FaultInjector,
}

impl ConcurrentRouter {
    /// Validate the fleet config and resolve per-lane run configs (shared
    /// budget override, KV shares, initial worker-pool slices).  `paths`
    /// locates the artifacts each lane's own engine loads.
    pub fn new(paths: Paths, cfg: RouterConfig) -> Result<ConcurrentRouter> {
        if cfg.models.is_empty() {
            bail!("router needs at least one model entry");
        }
        if cfg.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        let n = cfg.models.len();
        for (i, m) in cfg.models.iter().enumerate() {
            if cfg.models[..i].iter().any(|o| o.profile == m.profile) {
                bail!("duplicate model entry '{}'", m.profile);
            }
        }
        let weights = match &cfg.lane_weights {
            Some(w) => {
                if w.len() != n {
                    bail!("lane_weights has {} entries for {} models", w.len(), n);
                }
                if w.iter().any(|x| !x.is_finite() || *x <= 0.0) {
                    bail!("lane weights must be positive and finite");
                }
                w.clone()
            }
            None => vec![1.0; n],
        };
        if cfg.worker_allotment == Some(0) {
            bail!("worker_allotment must be >= 1");
        }
        let accountant = MemoryAccountant::new(cfg.budget);
        let faults = match &cfg.fault_plan {
            Some(plan) => FaultInjector::from_arg(plan)?,
            None => FaultInjector::off(),
        };
        accountant.set_faults(faults.clone());
        // per-lane KV grants: identical split rule to the serialized router
        let share_takers =
            cfg.models.iter().filter(|m| m.kv_cache && m.kv_budget.is_none()).count();
        let mut shares = kv_shares(cfg.kv_budget, share_takers).into_iter();
        let slices = cfg.worker_allotment.map(|w| split_allotment(w, &weights));
        let mut kv_lane_shares: Vec<Option<u64>> = Vec::with_capacity(n);
        let mut runs: Vec<RunConfig> = Vec::with_capacity(n);
        for (i, model) in cfg.models.iter().enumerate() {
            let mut run = model.clone();
            run.budget = cfg.budget;
            if run.kv_cache && run.kv_budget.is_none() {
                let share = shares.next().flatten();
                run.kv_budget = share;
                kv_lane_shares.push(share);
            } else {
                kv_lane_shares.push(None);
            }
            if let Some(s) = &slices {
                if run.mode == Mode::PipeLoad {
                    run.agents = s[i];
                }
            }
            runs.push(run);
        }
        let (tx, rx) = mpsc::channel();
        Ok(ConcurrentRouter {
            cfg,
            paths,
            runs,
            kv_lane_shares,
            weights,
            accountant,
            tx: Some(tx),
            rx,
            ids: Arc::new(AtomicU64::new(0)),
            telemetry: Telemetry::off(),
            faults,
        })
    }

    /// Attach a telemetry bus.  Each lane executor gets a lane-tagged
    /// clone at spawn and threads it into its session, so trace rows are
    /// `pid = lane`, `tid = worker` fleet-wide.
    pub fn set_telemetry(&mut self, t: Telemetry) {
        self.telemetry = t;
    }

    /// A cloneable submission handle (same type the serialized router
    /// hands out).  Call before [`ConcurrentRouter::run`].
    pub fn handle(&self) -> RouterHandle {
        let tx = self.tx.as_ref().expect("handle() after run()").clone();
        RouterHandle { tx, ids: self.ids.clone() }
    }

    /// The shared accountant every lane admits memory through.
    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// A clone of the un-laned base fault injector — the TCP front-end
    /// probes connection-drop faults through it, sharing the plan's step
    /// budgets and counters with the lane executors.
    pub(crate) fn fault_injector(&self) -> FaultInjector {
        self.faults.clone()
    }

    /// Spawn the lane executors, wire the fleet (victim chains, gate
    /// peers, the shared reclaim token), route requests until every
    /// handle is dropped or a shutdown arrives, then summarize.
    pub fn run(mut self) -> Result<RouterSummary> {
        self.tx.take(); // only external handles keep the queue open now
        // the un-laned base carries the bus; fires re-tag per-probe lane
        self.faults.set_telemetry(self.telemetry.clone());
        let t_start = Instant::now();
        let n = self.runs.len();
        let token = ReclaimToken::new();
        let governor = Arc::new(LaneGovernor::new(self.weights.clone()));

        let mut lane_txs: Vec<mpsc::Sender<LaneMsg>> = Vec::with_capacity(n);
        let mut seeds: Vec<LaneSeed> = Vec::with_capacity(n);
        let mut up_rxs = Vec::with_capacity(n);
        let mut down_txs = Vec::with_capacity(n);
        let (ready_tx, ready_rx) = mpsc::channel::<()>();
        for (idx, run) in self.runs.iter().enumerate() {
            let (tx, rx) = mpsc::channel::<LaneMsg>();
            let (up_tx, up_rx) = mpsc::channel::<Result<LaneWiring>>();
            let (down_tx, down_rx) = mpsc::channel::<WirePack>();
            lane_txs.push(tx);
            up_rxs.push(up_rx);
            down_txs.push(down_tx);
            seeds.push(LaneSeed {
                idx,
                run: run.clone(),
                rx,
                up_tx,
                down_rx,
                ready_tx: ready_tx.clone(),
                telemetry: self.telemetry.with_lane(idx as u32),
                faults: self.faults.with_lane(idx as u32),
                max_restarts: self.cfg.max_lane_restarts,
            });
        }
        drop(ready_tx);
        let fleet = Arc::new(FleetElastic {
            accountant: self.accountant.clone(),
            orig_budget: self.cfg.budget,
            kv_shares: self.kv_lane_shares.clone(),
            weights: self.weights.clone(),
            worker_allotment: self.cfg.worker_allotment,
            txs: lane_txs.clone(),
            state: Mutex::new(FleetState {
                ctrl: self.cfg.memory_trace.clone().map(BudgetController::new),
                passes: 0,
                steps: 0,
                floor: 0,
            }),
        });

        let max_batch = self.cfg.max_batch;
        let batch_window = self.cfg.batch_window;
        let budget = self.cfg.budget;
        let rx = &self.rx;
        let telemetry = self.telemetry.clone();
        let profiles: Vec<String> = self.runs.iter().map(|r| r.profile.clone()).collect();
        let paths = self.paths.clone();
        let accountant = self.accountant.clone();
        let faults_probe = self.faults.clone();

        let (outcomes, unroutable, unroutable_reasons) = std::thread::scope(
            |scope| -> Result<(Vec<LaneOutcome>, usize, RejectReasons)> {
                let mut joins = Vec::with_capacity(n);
                for seed in seeds {
                    let paths = paths.clone();
                    let accountant = accountant.clone();
                    let token = token.clone();
                    let governor = governor.clone();
                    let fleet = fleet.clone();
                    joins.push(scope.spawn(move || {
                        lane_main(
                            seed, paths, accountant, token, governor, fleet, max_batch,
                            batch_window,
                        )
                    }));
                }

                // phase 1: every lane opens its session and publishes its
                // Send handles; one failure aborts the whole fleet
                let mut wirings: Vec<LaneWiring> = Vec::with_capacity(n);
                let mut failure: Option<anyhow::Error> = None;
                for up_rx in &up_rxs {
                    match up_rx.recv() {
                        Ok(Ok(w)) => wirings.push(w),
                        Ok(Err(e)) => {
                            failure = Some(e);
                            break;
                        }
                        Err(_) => {
                            failure =
                                Some(anyhow!("lane exited before publishing its session"));
                            break;
                        }
                    }
                }
                if let Some(e) = failure {
                    drop(down_txs); // unblocks lanes parked on their wire pack
                    for j in joins {
                        let _ = j.join();
                    }
                    return Err(e);
                }

                // the fleet feasibility floor for elastic clamps
                fleet.set_floor(wirings.iter().map(|w| w.floor).max().unwrap_or(0));

                // phase 2: hand every lane its peers' handles
                for (i, down_tx) in down_txs.iter().enumerate() {
                    let peers: Vec<LaneWiring> = wirings
                        .iter()
                        .enumerate()
                        .filter(|(j, _)| *j != i)
                        .map(|(_, w)| w.clone())
                        .collect();
                    let _ = down_tx.send(WirePack { peers });
                }
                drop(down_txs);

                // phase 3: no request may race its lane's victim/peer
                // wiring — wait until every lane reports ready
                for _ in 0..n {
                    if ready_rx.recv().is_err() {
                        break; // a lane died; its join below reports it
                    }
                }

                // phase 4: route envelopes to lane executors
                let mut unroutable = 0usize;
                let mut unroutable_reasons = RejectReasons::default();
                loop {
                    match rx.recv() {
                        Ok(Envelope::Shutdown) => break,
                        Ok(Envelope::Stats(reply)) => {
                            // probe every live lane; each answers with a
                            // snapshot harvested on its own thread at its
                            // next pass / token boundary
                            let mut probes = Vec::with_capacity(lane_txs.len());
                            for tx in &lane_txs {
                                let (stx, srx) = mpsc::channel();
                                if tx.send(LaneMsg::Stats(stx)).is_ok() {
                                    probes.push(srx);
                                }
                            }
                            let snaps: Vec<LaneSnapshot> =
                                probes.into_iter().filter_map(|srx| srx.recv().ok()).collect();
                            let _ = reply.send(summarize_lanes(
                                snaps,
                                unroutable,
                                unroutable_reasons,
                                t_start.elapsed().as_secs_f64(),
                                budget,
                                fleet.steps(),
                                governor.peak() as u64,
                                faults_probe.snapshot(),
                            ));
                        }
                        Ok(Envelope::Infer(p)) => {
                            match profiles.iter().position(|m| *m == p.req.profile) {
                                Some(i) => {
                                    if telemetry.is_on() {
                                        telemetry.with_lane(i as u32).instant(
                                            "enqueue",
                                            worker::DRIVER,
                                            EvArgs::req(p.id),
                                        );
                                    }
                                    if let Err(mpsc::SendError(LaneMsg::Req(p))) =
                                        lane_txs[i].send(LaneMsg::Req(p))
                                    {
                                        unroutable += 1;
                                        unroutable_reasons.note(reject_reason::LANE_DEAD);
                                        telemetry.with_lane(i as u32).instant(
                                            "shed",
                                            worker::DRIVER,
                                            EvArgs::req(p.id)
                                                .with_reason(reject_reason::LANE_DEAD),
                                        );
                                        let _ = p.reply.send(InferResponse::rejected(
                                            p.id,
                                            &p.req.profile,
                                            p.enqueued,
                                            reject_reason::LANE_DEAD,
                                            "lane exited before serving this request",
                                        ));
                                    }
                                }
                                None => {
                                    unroutable += 1;
                                    unroutable_reasons.note(reject_reason::VALIDATION);
                                    telemetry.instant(
                                        "shed",
                                        worker::DRIVER,
                                        EvArgs::req(p.id).with_reason(reject_reason::VALIDATION),
                                    );
                                    let _ = p.reply.send(InferResponse::rejected(
                                        p.id,
                                        &p.req.profile,
                                        p.enqueued,
                                        reject_reason::VALIDATION,
                                        format!("unknown profile '{}'", p.req.profile),
                                    ));
                                }
                            }
                        }
                        Err(_) => break, // every handle dropped
                    }
                }
                // lanes finish their queues, then exit (channel order
                // guarantees Quit lands after every routed request)
                for tx in &lane_txs {
                    let _ = tx.send(LaneMsg::Quit);
                }
                drop(lane_txs);
                // reject anything still sitting in the inbox
                while let Ok(env) = rx.try_recv() {
                    if let Envelope::Infer(p) = env {
                        unroutable += 1;
                        unroutable_reasons.note(reject_reason::LANE_DEAD);
                        let _ = p.reply.send(InferResponse::rejected(
                            p.id,
                            &p.req.profile,
                            p.enqueued,
                            reject_reason::LANE_DEAD,
                            "router shut down",
                        ));
                    }
                }

                let mut outcomes = Vec::with_capacity(n);
                for j in joins {
                    outcomes.push(j.join().map_err(|_| anyhow!("lane thread panicked"))?);
                }
                Ok((outcomes, unroutable, unroutable_reasons))
            },
        )?;

        if let Some(o) = outcomes.iter().find(|o| o.aborted) {
            bail!("lane '{}' aborted before serving", o.profile);
        }

        // aggregate — same code path the mid-flight stats probe runs, so
        // a snapshot taken just before shutdown matches the final summary
        let snaps: Vec<LaneSnapshot> = outcomes
            .into_iter()
            .filter_map(|o| {
                let stats = o.stats?;
                Some(LaneSnapshot {
                    batch_sizes: o.batch_sizes,
                    peak: o.peak,
                    tokens: o.tokens,
                    sched: o.sched,
                    first_error: o.first_error,
                    stats,
                })
            })
            .collect();
        Ok(summarize_lanes(
            snaps,
            unroutable,
            unroutable_reasons,
            t_start.elapsed().as_secs_f64(),
            budget,
            fleet.steps(),
            governor.peak() as u64,
            self.faults.snapshot(),
        ))
    }
}

/// Fold per-lane snapshots into the fleet summary — field-for-field the
/// serialized router's.  Shared by the final aggregation in
/// [`ConcurrentRouter::run`] and the mid-flight `{"op":"stats"}` probe.
#[allow(clippy::too_many_arguments)]
fn summarize_lanes(
    snaps: Vec<LaneSnapshot>,
    unroutable: usize,
    unroutable_reasons: RejectReasons,
    wall: f64,
    budget: Option<u64>,
    budget_steps: u64,
    concurrent_passes_peak: u64,
    fsnap: FaultStatsSnapshot,
) -> RouterSummary {
    let mut latency = LatencyRecorder::new();
    let mut queue_wait = LatencyRecorder::new();
    let (mut served, mut rejected) = (0usize, unroutable);
    let mut reject_reasons = unroutable_reasons;
    let (mut total_batches, mut batch_sizes) = (0usize, 0usize);
    let mut peak = 0u64;
    let (mut hits, mut misses) = (0u64, 0u64);
    let (mut kv_inc, mut kv_rec, mut kv_evicted) = (0u64, 0u64, 0u64);
    let (mut elastic_ev, mut replans) = (0u64, 0u64);
    let (mut prefetched, mut pf_wasted) = (0u64, 0u64);
    let (mut dev_hits, mut spawns_avoided) = (0u64, 0u64);
    let (mut shared_blocks, mut dedup_bytes, mut total_tokens) = (0u64, 0u64, 0u64);
    let mut sched_total = SchedStats::default();
    let mut first_error: Option<String> = None;
    let mut per_model: Vec<ModelStats> = Vec::with_capacity(snaps.len());
    for s in snaps {
        let m = s.stats;
        served += m.served;
        rejected += m.rejected;
        reject_reasons.merge(&m.reject_reasons);
        total_batches += m.batches;
        batch_sizes += s.batch_sizes;
        peak = peak.max(s.peak);
        total_tokens += s.tokens;
        sched_total.merge(&s.sched);
        for &ms in m.latency.samples_ms() {
            latency.record_ms(ms);
        }
        for &ms in m.queue_wait.samples_ms() {
            queue_wait.record_ms(ms);
        }
        if first_error.is_none() {
            first_error = s.first_error;
        }
        hits += m.cache_hits;
        misses += m.cache_misses;
        kv_inc += m.kv_inc_passes;
        kv_rec += m.kv_recomputes;
        kv_evicted += m.kv_evicted_blocks;
        elastic_ev += m.elastic_evictions;
        replans += m.replans;
        prefetched += m.prefetched_stages;
        pf_wasted += m.prefetch_wasted;
        dev_hits += m.device_cache_hits;
        spawns_avoided += m.spawns_avoided;
        shared_blocks += m.shared_kv_blocks;
        dedup_bytes += m.kv_dedup_bytes;
        per_model.push(m);
    }
    RouterSummary {
        served,
        rejected,
        reject_reasons,
        batches: total_batches,
        latency,
        throughput_rps: served as f64 / wall.max(1e-9),
        peak_bytes: peak,
        budget_bytes: budget,
        mean_batch_size: batch_sizes as f64 / total_batches.max(1) as f64,
        cache_hits: hits,
        cache_misses: misses,
        kv_inc_passes: kv_inc,
        kv_recomputes: kv_rec,
        kv_evicted_blocks: kv_evicted,
        budget_steps,
        elastic_evictions: elastic_ev,
        replans,
        prefetched_stages: prefetched,
        prefetch_wasted: pf_wasted,
        device_cache_hits: dev_hits,
        spawns_avoided,
        joins: sched_total.joins,
        leaves: sched_total.leaves,
        shed_overload: sched_total.shed_overload,
        slo_attained_pct: sched_total.slo_attained_pct(),
        shared_kv_blocks: shared_blocks,
        kv_dedup_bytes: dedup_bytes,
        tokens_per_sec: total_tokens as f64 / wall.max(1e-9),
        queue_wait_p50_ms: queue_wait.p50(),
        queue_wait_p95_ms: queue_wait.p95(),
        concurrent_passes_peak,
        faults_injected: fsnap.faults_injected,
        load_retries: fsnap.load_retries,
        passes_timed_out: fsnap.passes_timed_out,
        lane_restarts: fsnap.lane_restarts,
        requeued: fsnap.requeued,
        per_model,
        first_error,
    }
}

/// One lane's executor: build an engine, open the session against the
/// shared accountant, exchange wiring with the fleet, then serve.
#[allow(clippy::too_many_arguments)]
fn lane_main(
    seed: LaneSeed,
    paths: Paths,
    accountant: MemoryAccountant,
    token: ReclaimToken,
    governor: Arc<LaneGovernor>,
    fleet: Arc<FleetElastic>,
    max_batch: usize,
    batch_window: Duration,
) -> LaneOutcome {
    let LaneSeed { idx, run, rx, up_tx, down_rx, ready_tx, telemetry: tel, faults, max_restarts } =
        seed;
    let profile = run.profile.clone();
    let out = LaneOutcome::new(profile.clone());
    let engine = match Engine::new(paths) {
        Ok(e) => e,
        Err(e) => {
            let _ = up_tx.send(Err(e));
            return out.aborted();
        }
    };
    let mut session = match engine.open_session_shared(&run, &accountant) {
        Ok(s) => s,
        Err(e) => {
            let _ = up_tx.send(Err(e));
            return out.aborted();
        }
    };
    session.set_telemetry(tel.clone());
    // arms the disk, the loader pool, and the retry policy with this
    // lane's probe (the shared accountant is armed once, at the router)
    session.set_faults(faults.clone());
    let wiring = LaneWiring {
        gate: session.pipeline_gate(),
        cache: session.layer_cache().cloned(),
        kv: session.kv_pool().cloned(),
        device: session.device_ledger(),
        floor: session.budget_floor(),
    };
    if up_tx.send(Ok(wiring)).is_err() {
        return out.aborted();
    }
    let pack = match down_rx.recv() {
        Ok(p) => p,
        Err(_) => return out.aborted(), // a peer lane failed to open
    };
    // cross-lane wiring: every peer's pins/KV/device copies are reclaim
    // victims of this lane's pressure, and this lane's frees wake
    // admissions parked on any peer (peer condvars)
    for peer in pack.peers {
        if let Some(c) = peer.cache {
            session.add_eviction_victim(c);
        }
        if let Some(p) = peer.kv {
            session.add_kv_eviction_victim(p);
        }
        if let Some(d) = peer.device {
            session.add_device_eviction_victim(d);
        }
        session.add_gate_peer(&peer.gate);
    }
    session.set_reclaim_token(token);
    // signal ready, then drop the sender: the coordinator's ready-barrier
    // recv() must be able to error out (not hang) if any lane dies
    let _ = ready_tx.send(());
    drop(ready_tx);

    // the lane supervisor: serve under `catch_unwind`, with the queue /
    // composer / in-flight set owned OUT HERE so a crash cannot take the
    // backlog down with the stack.  Each crash settles through a recover
    // helper (re-queue holders, shed the rest, heal the session) and
    // restarts the executor until the restart budget runs out.
    let mut out = out;
    let mut restarts = 0u32;
    let mut dead = false;
    if run.continuous {
        let orig_max_active = run.max_active.unwrap_or(DEFAULT_MAX_ACTIVE).max(1);
        let mut composer: BatchComposer<PendingReq> =
            BatchComposer::new(SchedConfig { max_active: orig_max_active, slo_ms: run.slo_ms });
        let mut active: Vec<LaneActive> = Vec::new();
        loop {
            let r = catch_unwind(AssertUnwindSafe(|| {
                lane_serve_continuous(
                    &mut session,
                    idx,
                    &profile,
                    orig_max_active,
                    &rx,
                    &governor,
                    &fleet,
                    &faults,
                    &mut composer,
                    &mut active,
                    &tel,
                    &mut out,
                )
            }));
            match r {
                Ok(()) => break,
                Err(_) => {
                    if !lane_recover_continuous(
                        &mut session,
                        &mut composer,
                        &mut active,
                        &faults,
                        &mut restarts,
                        max_restarts,
                        &profile,
                        &tel,
                        &mut out,
                    ) {
                        dead = true;
                        break;
                    }
                }
            }
        }
        out.sched = composer.stats();
    } else {
        let mut queue: VecDeque<PendingReq> = VecDeque::new();
        let mut inflight: Vec<PendingReq> = Vec::new();
        loop {
            let r = catch_unwind(AssertUnwindSafe(|| {
                lane_serve(
                    &mut session,
                    idx,
                    &profile,
                    &rx,
                    &governor,
                    &fleet,
                    max_batch,
                    batch_window,
                    &faults,
                    &mut queue,
                    &mut inflight,
                    &tel,
                    &mut out,
                )
            }));
            match r {
                Ok(()) => break,
                Err(_) => {
                    if !lane_recover_fixed(
                        &mut session,
                        &mut queue,
                        &mut inflight,
                        &faults,
                        &mut restarts,
                        max_restarts,
                        &profile,
                        &tel,
                        &mut out,
                    ) {
                        dead = true;
                        break;
                    }
                }
            }
        }
    }
    if dead {
        // restart budget exhausted: stay on the inbox shedding until Quit
        // (or the dispatcher hangs up) so everything already routed here
        // still gets a clean `lane_dead` response instead of a dropped
        // reply channel
        while let Ok(msg) = rx.recv() {
            match msg {
                LaneMsg::Req(p) => shed_lane_dead(
                    p,
                    "lane dead: crash-restart budget exhausted",
                    &profile,
                    &tel,
                    &mut out,
                ),
                LaneMsg::Stats(reply) => {
                    let _ = reply.send(snapshot_lane(&session, &profile, &out, out.sched));
                }
                LaneMsg::Budget { .. } => {}
                LaneMsg::Quit => break,
            }
        }
    }

    // per-lane counters, harvested on the thread that owns the session
    let stats = harvest_model_stats(&session, &profile, &out, out.sched);
    out.stats = Some(stats);
    // the chaos-soak invariant: a lane exits with the shared accountant
    // holding none of its bytes
    session.release_all();
    out
}

/// Read the session's counters (on the thread that owns it) into the
/// per-model stats block — used both at lane exit and for the mid-flight
/// [`LaneMsg::Stats`] probe.
fn harvest_model_stats(
    session: &Session<'_>,
    profile: &str,
    out: &LaneOutcome,
    sched: SchedStats,
) -> ModelStats {
    let cs = session.cache_stats();
    let (inc, rec) = session.kv_counters();
    let kvp = session.kv_pool_stats();
    let es = session.elastic_stats();
    let pf = session.prefetch_stats();
    let dev = session.device_stats();
    let pool_stats = session.pool_stats();
    ModelStats {
        profile: profile.to_string(),
        served: out.served,
        rejected: out.rejected,
        reject_reasons: out.reject_reasons,
        batches: out.batches,
        latency: out.latency.clone(),
        queue_wait: out.queue_wait.clone(),
        cache_hits: cs.hits,
        cache_misses: cs.misses,
        kv_inc_passes: inc,
        kv_recomputes: rec,
        kv_evicted_blocks: kvp.evicted_blocks,
        elastic_evictions: es.elastic_evictions,
        replans: es.replans,
        prefetched_stages: pf.prefetched,
        prefetch_wasted: pf.wasted,
        device_cache_hits: dev.hits,
        spawns_avoided: pool_stats.spawns_avoided(),
        joins: sched.joins,
        leaves: sched.leaves,
        shed_overload: sched.shed_overload,
        slo_attained_pct: sched.slo_attained_pct(),
        shared_kv_blocks: kvp.shared_total,
        kv_dedup_bytes: kvp.dedup_bytes,
    }
}

/// Build the full per-lane snapshot a [`LaneMsg::Stats`] probe returns.
fn snapshot_lane(
    session: &Session<'_>,
    profile: &str,
    out: &LaneOutcome,
    sched: SchedStats,
) -> LaneSnapshot {
    LaneSnapshot {
        batch_sizes: out.batch_sizes,
        peak: out.peak,
        tokens: out.tokens,
        sched,
        first_error: out.first_error.clone(),
        stats: harvest_model_stats(session, profile, out, sched),
    }
}

/// Handle a control message between passes; false = Quit (drain and exit).
fn handle_ctl(
    session: &mut Session<'_>,
    msg: LaneMsg,
    queue: &mut VecDeque<PendingReq>,
    profile: &str,
    out: &LaneOutcome,
) -> bool {
    match msg {
        LaneMsg::Req(p) => {
            queue.push_back(p);
            true
        }
        LaneMsg::Stats(reply) => {
            // fixed-batch lanes have no composer ledger; sched counters
            // stay at their defaults (same as the exit-time harvest)
            let _ = reply.send(snapshot_lane(session, profile, out, out.sched));
            true
        }
        LaneMsg::Budget { budget, kv_cap, agents } => {
            // the shared accountant was already resized by the detecting
            // lane; this lane re-derives pin/KV/device caps, settles its
            // reclaim chain, and resizes its worker slice — mid-traffic,
            // at its own pass boundary
            match kv_cap {
                Some(_) => {
                    session.apply_budget_with_kv(budget, kv_cap);
                }
                None => {
                    session.apply_budget(budget);
                }
            }
            if let Some(a) = agents {
                session.set_agents(a);
            }
            true
        }
        LaneMsg::Quit => false,
    }
}

/// The per-lane serving loop: batch folding, deadline admission, and
/// response fan-out are rule-for-rule the serialized router's — only the
/// governor admission (and the fleet elastic hook) are new, so per-lane
/// tokens stay bit-identical to a serialized run of the same traffic.
#[allow(clippy::too_many_arguments)]
fn lane_serve(
    session: &mut Session<'_>,
    lane_idx: usize,
    profile: &str,
    rx: &mpsc::Receiver<LaneMsg>,
    governor: &LaneGovernor,
    fleet: &FleetElastic,
    max_batch: usize,
    batch_window: Duration,
    faults: &FaultInjector,
    queue: &mut VecDeque<PendingReq>,
    inflight: &mut Vec<PendingReq>,
    tel: &Telemetry,
    out: &mut LaneOutcome,
) {
    let avail = session.profile().batches.clone();
    let largest_avail = avail.iter().copied().max().unwrap_or(1);
    let cap = max_batch.min(largest_avail).max(1);
    let mut open = true;

    loop {
        // supervised lane death: the crash surfaces between batches; the
        // unwind lands in lane_main's catch, which runs the supervisor.
        // `resume_unwind` skips the panic hook (no stderr spam for an
        // injected, fully-contained crash).
        if faults.fire(FaultKind::LaneDeath) {
            std::panic::resume_unwind(Box::new("injected lane death (fault plan)"));
        }
        if queue.is_empty() {
            if !open {
                break;
            }
            match rx.recv() {
                Ok(msg) => {
                    if !handle_ctl(session, msg, queue, profile, out) {
                        open = false;
                    }
                    continue;
                }
                Err(_) => break,
            }
        }

        // admit everything already queued (free), then wait out the batch
        // window only while the batch is unfilled
        if open {
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !handle_ctl(session, msg, queue, profile, out) {
                            open = false;
                            break;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        // wake-up sweep (whole queue, not just the admission pops below):
        // an expired request parked behind a live head is rejected promptly
        // instead of distorting fill windows and queue-wait percentiles
        sweep_expired_queue(queue, profile, tel, out);
        if queue.is_empty() {
            continue;
        }
        if open && queue.len() < cap {
            // never wait past a queued request's deadline
            let mut fill_deadline = Instant::now() + batch_window;
            if let Some(d) = queue.iter().filter_map(|p| p.deadline).min() {
                fill_deadline = fill_deadline.min(d);
            }
            loop {
                let now = Instant::now();
                if now >= fill_deadline {
                    break;
                }
                match rx.recv_timeout(fill_deadline - now) {
                    Ok(msg) => {
                        if !handle_ctl(session, msg, queue, profile, out) {
                            open = false;
                            break;
                        }
                        if queue.len() >= cap {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }

        // EDF within the lane: rotate the earliest-deadline request to
        // the head (no deadlines -> index 0 -> plain FIFO, preserving the
        // serialized router's fold order bit for bit)
        if let Some(best) = queue
            .iter()
            .enumerate()
            .min_by_key(|(_, p)| (p.deadline.is_none(), p.deadline, p.enqueued))
            .map(|(i, _)| i)
        {
            queue.rotate_left(best);
        }

        // admitted requests live in `inflight` (owned by the supervisor in
        // lane_main) so a crash mid-batch can re-queue them, not drop them
        inflight.clear();
        let mut hint_rows = 0usize;
        let now = Instant::now();
        while inflight.len() < cap {
            let Some(p) = queue.pop_front() else { break };
            if p.deadline.map(|d| d <= now).unwrap_or(false) {
                out.rejected += 1;
                out.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
                tel.instant(
                    "shed",
                    worker::DRIVER,
                    EvArgs::req(p.id).with_reason(reject_reason::DEADLINE_EXPIRED),
                );
                let _ = p.reply.send(InferResponse::rejected(
                    p.id,
                    profile,
                    p.enqueued,
                    reject_reason::DEADLINE_EXPIRED,
                    "deadline exceeded before admission",
                ));
                continue;
            }
            let rows = p.req.batch_hint.max(1);
            if rows > largest_avail {
                out.rejected += 1;
                out.reject_reasons.note(reject_reason::VALIDATION);
                tel.instant(
                    "shed",
                    worker::DRIVER,
                    EvArgs::req(p.id).with_reason(reject_reason::VALIDATION),
                );
                let _ = p.reply.send(InferResponse::rejected(
                    p.id,
                    profile,
                    p.enqueued,
                    reject_reason::VALIDATION,
                    format!("batch_hint {rows} exceeds largest AOT batch {largest_avail}"),
                ));
                continue;
            }
            if let Some(first) = inflight.first() {
                if first.req.seed != p.req.seed || hint_rows + rows > largest_avail {
                    queue.push_front(p);
                    break;
                }
            }
            hint_rows += rows;
            tel.instant("admit", worker::DRIVER, EvArgs::req(p.id));
            inflight.push(p);
        }
        if inflight.is_empty() {
            continue;
        }
        for p in inflight.iter() {
            out.queue_wait.record(now.saturating_duration_since(p.enqueued));
        }

        let b = pick_batch(&avail, hint_rows);
        let seed = inflight[0]
            .req
            .seed
            .unwrap_or_else(|| session.run_config().seed.wrapping_add(out.batches as u64));
        // cross-batch prefetch across the request boundary
        session.set_expect_more(!queue.is_empty());

        let passes_before = session.passes_run();
        governor.admit(lane_idx);
        tel.begin("batch", worker::DRIVER, EvArgs::default());
        let r = session.run_batch(b, seed);
        tel.end("batch", worker::DRIVER);
        governor.done();
        match r {
            Ok((report, outp)) => {
                out.peak = out.peak.max(report.peak_bytes);
                out.batches += 1;
                out.batch_sizes += inflight.len();
                debug_assert_eq!(
                    session.kv_pool().map(|p| p.used_bytes()).unwrap_or(0),
                    0,
                    "KV blocks must be freed when the ticket resolves"
                );
                let mut row_off = 0usize;
                for p in inflight.iter() {
                    let rows = p.req.batch_hint.max(1);
                    let generated_rows: Vec<Vec<i32>> = outp
                        .generated_rows
                        .iter()
                        .skip(row_off)
                        .take(rows)
                        .cloned()
                        .collect();
                    row_off += rows;
                    let latency = p.enqueued.elapsed();
                    out.latency.record(latency);
                    out.served += 1;
                    out.tokens += report.tokens as u64;
                    tel.instant("retire", worker::DRIVER, EvArgs::req(p.id));
                    let _ = p.reply.send(InferResponse {
                        id: p.id,
                        profile: profile.to_string(),
                        ok: true,
                        error: None,
                        reason: None,
                        latency_ms: latency.as_secs_f64() * 1000.0,
                        batch: b,
                        tokens: report.tokens,
                        generated_rows,
                        peak_bytes: report.peak_bytes,
                    });
                }
            }
            Err(e) => {
                // the pass drained its own ledger; peers keep flying
                if out.first_error.is_none() {
                    out.first_error = Some(format!("{e:#}"));
                }
                for p in inflight.iter() {
                    out.rejected += 1;
                    out.reject_reasons.note(reject_reason::INTERNAL);
                    tel.instant(
                        "retire",
                        worker::DRIVER,
                        EvArgs::req(p.id).with_reason(reject_reason::INTERNAL),
                    );
                    let _ = p.reply.send(InferResponse::rejected(
                        p.id,
                        profile,
                        p.enqueued,
                        reject_reason::INTERNAL,
                        format!("pass failed: {e:#}"),
                    ));
                }
            }
        }
        // every reply for this batch is out; nothing left to re-queue
        inflight.clear();
        fleet.after_batch(session.passes_run().saturating_sub(passes_before));
    }
}

/// Reject every queued request whose deadline has already passed — the
/// WHOLE queue, not just the head (same sweep the serialized router and
/// the composer run at their wake-ups).
fn sweep_expired_queue(
    queue: &mut VecDeque<PendingReq>,
    profile: &str,
    tel: &Telemetry,
    out: &mut LaneOutcome,
) {
    let now = Instant::now();
    let mut kept: VecDeque<PendingReq> = VecDeque::with_capacity(queue.len());
    for p in queue.drain(..) {
        if p.deadline.map(|d| d <= now).unwrap_or(false) {
            out.rejected += 1;
            out.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
            tel.instant(
                "shed",
                worker::DRIVER,
                EvArgs::req(p.id).with_reason(reject_reason::DEADLINE_EXPIRED),
            );
            let _ = p.reply.send(InferResponse::rejected(
                p.id,
                profile,
                p.enqueued,
                reject_reason::DEADLINE_EXPIRED,
                "deadline exceeded before admission",
            ));
        } else {
            kept.push_back(p);
        }
    }
    *queue = kept;
}

/// One request resident in a continuous lane's active set.
struct LaneActive {
    id: u64,
    enqueued: Instant,
    /// absolute deadline; enforced mid-decode at every token boundary
    deadline: Option<Instant>,
    slo_ms: Option<f64>,
    batch_hint: usize,
    batch: usize,
    reply: mpsc::Sender<InferResponse>,
    /// kept so the supervisor can re-queue this request across a lane
    /// crash-restart with its identity and deadline intact
    req: InferRequest,
    st: DecodeState,
}

/// Handle a control message at a token boundary of a continuous lane;
/// false = Quit (drain and exit).  Mirrors [`handle_ctl`] except requests
/// land in the composer's pending queue and a budget step shrinks the
/// active-set cap FIRST — fewer future joiners is the cheap lever, so the
/// eviction chain only reclaims shared KV blocks for pressure the smaller
/// active set still generates (the serialized router orders it the same).
fn handle_ctl_continuous(
    session: &mut Session<'_>,
    msg: LaneMsg,
    composer: &mut BatchComposer<PendingReq>,
    orig_max_active: usize,
    orig_budget: Option<u64>,
    profile: &str,
    out: &LaneOutcome,
) -> bool {
    match msg {
        LaneMsg::Req(p) => {
            composer.push(Entry {
                enqueued: p.enqueued,
                deadline: p.deadline,
                slo_ms: p.req.slo_ms,
                payload: p,
            });
            true
        }
        LaneMsg::Stats(reply) => {
            let _ = reply.send(snapshot_lane(session, profile, out, composer.stats()));
            true
        }
        LaneMsg::Budget { budget, kv_cap, agents } => {
            if let Some(orig) = orig_budget {
                composer.set_max_active(scaled_active_cap(orig_max_active, orig, budget));
            }
            match kv_cap {
                Some(_) => {
                    session.apply_budget_with_kv(budget, kv_cap);
                }
                None => {
                    session.apply_budget(budget);
                }
            }
            if let Some(a) = agents {
                session.set_agents(a);
            }
            true
        }
        LaneMsg::Quit => false,
    }
}

/// The continuous-batching per-lane serving loop: the lane re-forms its
/// active set at every token boundary through a [`BatchComposer`] —
/// joiners prime with one prefix pass ([`Session::begin_decode`] + first
/// [`Session::decode_step`]), every active request advances one token per
/// iteration, finished rows retire immediately and free their KV blocks.
/// Each iteration is governor-gated, so concurrent lanes share the device
/// under the same weighted-fair clock as fixed-batch lanes, and the fleet
/// elastic hook still counts engine passes across lanes.
///
/// Tokens stay bit-identical to the fixed path by construction: each
/// request decodes at its own fixed-path batch size and seed
/// (`cfg.seed + lane_batches` — the composer admits in EDF order, and the
/// lane counts a batch per admission), so interleaving only moves *when*
/// a request's passes run, never what they compute.
#[allow(clippy::too_many_arguments)]
fn lane_serve_continuous(
    session: &mut Session<'_>,
    lane_idx: usize,
    profile: &str,
    orig_max_active: usize,
    rx: &mpsc::Receiver<LaneMsg>,
    governor: &LaneGovernor,
    fleet: &FleetElastic,
    faults: &FaultInjector,
    composer: &mut BatchComposer<PendingReq>,
    active: &mut Vec<LaneActive>,
    tel: &Telemetry,
    out: &mut LaneOutcome,
) {
    let avail = session.profile().batches.clone();
    let largest_avail = avail.iter().copied().max().unwrap_or(1);
    let mut open = true;

    loop {
        // supervised lane death: surfaces at a token boundary, never
        // inside a pass; the unwind lands in lane_main's catch
        if faults.fire(FaultKind::LaneDeath) {
            std::panic::resume_unwind(Box::new("injected lane death (fault plan)"));
        }
        if active.is_empty() && composer.is_idle() {
            if !open {
                break;
            }
            match rx.recv() {
                Ok(msg) => {
                    if !handle_ctl_continuous(
                        session,
                        msg,
                        composer,
                        orig_max_active,
                        fleet.orig_budget,
                        profile,
                        out,
                    ) {
                        open = false;
                    }
                    continue;
                }
                Err(_) => break,
            }
        }

        // drain control messages without stalling a token boundary
        if open {
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !handle_ctl_continuous(
                            session,
                            msg,
                            composer,
                            orig_max_active,
                            fleet.orig_budget,
                            profile,
                            out,
                        ) {
                            open = false;
                            break;
                        }
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }

        // wake-up sweep: the WHOLE pending queue, not just the head
        let now = Instant::now();
        for e in composer.sweep_expired(now) {
            out.rejected += 1;
            out.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
            tel.instant(
                "shed",
                worker::DRIVER,
                EvArgs::req(e.payload.id).with_reason(reject_reason::DEADLINE_EXPIRED),
            );
            let _ = e.payload.reply.send(InferResponse::rejected(
                e.payload.id,
                profile,
                e.payload.enqueued,
                reject_reason::DEADLINE_EXPIRED,
                "deadline exceeded before admission",
            ));
        }

        // fill free slots at this token boundary (EDF order, SLO shedding)
        let (joins, drops) = composer.admit(now, active.len());
        for (e, why) in drops {
            out.rejected += 1;
            out.reject_reasons.note(why.slug());
            tel.instant(
                "shed",
                worker::DRIVER,
                EvArgs::req(e.payload.id).with_reason(why.slug()),
            );
            let msg = match why {
                DropReason::Expired => "deadline exceeded before admission".to_string(),
                DropReason::Overload => format!(
                    "shed: overload (queued {:.1} ms, past the SLO target)",
                    now.duration_since(e.enqueued).as_secs_f64() * 1000.0
                ),
            };
            let _ = e.payload.reply.send(InferResponse::rejected(
                e.payload.id,
                profile,
                e.payload.enqueued,
                why.slug(),
                msg,
            ));
        }
        for e in joins {
            let p = e.payload;
            let rows = p.req.batch_hint.max(1);
            if rows > largest_avail {
                composer.unjoin();
                out.rejected += 1;
                out.reject_reasons.note(reject_reason::VALIDATION);
                tel.instant(
                    "shed",
                    worker::DRIVER,
                    EvArgs::req(p.id).with_reason(reject_reason::VALIDATION),
                );
                let _ = p.reply.send(InferResponse::rejected(
                    p.id,
                    profile,
                    p.enqueued,
                    reject_reason::VALIDATION,
                    format!("batch_hint {rows} exceeds largest AOT batch {largest_avail}"),
                ));
                continue;
            }
            out.queue_wait.record(now.saturating_duration_since(p.enqueued));
            // same batch/seed derivation as the fixed path, so a request's
            // tokens are bit-identical between the two schedulers
            let b = pick_batch(&avail, rows);
            let seed = p
                .req
                .seed
                .unwrap_or_else(|| session.run_config().seed.wrapping_add(out.batches as u64));
            out.batches += 1;
            out.batch_sizes += 1;
            tel.instant("admit", worker::DRIVER, EvArgs::req(p.id));
            tel.instant("prime", worker::DRIVER, EvArgs::req(p.id));
            let st = session.begin_decode(b, seed);
            tel.instant("join", worker::DRIVER, EvArgs::req(p.id));
            active.push(LaneActive {
                id: p.id,
                enqueued: p.enqueued,
                deadline: p.deadline,
                slo_ms: e.slo_ms,
                batch_hint: rows,
                batch: b,
                reply: p.reply,
                req: p.req,
                st,
            });
        }
        if active.is_empty() {
            continue;
        }

        // one token boundary: every active request advances one step.
        // Governor-gated like a fixed batch, so concurrent lanes still
        // share the device weighted-fair.
        let passes_before = session.passes_run();
        governor.admit(lane_idx);
        let tok_now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            // deadline enforcement mid-decode: an expired request retires
            // at this token boundary instead of burning passes to the end
            if active[i].deadline.is_some_and(|d| d <= tok_now) {
                let a = active.swap_remove(i);
                composer.retire(a.enqueued, a.slo_ms, tok_now, false);
                out.rejected += 1;
                out.reject_reasons.note(reject_reason::DEADLINE_EXPIRED);
                tel.instant(
                    "retire",
                    worker::DRIVER,
                    EvArgs::req(a.id).with_reason(reject_reason::DEADLINE_EXPIRED),
                );
                let _ = a.reply.send(InferResponse::rejected(
                    a.id,
                    profile,
                    a.enqueued,
                    reject_reason::DEADLINE_EXPIRED,
                    "deadline exceeded mid-decode (retired at token boundary)",
                ));
                // `a.st` drops here: the dead decode's KV blocks free
                continue;
            }
            // keep cross-pass prefetch alive while ANY work will follow
            let expect_next = active.len() > 1
                || composer.pending_len() > 0
                || !active[i].st.last_step();
            tel.instant("decode_step", worker::DRIVER, EvArgs::req(active[i].id));
            match session.decode_step(&mut active[i].st, expect_next) {
                Err(e) => {
                    if out.first_error.is_none() {
                        out.first_error = Some(format!("{e:#}"));
                    }
                    let a = active.swap_remove(i);
                    composer.retire(a.enqueued, a.slo_ms, Instant::now(), false);
                    out.rejected += 1;
                    out.reject_reasons.note(reject_reason::INTERNAL);
                    tel.instant(
                        "retire",
                        worker::DRIVER,
                        EvArgs::req(a.id).with_reason(reject_reason::INTERNAL),
                    );
                    let _ = a.reply.send(InferResponse::rejected(
                        a.id,
                        profile,
                        a.enqueued,
                        reject_reason::INTERNAL,
                        format!("pass failed: {e:#}"),
                    ));
                }
                Ok(()) if active[i].st.done() => {
                    let a = active.swap_remove(i);
                    let (report, outp) = session.finish_decode(a.st);
                    out.peak = out.peak.max(report.peak_bytes);
                    let done = Instant::now();
                    composer.retire(a.enqueued, a.slo_ms, done, true);
                    let latency = done.duration_since(a.enqueued);
                    out.latency.record(latency);
                    out.served += 1;
                    out.tokens += report.tokens as u64;
                    let generated_rows: Vec<Vec<i32>> =
                        outp.generated_rows.iter().take(a.batch_hint).cloned().collect();
                    tel.instant("retire", worker::DRIVER, EvArgs::req(a.id));
                    tel.instant("leave", worker::DRIVER, EvArgs::req(a.id));
                    let _ = a.reply.send(InferResponse {
                        id: a.id,
                        profile: profile.to_string(),
                        ok: true,
                        error: None,
                        reason: None,
                        latency_ms: latency.as_secs_f64() * 1000.0,
                        batch: a.batch,
                        tokens: report.tokens,
                        generated_rows,
                        peak_bytes: report.peak_bytes,
                    });
                }
                Ok(()) => i += 1,
            }
        }
        governor.done();
        composer.note_iteration();
        fleet.after_batch(session.passes_run().saturating_sub(passes_before));
    }
}

/// Reject one request with `lane_dead` — the supervisor's shed path for
/// work a crashed lane can no longer honor.
fn shed_lane_dead(
    p: PendingReq,
    why: &str,
    profile: &str,
    tel: &Telemetry,
    out: &mut LaneOutcome,
) {
    out.rejected += 1;
    out.reject_reasons.note(reject_reason::LANE_DEAD);
    tel.instant("shed", worker::DRIVER, EvArgs::req(p.id).with_reason(reject_reason::LANE_DEAD));
    let _ = p.reply.send(InferResponse::rejected(
        p.id,
        profile,
        p.enqueued,
        reject_reason::LANE_DEAD,
        why,
    ));
}

/// Settle a crashed continuous lane and decide restart (true) vs death
/// (false).  In-flight decodes whose deadlines still hold re-queue with
/// their identity, enqueue time and deadline intact (EDF order and expiry
/// stay honest); the rest shed with `lane_dead`.  The session heals via
/// [`Session::recover_after_abort`] either way — on death the whole
/// backlog sheds too.
#[allow(clippy::too_many_arguments)]
fn lane_recover_continuous(
    session: &mut Session<'_>,
    composer: &mut BatchComposer<PendingReq>,
    active: &mut Vec<LaneActive>,
    faults: &FaultInjector,
    restarts: &mut u32,
    max_restarts: u32,
    profile: &str,
    tel: &Telemetry,
    out: &mut LaneOutcome,
) -> bool {
    let now = Instant::now();
    let restart = *restarts < max_restarts;
    // each entry's decode state drops as it settles, releasing its KV
    // sequence while the pool still knows it
    for a in active.drain(..).collect::<Vec<_>>() {
        composer.retire(a.enqueued, a.slo_ms, now, false);
        let holds = a.deadline.map(|d| d > now).unwrap_or(true);
        let p = PendingReq {
            id: a.id,
            req: a.req,
            enqueued: a.enqueued,
            deadline: a.deadline,
            reply: a.reply,
        };
        if restart && holds {
            faults.stats().note_requeued();
            composer.push(Entry {
                enqueued: p.enqueued,
                deadline: p.deadline,
                slo_ms: a.slo_ms,
                payload: p,
            });
        } else {
            shed_lane_dead(p, "lane crashed; in-flight decode lost", profile, tel, out);
        }
    }
    session.recover_after_abort();
    if restart {
        *restarts += 1;
        faults.stats().note_lane_restart();
        tel.instant("lane_restart", worker::DRIVER, EvArgs::default().with_reason("supervisor"));
        true
    } else {
        for e in composer.drain_pending() {
            shed_lane_dead(
                e.payload,
                "lane dead: crash-restart budget exhausted",
                profile,
                tel,
                out,
            );
        }
        false
    }
}

/// Fixed-batch twin of [`lane_recover_continuous`]: the crashed batch sits
/// in `inflight`; holders re-queue at the head of the lane queue in their
/// original order, the rest shed.
#[allow(clippy::too_many_arguments)]
fn lane_recover_fixed(
    session: &mut Session<'_>,
    queue: &mut VecDeque<PendingReq>,
    inflight: &mut Vec<PendingReq>,
    faults: &FaultInjector,
    restarts: &mut u32,
    max_restarts: u32,
    profile: &str,
    tel: &Telemetry,
    out: &mut LaneOutcome,
) -> bool {
    let now = Instant::now();
    let restart = *restarts < max_restarts;
    // reverse drain + push_front preserves the batch's original order
    for p in inflight.drain(..).rev() {
        let holds = p.deadline.map(|d| d > now).unwrap_or(true);
        if restart && holds {
            faults.stats().note_requeued();
            queue.push_front(p);
        } else {
            shed_lane_dead(p, "lane crashed; in-flight batch lost", profile, tel, out);
        }
    }
    session.recover_after_abort();
    if restart {
        *restarts += 1;
        faults.stats().note_lane_restart();
        tel.instant("lane_restart", worker::DRIVER, EvArgs::default().with_reason("supervisor"));
        true
    } else {
        for p in queue.drain(..) {
            shed_lane_dead(p, "lane dead: crash-restart budget exhausted", profile, tel, out);
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_allotment_is_weighted_min_one() {
        assert_eq!(split_allotment(4, &[1.0, 1.0]), vec![2, 2]);
        assert_eq!(split_allotment(6, &[2.0, 1.0]), vec![4, 2]);
        // min 1 even when the weight share rounds to zero
        assert_eq!(split_allotment(2, &[100.0, 1.0]), vec![1, 1]);
        // remainder lands on the heaviest lane
        assert_eq!(split_allotment(5, &[1.0, 1.0, 2.0]), vec![1, 1, 3]);
        // every slice is at least 1 even when total < lanes
        let s = split_allotment(1, &[1.0, 1.0, 1.0]);
        assert!(s.iter().all(|&x| x >= 1), "{s:?}");
    }

    #[test]
    fn may_start_gate_bounds_the_lead() {
        assert!(may_start(0.0, f64::INFINITY), "no waiting peer -> always start");
        assert!(may_start(1.0, 0.5), "within slack");
        assert!(!may_start(2.5, 1.0), "too far ahead of a backlogged peer");
        // the most behind waiter is always admissible (deadlock freedom)
        assert!(may_start(1.0, 1.0));
    }

    #[test]
    fn governor_tracks_in_flight_peak_and_batches() {
        let g = LaneGovernor::new(vec![1.0, 2.0]);
        g.admit(0);
        g.admit(1); // lane 0 is not waiting anymore, lane 1 never blocks
        let (in_flight, peak, total) = g.snapshot();
        assert_eq!((in_flight, peak, total), (2, 2, 2));
        g.done();
        g.done();
        let (in_flight, peak, total) = g.snapshot();
        assert_eq!((in_flight, peak, total), (0, 2, 2));
    }

    #[test]
    fn governor_two_racing_lanes_never_deadlock() {
        let g = Arc::new(LaneGovernor::new(vec![1.0, 1.0]));
        let mut joins = Vec::new();
        for lane in 0..2usize {
            let g = g.clone();
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    g.admit(lane);
                    g.done();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let (in_flight, peak, total) = g.snapshot();
        assert_eq!(in_flight, 0);
        assert!(peak >= 1);
        assert_eq!(total, 100);
    }
}
