//! Serving layer: multi-model router, TCP front-end, and the single-model
//! compatibility shim.
//!
//! The end-to-end realization of the paper's §V-C serving claim ("all
//! results meeting SLO expectations"), redesigned around a request router:
//!
//! * [`router`] — the core.  A [`Router`] owns one long-lived
//!   [`Session`] per model profile, **all opened against one shared
//!   [`MemoryAccountant`]** ([`Engine::open_session_shared`]) so N models
//!   contend for a single device-wide budget; one model's `S^stop`
//!   pressure can evict another model's pinned hot layers.  Producers on
//!   any thread submit typed [`InferRequest`]s through a cloneable,
//!   mpsc-backed [`RouterHandle`] and await [`InferResponse`]s via
//!   [`Ticket`]s.  Scheduling is per-profile: earliest-deadline-first
//!   lane selection, a batch-fill window, and deadline-aware admission
//!   that rejects expired requests instead of spending passes on them.
//!   The router loop runs on the caller's thread — the session (and its
//!   non-Send PJRT runtime) never migrates.
//! * [`lanes`] — the concurrent router ([`ConcurrentRouter`],
//!   `RouterConfig { concurrent: true, .. }`): one executor thread +
//!   engine per model lane, passes overlapping against the same shared
//!   budget.  Per-pass ledgers keep failure recovery exact, a fleet-wide
//!   reclaim token keeps cross-lane eviction chains safe, and a weighted
//!   governor splits admissions (and the Loading-Agent allotment) across
//!   lanes.  Per-lane tokens stay bit-identical to the serialized router.
//! * [`tcp`] — a minimal line-delimited-JSON TCP front-end
//!   (`hermes serve --listen <addr>`): external clients drive the same
//!   queue through per-connection reader threads.
//! * [`summary`] — [`serve`]/[`ServeSummary`], the original single-model
//!   serving API, rebuilt as a thin shim over a one-model router so
//!   existing benches, tests, and examples keep working unchanged.
//!
//! [`Session`]: crate::engine::Session
//! [`MemoryAccountant`]: crate::memory::MemoryAccountant
//! [`Engine::open_session_shared`]: crate::engine::Engine::open_session_shared

pub mod lanes;
pub mod router;
pub mod summary;
pub mod tcp;

pub use lanes::ConcurrentRouter;
pub use router::{
    kv_shares, pick_batch, reject_reason, InferRequest, InferResponse, ModelStats, RejectReasons,
    Router, RouterConfig, RouterHandle, RouterSummary, Ticket,
};
pub use summary::{e2e_default, serve, ServeConfig, ServeSummary};
pub use tcp::TcpFrontend;
