//! Serving loop: batched request execution with SLO reporting.
//!
//! The end-to-end driver for the paper's §V-C serving claim ("all results
//! meeting SLO expectations").  A workload generator thread produces
//! requests with Poisson arrivals into a queue; the serving loop batches
//! them (size- and deadline-bounded) and executes each batch as one pass
//! of a single long-lived [`Session`] in the configured mode — profile
//! resolution, weight validation, and AOT prepare run once per serving
//! session, not once per batch, and PIPELOAD's hot-layer cache (if a pin
//! budget is set) carries pinned layers from batch to batch.  The session
//! (and its non-Send PJRT runtime) stays on the caller's thread — a TCP
//! front-end would feed the same queue without touching this loop.
//!
//! [`Session`]: crate::engine::Session

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{Mode, RunConfig};
use crate::engine::Engine;
use crate::metrics::{check_slo, LatencyRecorder, SloReport};
use crate::util::rng::Rng;

/// Serving workload + policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub run: RunConfig,
    /// total requests to serve
    pub num_requests: usize,
    /// mean arrival rate (requests/sec); 0 = closed loop (back to back)
    pub arrival_rps: f64,
    /// max requests folded into one batch (capped by AOT batch sizes)
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub batch_window: Duration,
    /// p95 latency target
    pub slo_ms: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            run: RunConfig::default(),
            num_requests: 16,
            arrival_rps: 0.0,
            max_batch: 4,
            batch_window: Duration::from_millis(20),
            slo_ms: 1000.0,
        }
    }
}

#[derive(Debug)]
struct Request {
    id: usize,
    enqueued: Instant,
}

/// Summary of a serving session.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub served: usize,
    pub batches: usize,
    pub latency: LatencyRecorder,
    pub throughput_rps: f64,
    pub peak_bytes: u64,
    pub slo: SloReport,
    pub mean_batch_size: f64,
    /// hot-layer cache hits/misses across all batches (0/0 = no cache)
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Pick the smallest AOT-compiled batch size that fits `n` requests (or
/// the largest available if none fit).
pub fn pick_batch(available: &[usize], n: usize) -> usize {
    let mut sorted: Vec<usize> = available.to_vec();
    sorted.sort_unstable();
    for &b in &sorted {
        if b >= n {
            return b;
        }
    }
    sorted.last().copied().unwrap_or(1)
}

/// Run the serving session; engine passes happen on this thread.
/// One [`crate::engine::Session`] serves every batch: `Runtime::prepare`
/// runs exactly once here, regardless of how many batches follow.
pub fn serve(engine: &Engine, cfg: &ServeConfig) -> Result<ServeSummary> {
    let mut session = engine.open_session(&cfg.run)?;
    let batches_avail = session.profile().batches.clone();
    let (tx, rx) = mpsc::channel::<Request>();
    let num = cfg.num_requests;
    let rps = cfg.arrival_rps;
    let seed = cfg.run.seed;

    // workload generator (open loop with Poisson arrivals, or closed loop)
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(seed ^ 0x5e7e);
        for id in 0..num {
            if rps > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(rng.exp(1.0 / rps)));
            }
            if tx.send(Request { id, enqueued: Instant::now() }).is_err() {
                return;
            }
        }
    });

    let mut latency = LatencyRecorder::new();
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut peak = 0u64;
    let mut batch_sizes = 0usize;
    let t_start = Instant::now();

    while served < cfg.num_requests {
        // block for the first request, then fill the batch within the window
        let first = rx.recv().expect("producer ended early");
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_window;
        let cap = cfg.max_batch.min(batches_avail.iter().copied().max().unwrap_or(1));
        while batch.len() < cap {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        let b = pick_batch(&batches_avail, batch.len());
        let seed = cfg.run.seed.wrapping_add(batches as u64);
        let (report, _) = session.run_batch(b, seed)?;
        peak = peak.max(report.peak_bytes);
        batches += 1;
        batch_sizes += batch.len();
        for r in &batch {
            latency.record(r.enqueued.elapsed());
            let _ = r.id;
        }
        served += batch.len();
    }
    producer.join().ok();

    let wall = t_start.elapsed().as_secs_f64();
    let slo = check_slo(&latency, cfg.slo_ms);
    let cache = session.cache_stats();
    Ok(ServeSummary {
        served,
        batches,
        throughput_rps: served as f64 / wall.max(1e-9),
        peak_bytes: peak,
        slo,
        mean_batch_size: batch_sizes as f64 / batches.max(1) as f64,
        latency,
        cache_hits: cache.hits,
        cache_misses: cache.misses,
    })
}

/// Convenience: serving defaults for the E2E example (PIPELOAD on the
/// BERT sim profile with a batch-4 entry).
pub fn e2e_default(profile: &str, agents: usize, budget: Option<u64>) -> ServeConfig {
    ServeConfig {
        run: RunConfig {
            profile: profile.into(),
            mode: Mode::PipeLoad,
            agents,
            budget,
            ..RunConfig::default()
        },
        ..ServeConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_batch_smallest_fitting() {
        assert_eq!(pick_batch(&[1, 4], 1), 1);
        assert_eq!(pick_batch(&[1, 4], 2), 4);
        assert_eq!(pick_batch(&[1, 4], 4), 4);
        assert_eq!(pick_batch(&[1, 4], 9), 4); // overflow -> largest
        assert_eq!(pick_batch(&[], 3), 1);
    }

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert!(c.num_requests > 0);
        assert!(c.slo_ms > 0.0);
    }
}
