//! Single-model serving compatibility layer + summaries.
//!
//! [`serve`] reproduces the original serving API (the paper's §V-C driver:
//! Poisson workload generator -> batcher -> one session) as a thin shim
//! over the [`Router`]: it builds a one-model [`RouterConfig`], spawns the
//! workload generator as a producer thread feeding a [`RouterHandle`], and
//! runs the router loop on the calling thread.  Benches, tests, and
//! examples written against `serve()` / [`ServeSummary`] keep working
//! unchanged; new callers should use the [`Router`] directly.

use std::time::Duration;

use anyhow::Result;

use super::router::{InferRequest, RejectReasons, Router, RouterConfig, RouterSummary};
use crate::config::{Mode, RunConfig};
use crate::elastic::PressureTrace;
use crate::engine::Engine;
use crate::metrics::{check_slo, LatencyRecorder, SloReport};
use crate::telemetry::Telemetry;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// Serving workload + policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub run: RunConfig,
    /// total requests to serve
    pub num_requests: usize,
    /// mean arrival rate (requests/sec); 0 = closed loop (back to back)
    pub arrival_rps: f64,
    /// max requests folded into one batch (capped by AOT batch sizes)
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub batch_window: Duration,
    /// p95 latency target
    pub slo_ms: f64,
    /// memory-pressure trace applied between batches (see [`crate::elastic`])
    pub memory_trace: Option<PressureTrace>,
    /// structured event bus threaded through the router and its session
    /// (off by default — the disabled path is a single atomic load)
    pub telemetry: Telemetry,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            run: RunConfig::default(),
            num_requests: 16,
            arrival_rps: 0.0,
            max_batch: 4,
            batch_window: Duration::from_millis(20),
            slo_ms: 1000.0,
            memory_trace: None,
            telemetry: Telemetry::off(),
        }
    }
}

impl ServeConfig {
    /// The equivalent one-model router configuration.
    pub fn router_config(&self) -> RouterConfig {
        RouterConfig {
            models: vec![self.run.clone()],
            budget: self.run.budget,
            kv_budget: self.run.kv_budget,
            max_batch: self.max_batch,
            batch_window: self.batch_window,
            memory_trace: self.memory_trace.clone(),
            fault_plan: self.run.fault_plan.clone(),
            max_lane_restarts: self.run.max_lane_restarts,
            ..RouterConfig::default()
        }
    }
}

/// Summary of a serving session.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub served: usize,
    /// per-reason rejection counters (zero across the board on a clean run)
    pub reject_reasons: RejectReasons,
    pub batches: usize,
    pub latency: LatencyRecorder,
    pub throughput_rps: f64,
    pub peak_bytes: u64,
    pub slo: SloReport,
    pub mean_batch_size: f64,
    /// hot-layer cache hits/misses across all batches (0/0 = no cache)
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// KV cache: incremental decode passes / full-prefix recomputes /
    /// blocks evicted under memory pressure (all 0 = KV off)
    pub kv_inc_passes: u64,
    pub kv_recomputes: u64,
    pub kv_evicted_blocks: u64,
    /// elastic controller: budget steps applied / pins+KV blocks evicted
    /// by them / agent-count re-plans (all 0 = no memory trace)
    pub budget_steps: u64,
    pub elastic_evictions: u64,
    pub replans: u64,
    /// cross-pass prefetch: stages loaded ahead of their pass / reclaimed
    /// before use (both 0 = prefetch off)
    pub prefetched_stages: u64,
    pub prefetch_wasted: u64,
    /// device-resident cache: stages that skipped host->device upload
    pub device_cache_hits: u64,
    /// worker pool: thread spawn/joins avoided vs the per-pass design
    pub spawns_avoided: u64,
    /// continuous batching: requests that joined / left a running decode
    /// and requests shed at admission (all 0 = fixed-batch serving)
    pub joins: u64,
    pub leaves: u64,
    pub shed_overload: u64,
    /// % of SLO-targeted served requests that met their target (100 when
    /// nothing carried a target)
    pub slo_attained_pct: f64,
    /// KV prefix sharing: cross-request block share events / bytes the
    /// accountant never charged thanks to dedup (both 0 = sharing idle)
    pub shared_kv_blocks: u64,
    pub kv_dedup_bytes: u64,
    /// generated tokens per wall-clock second across the run
    pub tokens_per_sec: f64,
    /// admission: time requests spent queued before their pass started
    pub queue_wait_p50_ms: f64,
    pub queue_wait_p95_ms: f64,
    /// most engine passes in flight at once (1 = serialized router)
    pub concurrent_passes_peak: u64,
    /// fault plane: faults fired by the injection plan / transient load
    /// failures retried / passes quiesced by the watchdog / lane
    /// crash-restarts / requests re-queued across restarts (all 0 = no
    /// plan armed and nothing transient happened)
    pub faults_injected: u64,
    pub load_retries: u64,
    pub passes_timed_out: u64,
    pub lane_restarts: u64,
    pub requeued: u64,
}

impl ServeSummary {
    /// Collapse a router summary into the single-model serving report.
    pub fn from_router(s: RouterSummary, slo_ms: f64) -> ServeSummary {
        let slo = check_slo(&s.latency, slo_ms);
        ServeSummary {
            served: s.served,
            reject_reasons: s.reject_reasons,
            batches: s.batches,
            throughput_rps: s.throughput_rps,
            peak_bytes: s.peak_bytes,
            slo,
            mean_batch_size: s.mean_batch_size,
            latency: s.latency,
            cache_hits: s.cache_hits,
            cache_misses: s.cache_misses,
            kv_inc_passes: s.kv_inc_passes,
            kv_recomputes: s.kv_recomputes,
            kv_evicted_blocks: s.kv_evicted_blocks,
            budget_steps: s.budget_steps,
            elastic_evictions: s.elastic_evictions,
            replans: s.replans,
            prefetched_stages: s.prefetched_stages,
            prefetch_wasted: s.prefetch_wasted,
            device_cache_hits: s.device_cache_hits,
            spawns_avoided: s.spawns_avoided,
            joins: s.joins,
            leaves: s.leaves,
            shed_overload: s.shed_overload,
            slo_attained_pct: s.slo_attained_pct,
            shared_kv_blocks: s.shared_kv_blocks,
            kv_dedup_bytes: s.kv_dedup_bytes,
            tokens_per_sec: s.tokens_per_sec,
            queue_wait_p50_ms: s.queue_wait_p50_ms,
            queue_wait_p95_ms: s.queue_wait_p95_ms,
            concurrent_passes_peak: s.concurrent_passes_peak,
            faults_injected: s.faults_injected,
            load_retries: s.load_retries,
            passes_timed_out: s.passes_timed_out,
            lane_restarts: s.lane_restarts,
            requeued: s.requeued,
        }
    }

    /// Machine-readable summary (the `serve --json` output; stable keys so
    /// future PRs can record bench trajectories in `BENCH_*.json`).
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("served", self.served)
            .set("reject_reasons", self.reject_reasons.to_json())
            .set("batches", self.batches)
            .set("mean_batch_size", self.mean_batch_size)
            .set("throughput_rps", self.throughput_rps)
            .set("latency", self.latency.to_json())
            .set("peak_bytes", self.peak_bytes)
            .set("slo", self.slo.to_json())
            .set("cache_hits", self.cache_hits)
            .set("cache_misses", self.cache_misses)
            .set("kv_inc_passes", self.kv_inc_passes)
            .set("kv_recomputes", self.kv_recomputes)
            .set("kv_evicted_blocks", self.kv_evicted_blocks)
            .set("budget_steps", self.budget_steps)
            .set("elastic_evictions", self.elastic_evictions)
            .set("replans", self.replans)
            .set("prefetched_stages", self.prefetched_stages)
            .set("prefetch_wasted", self.prefetch_wasted)
            .set("device_cache_hits", self.device_cache_hits)
            .set("spawns_avoided", self.spawns_avoided)
            .set("joins", self.joins)
            .set("leaves", self.leaves)
            .set("shed_overload", self.shed_overload)
            .set("slo_attained_pct", self.slo_attained_pct)
            .set("shared_kv_blocks", self.shared_kv_blocks)
            .set("kv_dedup_bytes", self.kv_dedup_bytes)
            .set("tokens_per_sec", self.tokens_per_sec)
            .set("queue_wait_p50_ms", self.queue_wait_p50_ms)
            .set("queue_wait_p95_ms", self.queue_wait_p95_ms)
            .set("concurrent_passes_peak", self.concurrent_passes_peak)
            .set("faults_injected", self.faults_injected)
            .set("load_retries", self.load_retries)
            .set("passes_timed_out", self.passes_timed_out)
            .set("lane_restarts", self.lane_restarts)
            .set("requeued", self.requeued)
    }
}

/// Run the serving session; engine passes happen on this thread.  One
/// [`crate::engine::Session`] (inside the one-model router) serves every
/// batch: `Runtime::prepare` runs exactly once here, regardless of how
/// many batches follow.  A dropped producer ends the run gracefully — it
/// is a short workload, never a panic.
pub fn serve(engine: &Engine, cfg: &ServeConfig) -> Result<ServeSummary> {
    let mut router = Router::new(engine, cfg.router_config())?;
    router.set_telemetry(cfg.telemetry.clone());
    let handle = router.handle();
    let profile = cfg.run.profile.clone();
    let num = cfg.num_requests;
    let rps = cfg.arrival_rps;
    let seed = cfg.run.seed;

    // workload generator (open loop with Poisson arrivals, or closed loop)
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(seed ^ 0x5e7e);
        for _ in 0..num {
            if rps > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(rng.exp(1.0 / rps)));
            }
            if handle.submit(InferRequest::new(profile.clone())).is_err() {
                return; // router exited early; nothing left to feed
            }
        }
        handle.shutdown();
    });

    let summary = router.run()?;
    producer.join().map_err(|_| anyhow::anyhow!("workload generator panicked"))?;
    // the shim submits no deadlines and only known profiles, so a rejected
    // request can only mean a failed engine pass — surface its root cause
    // as an error, exactly like the pre-router serve() did
    if summary.rejected > 0 {
        anyhow::bail!(
            "{} of {} requests failed: {}",
            summary.rejected,
            cfg.num_requests,
            summary.first_error.as_deref().unwrap_or("see per-request responses"),
        );
    }
    Ok(ServeSummary::from_router(summary, cfg.slo_ms))
}

/// Convenience: serving defaults for the E2E example (PIPELOAD on the
/// BERT sim profile with a batch-4 entry).
pub fn e2e_default(profile: &str, agents: usize, budget: Option<u64>) -> ServeConfig {
    ServeConfig {
        run: RunConfig {
            profile: profile.into(),
            mode: Mode::PipeLoad,
            agents,
            budget,
            ..RunConfig::default()
        },
        ..ServeConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = ServeConfig::default();
        assert!(c.num_requests > 0);
        assert!(c.slo_ms > 0.0);
    }

    #[test]
    fn router_config_mirrors_serve_config() {
        let c = ServeConfig {
            run: RunConfig { budget: Some(1234), ..RunConfig::default() },
            max_batch: 7,
            ..ServeConfig::default()
        };
        let rc = c.router_config();
        assert_eq!(rc.models.len(), 1);
        assert_eq!(rc.budget, Some(1234));
        assert_eq!(rc.max_batch, 7);
        assert_eq!(rc.batch_window, c.batch_window);
    }

    #[test]
    fn summary_json_has_stable_keys() {
        let s = ServeSummary {
            served: 4,
            reject_reasons: RejectReasons::default(),
            batches: 2,
            latency: LatencyRecorder::new(),
            throughput_rps: 1.5,
            peak_bytes: 2048,
            slo: check_slo(&LatencyRecorder::new(), 100.0),
            mean_batch_size: 2.0,
            cache_hits: 1,
            cache_misses: 3,
            kv_inc_passes: 5,
            kv_recomputes: 1,
            kv_evicted_blocks: 2,
            budget_steps: 1,
            elastic_evictions: 4,
            replans: 1,
            prefetched_stages: 6,
            prefetch_wasted: 1,
            device_cache_hits: 8,
            spawns_avoided: 12,
            joins: 3,
            leaves: 3,
            shed_overload: 1,
            slo_attained_pct: 100.0,
            shared_kv_blocks: 2,
            kv_dedup_bytes: 4096,
            tokens_per_sec: 9.5,
            queue_wait_p50_ms: 0.5,
            queue_wait_p95_ms: 1.5,
            concurrent_passes_peak: 1,
            faults_injected: 0,
            load_retries: 0,
            passes_timed_out: 0,
            lane_restarts: 0,
            requeued: 0,
        };
        let v = s.to_json();
        for key in [
            "served",
            "reject_reasons",
            "batches",
            "throughput_rps",
            "latency",
            "peak_bytes",
            "slo",
            "cache_hits",
            "joins",
            "leaves",
            "shed_overload",
            "slo_attained_pct",
            "shared_kv_blocks",
            "kv_dedup_bytes",
            "tokens_per_sec",
            "faults_injected",
            "load_retries",
            "passes_timed_out",
            "lane_restarts",
            "requeued",
        ] {
            assert!(v.get(key).is_some(), "missing key {key}");
        }
        assert_eq!(v.get("slo").unwrap().get("target_ms").unwrap().as_f64().unwrap(), 100.0);
    }
}
