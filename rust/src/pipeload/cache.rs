//! Hot-layer cache: the Daemon's "pin instead of destroy" policy.
//!
//! The paper's dynamic memory management always destroys a layer's weights
//! after compute (`S_dest`).  That is optimal when the budget is the model
//! bottleneck, but generative decode re-loads every layer once per token —
//! pure waste whenever the budget has slack.  This cache generalizes the
//! policy from *always destroy* to *destroy when the budget needs it*:
//!
//! * after compute, the Daemon may **pin** a layer here (up to a dedicated
//!   pin budget) instead of dropping it — the bytes stay accounted in the
//!   pass's [`MemoryAccountant`];
//! * on the next pass, a Loading Agent that finds its stage pinned takes it
//!   straight from the cache — no disk read, no memory admission;
//! * when an admission stalls on the budget (`S^stop` pressure), the
//!   [`OrderedGate`] evicts pinned layers LRU-first until the admission
//!   fits, so pinning can never deadlock a tight-budget run.
//!
//! A taken entry leaves the cache for the duration of its pass (its bytes
//! travel with the `StageMsg`); the Daemon re-pins it after compute.  That
//! keeps eviction trivially safe: only layers not in flight are evictable.
//!
//! [`OrderedGate`]: crate::pipeload::gate::OrderedGate

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::config::PinPolicy;
use crate::memory::MemoryAccountant;
use crate::weights::Shard;

/// Counters for the cache-hit metrics in `RunReport`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// passes found the stage pinned (skipped disk + admission)
    pub hits: u64,
    /// passes had to load the stage from disk
    pub misses: u64,
    /// pinned layers reclaimed under `S^stop` pressure
    pub evictions: u64,
    /// lower-scoring pins displaced by the cost policy (their bytes go
    /// back to the budget via the gate, not counted as `evictions`)
    pub displaced: u64,
    /// bytes currently pinned
    pub pinned_bytes: u64,
    /// layers currently pinned
    pub pinned_layers: usize,
}

impl CacheStats {
    /// Hit fraction over all lookups (0.0 when the cache was never used).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[derive(Debug)]
struct Entry {
    shard: Arc<Shard>,
    bytes: u64,
    /// logical clock of the last take/pin (LRU victim = smallest)
    last_use: u64,
    /// load-cost-per-byte (cost policy's keep score; 0 under fifo)
    score: f64,
}

#[derive(Debug)]
struct CacheState {
    entries: HashMap<usize, Entry>,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    displaced: u64,
    pinned_bytes: u64,
    /// current pin cap — mutable at run time (elastic budget steps resize
    /// it through [`LayerCache::set_pin_budget`])
    pin_budget: u64,
}

/// Shared pinned-layer store; clone freely (Arc inside).
#[derive(Debug, Clone)]
pub struct LayerCache {
    policy: PinPolicy,
    inner: Arc<Mutex<CacheState>>,
}

impl LayerCache {
    /// `pin_budget` caps the bytes the Daemon may keep resident between
    /// passes; eviction under memory pressure can still undercut it.
    pub fn new(pin_budget: u64) -> LayerCache {
        LayerCache::with_policy(pin_budget, PinPolicy::Fifo)
    }

    pub fn with_policy(pin_budget: u64, policy: PinPolicy) -> LayerCache {
        LayerCache {
            policy,
            inner: Arc::new(Mutex::new(CacheState {
                entries: HashMap::new(),
                clock: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                displaced: 0,
                pinned_bytes: 0,
                pin_budget,
            })),
        }
    }

    pub fn pin_budget(&self) -> u64 {
        self.inner.lock().unwrap().pin_budget
    }

    /// Victim choice under pressure, honoring the pin policy: `fifo`
    /// evicts LRU; `cost` evicts the cheapest-to-reload pin first (oldest
    /// within a tie) — the same ordering `pin_scored` displaces by, so the
    /// bytes kept are always the most expensive to re-read.
    fn victim_of(s: &CacheState, policy: PinPolicy) -> Option<usize> {
        match policy {
            PinPolicy::Fifo => s.entries.iter().min_by_key(|(_, e)| e.last_use).map(|(&st, _)| st),
            PinPolicy::Cost => s
                .entries
                .iter()
                .min_by(|a, b| {
                    a.1.score
                        .partial_cmp(&b.1.score)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.1.last_use.cmp(&b.1.last_use))
                })
                .map(|(&st, _)| st),
        }
    }

    /// Retarget the pin cap (elastic budget step).  Shrinking below the
    /// currently pinned bytes evicts pins (policy-ordered; see
    /// [`LayerCache::victim_of`]) until the new cap holds, returning their
    /// bytes through `accountant` (they were accounted while pinned).
    /// Growing just widens future pin headroom.  Returns bytes freed; the
    /// freed bytes count as `evictions` — this IS memory pressure,
    /// arriving from outside instead of from an admission.
    pub fn set_pin_budget(&self, new_budget: u64, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        s.pin_budget = new_budget;
        let mut freed = 0u64;
        while s.pinned_bytes > new_budget {
            let victim = match Self::victim_of(&s, self.policy) {
                Some(stage) => stage,
                None => break,
            };
            let e = s.entries.remove(&victim).unwrap();
            s.pinned_bytes -= e.bytes;
            s.evictions += 1;
            freed += e.bytes;
            drop(e.shard); // the destruction
            accountant.free(e.bytes);
        }
        freed
    }

    pub fn policy(&self) -> PinPolicy {
        self.policy
    }

    /// Take a pinned stage out of the cache (hit).  The entry's bytes stay
    /// accounted with the caller, who must hand them back via
    /// [`LayerCache::pin`] or free them through the gate.
    pub fn take(&self, stage: usize) -> Option<(Arc<Shard>, u64)> {
        let mut s = self.inner.lock().unwrap();
        match s.entries.remove(&stage) {
            Some(e) => {
                s.pinned_bytes -= e.bytes;
                s.hits += 1;
                Some((e.shard, e.bytes))
            }
            None => None,
        }
    }

    /// Record that a stage had to come from disk (miss).
    pub fn record_miss(&self) {
        self.inner.lock().unwrap().misses += 1;
    }

    /// Is this stage currently pinned?  (Snapshot — prefetch tasks use it
    /// to skip loading stages the next pass will hit anyway.)
    pub fn is_pinned(&self, stage: usize) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&stage)
    }

    /// Try to pin a computed stage instead of destroying it.  Returns false
    /// when the pin budget has no room — the caller destroys as usual.
    /// The stage's bytes remain accounted in the pass accountant on success.
    pub fn pin(&self, stage: usize, shard: Arc<Shard>, bytes: u64) -> bool {
        let (pinned, displaced) = self.pin_scored(stage, shard, bytes, 0.0);
        debug_assert_eq!(displaced, 0, "unscored pins never displace");
        pinned
    }

    /// [`LayerCache::pin`] with a load-cost-per-byte score.  Under the
    /// `cost` policy a full cache still pins the new layer if strictly
    /// lower-scoring pins can be displaced to make room; the displaced
    /// bytes are returned and MUST be freed by the caller through the
    /// gate (they were accounted while pinned).  Under `fifo`, or when
    /// nothing cheap enough can be displaced, behaves like `pin`.
    pub fn pin_scored(
        &self,
        stage: usize,
        shard: Arc<Shard>,
        bytes: u64,
        score: f64,
    ) -> (bool, u64) {
        let mut s = self.inner.lock().unwrap();
        // Never double-pin a stage: with cross-pass prefetch a pass can
        // compute a buffer-sourced copy of a stage whose pin was never
        // taken, and overwriting the entry would orphan the old copy's
        // accounted bytes.  The caller destroys the duplicate as usual.
        if s.entries.contains_key(&stage) {
            return (false, 0);
        }
        let pin_budget = s.pin_budget;
        let mut displaced_bytes = 0u64;
        if s.pinned_bytes + bytes > pin_budget {
            if self.policy != PinPolicy::Cost || bytes > pin_budget {
                return (false, 0);
            }
            // cheapest-to-reload pins go first, oldest within a tie
            let mut victims: Vec<(usize, u64, f64, u64)> = s
                .entries
                .iter()
                .filter(|(_, e)| e.score < score)
                .map(|(&st, e)| (st, e.bytes, e.score, e.last_use))
                .collect();
            victims.sort_by(|a, b| {
                a.2.partial_cmp(&b.2).unwrap_or(std::cmp::Ordering::Equal).then(a.3.cmp(&b.3))
            });
            let need = s.pinned_bytes + bytes - pin_budget;
            let mut reclaim = 0u64;
            let mut chosen = Vec::new();
            for (st, b, _, _) in victims {
                if reclaim >= need {
                    break;
                }
                reclaim += b;
                chosen.push(st);
            }
            if reclaim < need {
                return (false, 0); // not enough cheap pins to displace
            }
            for st in chosen {
                let e = s.entries.remove(&st).unwrap();
                s.pinned_bytes -= e.bytes;
                s.displaced += 1;
                displaced_bytes += e.bytes;
                drop(e.shard); // the destruction
            }
        }
        s.clock += 1;
        let clock = s.clock;
        s.pinned_bytes += bytes;
        s.entries.insert(stage, Entry { shard, bytes, last_use: clock, score });
        (true, displaced_bytes)
    }

    /// `S^stop` pressure valve: evict pinned layers (policy-ordered; see
    /// [`LayerCache::victim_of`]) until `bytes` fit the accountant's
    /// budget or nothing is left.  Returns bytes freed.
    pub fn evict_for(&self, bytes: u64, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        while accountant.would_block(bytes) {
            let victim = match Self::victim_of(&s, self.policy) {
                Some(stage) => stage,
                None => break,
            };
            let e = s.entries.remove(&victim).unwrap();
            s.pinned_bytes -= e.bytes;
            s.evictions += 1;
            freed += e.bytes;
            drop(e.shard); // the destruction
            accountant.free(e.bytes);
        }
        freed
    }

    /// Drop every pinned layer AND return its bytes to `accountant` (used
    /// when a failed pass must release its pins without resetting a shared
    /// accountant that other sessions still account into).  Not counted as
    /// evictions — this is error cleanup, not `S^stop` pressure.
    pub fn drain(&self, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        for (_, e) in s.entries.drain() {
            freed += e.bytes;
            drop(e.shard);
            accountant.free(e.bytes);
        }
        s.pinned_bytes = 0;
        freed
    }

    /// Drop every pinned layer without touching the accountant (used when a
    /// failed pass resets the accountant wholesale).
    pub fn clear(&self) {
        let mut s = self.inner.lock().unwrap();
        s.entries.clear();
        s.pinned_bytes = 0;
    }

    pub fn stats(&self) -> CacheStats {
        let s = self.inner.lock().unwrap();
        CacheStats {
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
            displaced: s.displaced,
            pinned_bytes: s.pinned_bytes,
            pinned_layers: s.entries.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(stage: u32) -> Arc<Shard> {
        Arc::new(Shard { kind: "encoder_layer".into(), stage, tensors: vec![] })
    }

    #[test]
    fn pin_take_roundtrip_counts_hits() {
        let c = LayerCache::new(1000);
        assert!(c.pin(3, shard(3), 400));
        let (s, b) = c.take(3).unwrap();
        assert_eq!(s.stage, 3);
        assert_eq!(b, 400);
        assert!(c.take(3).is_none()); // taken entries leave the cache
        let st = c.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.pinned_bytes, 0);
        assert_eq!(st.pinned_layers, 0);
    }

    #[test]
    fn pin_budget_enforced() {
        let c = LayerCache::new(500);
        assert!(c.pin(0, shard(0), 300));
        assert!(!c.pin(1, shard(1), 300)); // would exceed 500
        assert!(c.pin(2, shard(2), 200));
        assert_eq!(c.stats().pinned_bytes, 500);
        assert_eq!(c.stats().pinned_layers, 2);
    }

    #[test]
    fn evict_for_frees_lru_first_until_fit() {
        let accountant = MemoryAccountant::new(Some(1000));
        let c = LayerCache::new(1000);
        for stage in 0..3usize {
            assert!(accountant.try_acquire(300));
            assert!(c.pin(stage, shard(stage as u32), 300));
        }
        assert_eq!(accountant.used(), 900);
        // wanting 500 forces two evictions (oldest pins first: 0 then 1)
        let freed = c.evict_for(500, &accountant);
        assert_eq!(freed, 600);
        assert_eq!(accountant.used(), 300);
        let st = c.stats();
        assert_eq!(st.evictions, 2);
        assert!(c.take(2).is_some(), "newest pin must survive");
        assert!(c.take(0).is_none());
        assert!(c.take(1).is_none());
    }

    #[test]
    fn evict_for_stops_when_cache_empty() {
        let accountant = MemoryAccountant::new(Some(100));
        assert!(accountant.try_acquire(100));
        let c = LayerCache::new(100);
        assert_eq!(c.evict_for(50, &accountant), 0);
        assert_eq!(accountant.used(), 100);
    }

    #[test]
    fn hit_rate_math() {
        let c = LayerCache::new(100);
        assert_eq!(c.stats().hit_rate(), 0.0);
        c.pin(0, shard(0), 10);
        c.take(0);
        c.record_miss();
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn drain_frees_through_accountant() {
        let accountant = MemoryAccountant::new(Some(1000));
        let c = LayerCache::new(1000);
        for stage in 0..2usize {
            assert!(accountant.try_acquire(300));
            assert!(c.pin(stage, shard(stage as u32), 300));
        }
        assert_eq!(c.drain(&accountant), 600);
        assert_eq!(accountant.used(), 0);
        assert_eq!(c.stats().pinned_layers, 0);
        assert_eq!(c.stats().evictions, 0, "drain is not an eviction");
    }

    #[test]
    fn cost_policy_displaces_cheaper_pins() {
        use crate::config::PinPolicy;
        let c = LayerCache::with_policy(500, PinPolicy::Cost);
        assert!(c.pin_scored(0, shard(0), 300, 1.0).0);
        assert!(c.pin_scored(1, shard(1), 200, 5.0).0);
        // cache full; a higher-scoring layer displaces the cheapest pin
        let (pinned, displaced) = c.pin_scored(2, shard(2), 250, 3.0);
        assert!(pinned);
        assert_eq!(displaced, 300, "stage 0 (score 1.0) was displaced");
        let st = c.stats();
        assert_eq!(st.displaced, 1);
        assert_eq!(st.evictions, 0, "displacement is not S^stop eviction");
        assert_eq!(st.pinned_bytes, 450);
        assert!(c.take(0).is_none());
        assert!(c.take(1).is_some());
        // a lower-scoring layer cannot displace anything
        let (pinned, displaced) = c.pin_scored(3, shard(3), 300, 0.5);
        assert!(!pinned);
        assert_eq!(displaced, 0);
    }

    #[test]
    fn fifo_policy_never_displaces() {
        let c = LayerCache::new(500);
        assert!(c.pin_scored(0, shard(0), 400, 1.0).0);
        let (pinned, displaced) = c.pin_scored(1, shard(1), 200, 99.0);
        assert!(!pinned);
        assert_eq!(displaced, 0);
    }

    #[test]
    fn set_pin_budget_shrink_evicts_lru_down_to_cap() {
        let accountant = MemoryAccountant::new(Some(1000));
        let c = LayerCache::new(900);
        for stage in 0..3usize {
            assert!(accountant.try_acquire(300));
            assert!(c.pin(stage, shard(stage as u32), 300));
        }
        // cap 400: two LRU pins (0, 1) must go, newest survives
        let freed = c.set_pin_budget(400, &accountant);
        assert_eq!(freed, 600);
        assert_eq!(c.pin_budget(), 400);
        assert_eq!(accountant.used(), 300);
        assert_eq!(c.stats().evictions, 2);
        let (_, taken) = c.take(2).expect("newest pin must survive");
        accountant.free(taken);
        // grow widens headroom without evicting anything
        assert_eq!(c.set_pin_budget(900, &accountant), 0);
        assert_eq!(c.pin_budget(), 900);
        // and the new cap is live for future pins
        assert!(accountant.try_acquire(800));
        assert!(c.pin(5, shard(5), 800));
    }

    #[test]
    fn cost_policy_pressure_evicts_cheapest_pins_first() {
        use crate::config::PinPolicy;
        let accountant = MemoryAccountant::new(Some(1000));
        let c = LayerCache::with_policy(900, PinPolicy::Cost);
        // expensive layer pinned FIRST (oldest): pure LRU would evict it
        assert!(accountant.try_acquire(300));
        assert!(c.pin_scored(0, shard(0), 300, 9.0).0);
        assert!(accountant.try_acquire(300));
        assert!(c.pin_scored(1, shard(1), 300, 1.0).0);
        assert!(accountant.try_acquire(300));
        assert!(c.pin_scored(2, shard(2), 300, 5.0).0);
        // elastic shrink to 300: the two cheapest pins (1, then 2) go
        let freed = c.set_pin_budget(300, &accountant);
        assert_eq!(freed, 600);
        assert!(c.take(0).is_some(), "the costliest pin must survive the shrink");
        // S^stop pressure uses the same ordering: re-pin cheap, then stall
        assert!(accountant.try_acquire(300));
        assert!(c.pin_scored(3, shard(3), 300, 1.0).0);
        let freed = c.evict_for(700, &accountant);
        assert_eq!(freed, 300, "cheap pin evicted under admission pressure");
        assert!(c.take(3).is_none());
    }

    #[test]
    fn clear_drops_everything() {
        let c = LayerCache::new(100);
        c.pin(0, shard(0), 50);
        c.clear();
        assert_eq!(c.stats().pinned_layers, 0);
        assert_eq!(c.stats().pinned_bytes, 0);
        assert!(c.take(0).is_none());
    }
}
