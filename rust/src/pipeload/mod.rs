//! PIPELOAD: the paper's memory-efficient pipeline execution mechanism.
//!
//! Three worker kinds cooperate over one model pass (paper Fig. 4):
//!
//! * **Loading Agents** (m threads) — stream their assigned stage shards
//!   ([`assignment`]) disk→memory through the edge-storage simulator,
//!   gated by the Daemon's ordered memory admission ([`gate`]); emit
//!   `S_comp` when a layer is resident.
//! * **Inference Agent** (the calling thread — it owns the non-Send PJRT
//!   runtime) — maintains the inference queue (an index-ordered pending
//!   map), computes layers strictly in stage order, emits `S_dest`.
//! * **Daemon Agent** (one thread) — receives `S_dest`, destroys the
//!   layer's weights and returns their bytes to the budget; its admission
//!   gate embodies `S_stop` (loading pauses while memory is short).
//!
//! The same machinery with `destroy_after_compute = false` and one agent
//! is the PipeSwitch-style *standard pipeline* comparator: layers stay
//! resident, so peak memory equals the whole model.
//!
//! # Sessions & hot-layer cache
//!
//! [`run_pipeline`] is the one-shot entry point: it builds a fresh
//! accountant + gate + assignment per pass (the paper's semantics, where
//! every generated token reloads the model).  Long-lived callers — the
//! serving loop and the generative decode loop — instead construct those
//! once in an [`engine::session::Session`] and drive [`run_pass`]
//! directly, which accepts a [`PassEnv`]:
//!
//! * a reusable [`gate::OrderedGate`] (rearmed with `reset()` per pass, so
//!   the budget and any pinned bytes persist across passes);
//! * a precomputed agent [`assignment`];
//! * an optional [`cache::LayerCache`].  With the cache attached, the
//!   Daemon *pins* computed layers (up to the pin budget) instead of
//!   destroying them, and the next pass's Loading Agents take pinned
//!   stages straight from memory — no disk read, no admission.  Under
//!   `S^stop` pressure the gate evicts pins LRU-first, so the cache only
//!   ever consumes budget slack.
//!
//! [`engine::session::Session`]: crate::engine::session::Session

pub mod assignment;
pub mod cache;
pub mod gate;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::diskio::Disk;
use crate::kvcache::KvSeq;
use crate::memory::MemoryAccountant;
use crate::model::{Profile, TensorSpec};
use crate::runtime::{literal_for_spec, Runtime};
use crate::signals::{Signal, SignalLog};
use crate::trace::{Kind, Lane, Tracer};
use crate::weights::{read_shard_from, validate_against, Shard};
use cache::LayerCache;
use gate::OrderedGate;

/// Trace/stat threshold: spans shorter than this are scheduling noise, not
/// stalls (a `recv` that found its message already waiting is not a stall).
const STALL_EPS_MS: f64 = 0.05;

/// Input to one model pass.
#[derive(Debug, Clone)]
pub enum ModelInput {
    /// token ids (BERT / GPT-2 / GPT-J / BART), padded to max_seq * batch
    Ids(Vec<i32>),
    /// flattened image patches (ViT): batch * (seq-1) * patch_dim
    Patches(Vec<f32>),
}

impl ModelInput {
    pub fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        match self {
            ModelInput::Ids(v) => literal_for_spec(spec, None, Some(v)),
            ModelInput::Patches(v) => literal_for_spec(spec, Some(v), None),
        }
    }

    /// Upload directly to a device buffer (the hot-path entry point).
    pub fn to_buffer(&self, rt: &Runtime, spec: &TensorSpec) -> Result<xla::PjRtBuffer> {
        let n: usize = spec.shape.iter().product();
        match self {
            ModelInput::Ids(v) => {
                if v.len() != n {
                    anyhow::bail!("ids len {} != spec {:?}", v.len(), spec.shape);
                }
                rt.buffer_i32(v, &spec.shape)
            }
            ModelInput::Patches(v) => {
                if v.len() != n {
                    anyhow::bail!("patches len {} != spec {:?}", v.len(), spec.shape);
                }
                rt.buffer_f32(v, &spec.shape)
            }
        }
    }
}

/// Pipeline configuration knobs.
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    /// number of Loading Agents (m)
    pub agents: usize,
    /// PIPELOAD destroys weights after compute; PipeSwitch keeps them
    pub destroy_after_compute: bool,
    /// verify shard tensors against manifest specs while loading
    pub validate_shards: bool,
}

impl PipelineOpts {
    pub fn pipeload(agents: usize) -> PipelineOpts {
        PipelineOpts { agents, destroy_after_compute: true, validate_shards: false }
    }

    /// Standard pipeline (the paper's PipeSwitch comparator): one loading
    /// stream, layer-granularity overlap, no destruction.
    pub fn pipeswitch() -> PipelineOpts {
        PipelineOpts { agents: 1, destroy_after_compute: false, validate_shards: false }
    }
}

/// Everything one pass needs (runtime stays on the calling thread).
pub struct ExecCtx<'rt> {
    pub runtime: &'rt Runtime,
    pub profile: &'rt Profile,
    /// directory holding this profile's shards: <weights>/<profile>/
    pub shard_dir: PathBuf,
    pub disk: Disk,
    pub tracer: Tracer,
    pub signals: SignalLog,
    pub batch: usize,
}

impl<'rt> ExecCtx<'rt> {
    pub fn new(runtime: &'rt Runtime, profile_name: &str, weights_dir: &Path, disk: Disk) -> Result<ExecCtx<'rt>> {
        let profile = runtime.profile(profile_name)?;
        Ok(ExecCtx {
            runtime,
            profile,
            shard_dir: weights_dir.join(&profile.name),
            disk,
            tracer: Tracer::disabled(),
            signals: SignalLog::new(),
            batch: 1,
        })
    }
}

/// Per-pass measurements (the engine aggregates these into a RunReport).
#[derive(Debug, Clone, Default)]
pub struct PassStats {
    pub peak_bytes: u64,
    pub mem_stall_ms: f64,
    pub wait_stall_ms: f64,
    pub load_ms_total: f64,
    pub compute_ms_total: f64,
    /// stages served from the hot-layer cache (sessions only)
    pub cache_hits: u64,
    /// stages loaded from disk while a cache was attached
    pub cache_misses: u64,
}

/// Error marker for a KV sequence reclaimed while its incremental pass was
/// mid-flight (`S^stop` pressure from that pass's own weight admissions).
/// The session matches on this to fall back to full-prefix recompute;
/// every other pass failure propagates.
pub const KV_EVICTED_MIDPASS: &str = "kv sequence evicted mid-pass";

/// Long-lived pipeline state a pass runs against.  [`run_pipeline`] builds
/// a throwaway one; a `Session` owns one across passes.
pub struct PassEnv<'a> {
    pub gate: &'a OrderedGate,
    /// hot-layer cache (pin-instead-of-destroy); None = paper semantics
    pub cache: Option<&'a LayerCache>,
    /// stage-to-agent assignment; must cover `opts.agents` agents
    pub plan: &'a [Vec<usize>],
}

/// What the Inference Agent computes during one pass.  Loading, admission,
/// and destruction are identical in every mode — the KV cache changes the
/// *compute* per stage, not the weight streaming the paper is about.
pub enum PassMode<'k> {
    /// full-sequence entries over the whole (padded) prefix — the paper's
    /// per-token semantics
    Full,
    /// full-sequence pass that additionally runs each body stage's `*_kv`
    /// prime entry and seeds `kv` with K/V for positions `0..prefix_len`
    PrimeKv { kv: &'k KvSeq, prefix_len: usize },
    /// single-token pass over the `*_inc` entries: the new token at
    /// position `pos` attends to the cached prefix, and each body stage
    /// appends its K/V row to `kv`.  Requires `kv.tokens() == pos` and
    /// reserved capacity for `pos + 1`.
    Incremental { kv: &'k KvSeq, pos: usize },
}

// Whether a shard came from disk or the hot-layer cache, its accounting is
// identical once in flight: bytes ride with the message, and the Daemon
// either pins them (stay accounted) or destroys them (freed via the gate).
struct StageMsg {
    stage: usize,
    #[allow(dead_code)]
    agent: usize,
    shard: Arc<Shard>,
    bytes: u64,
}

/// Run one full pipelined pass with throwaway state; returns the head
/// output buffer + stats.  (Sessions call [`run_pass`] with persistent
/// state instead.)
pub fn run_pipeline(
    ctx: &ExecCtx,
    opts: &PipelineOpts,
    budget: Option<u64>,
    input: &ModelInput,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let accountant = MemoryAccountant::new(budget);
    let gate = OrderedGate::new(accountant);
    let plan = assignment::assignment(ctx.profile.stages.len(), opts.agents.max(1));
    let env = PassEnv { gate: &gate, cache: None, plan: &plan };
    run_pass(ctx, opts, &env, input)
}

/// Run one pipelined pass against caller-owned state (gate, assignment,
/// optional hot-layer cache).  The gate must be rearmed (`reset`) by the
/// caller between passes.
pub fn run_pass(
    ctx: &ExecCtx,
    opts: &PipelineOpts,
    env: &PassEnv,
    input: &ModelInput,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    run_pass_mode(ctx, opts, env, input, &PassMode::Full)
}

/// [`run_pass`] with an explicit [`PassMode`] (the KV decode paths).
pub fn run_pass_mode(
    ctx: &ExecCtx,
    opts: &PipelineOpts,
    env: &PassEnv,
    input: &ModelInput,
    mode: &PassMode,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let profile = ctx.profile;
    if opts.agents == 0 {
        bail!("need at least one loading agent");
    }
    if !opts.destroy_after_compute {
        if let Some(b) = env.gate.accountant().budget() {
            if b < profile.total_weight_bytes {
                bail!(
                    "standard pipeline keeps all weights resident; model needs {} B > budget {} B",
                    profile.total_weight_bytes,
                    b
                );
            }
        }
    }

    let gate = env.gate;
    let accountant = gate.accountant().clone();
    let (tx_load, rx_load) = mpsc::channel::<Result<StageMsg>>();
    let (tx_dest, rx_dest) = mpsc::channel::<StageMsg>();
    let mem_stall_ms = Arc::new(Mutex::new(0.0f64));
    let load_ms = Arc::new(Mutex::new(0.0f64));
    let stats0 = env.cache.map(|c| c.stats());

    let result = std::thread::scope(|scope| -> Result<(xla::PjRtBuffer, PassStats)> {
        // ---- Daemon Agent -------------------------------------------------
        let daemon_gate = gate.clone();
        let daemon_cache = env.cache.cloned();
        let daemon_tracer = ctx.tracer.clone();
        let daemon_disk = ctx.disk.clone();
        let destroy = opts.destroy_after_compute;
        scope.spawn(move || {
            let mut kept: Vec<StageMsg> = Vec::new();
            for msg in rx_dest {
                if destroy {
                    let t0 = daemon_tracer.now_ms();
                    // Pin instead of destroy when the pin budget has room;
                    // the layer's bytes stay accounted for the next pass.
                    // The score (predicted reload cost per byte) only
                    // matters under the cost policy, where an expensive
                    // layer may displace cheaper pins; displaced bytes go
                    // back to the budget through the gate.
                    if let Some(cache) = &daemon_cache {
                        let score =
                            daemon_disk.est_load_ms(msg.bytes) / msg.bytes.max(1) as f64;
                        let (pinned, displaced) =
                            cache.pin_scored(msg.stage, msg.shard.clone(), msg.bytes, score);
                        if displaced > 0 {
                            daemon_gate.free(displaced);
                        }
                        if pinned {
                            daemon_tracer.record(
                                Lane::Daemon,
                                Kind::Pin,
                                Some(msg.stage),
                                t0,
                                daemon_tracer.now_ms(),
                            );
                            continue;
                        }
                    }
                    drop(msg.shard); // the destruction
                    daemon_gate.free(msg.bytes);
                    daemon_tracer.record(
                        Lane::Daemon,
                        Kind::Destroy,
                        Some(msg.stage),
                        t0,
                        daemon_tracer.now_ms(),
                    );
                } else {
                    kept.push(msg); // standard pipeline: stays resident
                }
            }
            for msg in kept {
                daemon_gate.free(msg.bytes);
            }
        });

        // ---- Loading Agents ----------------------------------------------
        for (agent, my_stages) in env.plan.iter().enumerate() {
            if my_stages.is_empty() {
                continue;
            }
            let gate = gate.clone();
            let cache = env.cache.cloned();
            let tx = tx_load.clone();
            let tracer = ctx.tracer.clone();
            let signals = ctx.signals.clone();
            let disk = ctx.disk.clone();
            let shard_dir = ctx.shard_dir.clone();
            let stall_acc = mem_stall_ms.clone();
            let load_acc = load_ms.clone();
            let my_stages = my_stages.clone();
            let validate = opts.validate_shards;
            scope.spawn(move || {
                for &stage_idx in &my_stages {
                    let stage = &profile.stages[stage_idx];
                    let bytes = profile.stage_bytes(stage);
                    // Hot-layer cache: a pinned stage skips disk AND
                    // admission (its bytes are already resident), but must
                    // still take its slot in the admission order — and its
                    // ordering wait is recorded exactly like a miss's.
                    if let Some(cache) = &cache {
                        if let Some((shard, bytes)) = cache.take(stage_idx) {
                            let t_gate0 = tracer.now_ms();
                            let waited = match gate.skip(stage_idx) {
                                Ok(w) => w,
                                Err(e) => {
                                    let _ = tx.send(Err(e));
                                    return;
                                }
                            };
                            let waited_ms = waited.as_secs_f64() * 1000.0;
                            if waited_ms > STALL_EPS_MS {
                                tracer.record(
                                    Lane::Loader(agent),
                                    Kind::StallMem,
                                    Some(stage_idx),
                                    t_gate0,
                                    tracer.now_ms(),
                                );
                                signals.emit(Signal::Stop { agent, ms: waited_ms });
                                *stall_acc.lock().unwrap() += waited_ms;
                            }
                            signals.emit(Signal::Comp { stage: stage_idx, agent });
                            let _ = tx.send(Ok(StageMsg { stage: stage_idx, agent, shard, bytes }));
                            continue;
                        }
                        cache.record_miss();
                    }
                    // S^stop: wait for the Daemon's memory admission.
                    let t_gate0 = tracer.now_ms();
                    let waited = match gate.admit(stage_idx, bytes) {
                        Ok(w) => w,
                        Err(e) => {
                            let _ = tx.send(Err(e.context(format!("admitting stage {stage_idx}"))));
                            return;
                        }
                    };
                    let waited_ms = waited.as_secs_f64() * 1000.0;
                    if waited_ms > STALL_EPS_MS {
                        tracer.record(
                            Lane::Loader(agent),
                            Kind::StallMem,
                            Some(stage_idx),
                            t_gate0,
                            tracer.now_ms(),
                        );
                        signals.emit(Signal::Stop { agent, ms: waited_ms });
                        *stall_acc.lock().unwrap() += waited_ms;
                    }
                    // Load disk -> memory through the throttled stream.
                    let t0 = tracer.now_ms();
                    let loaded: Result<Shard> = (|| {
                        let reader = disk.open(&shard_dir.join(&stage.shard))?;
                        let shard = read_shard_from(reader)
                            .with_context(|| format!("shard {}", stage.shard))?;
                        if validate {
                            validate_against(&shard, profile.stage_params(stage)?)?;
                        }
                        Ok(shard)
                    })();
                    match loaded {
                        Ok(shard) => {
                            let t1 = tracer.now_ms();
                            tracer.record(Lane::Loader(agent), Kind::Load, Some(stage_idx), t0, t1);
                            *load_acc.lock().unwrap() += t1 - t0;
                            // S_comp: layer ready for computation.
                            signals.emit(Signal::Comp { stage: stage_idx, agent });
                            let _ = tx.send(Ok(StageMsg {
                                stage: stage_idx,
                                agent,
                                shard: Arc::new(shard),
                                bytes,
                            }));
                        }
                        Err(e) => {
                            gate.free(bytes);
                            let _ = tx.send(Err(e));
                            return;
                        }
                    }
                }
            });
        }
        drop(tx_load);

        // ---- Inference Agent (this thread owns the PJRT runtime) ----------
        let run = inference_loop(ctx, profile, input, rx_load, &tx_dest, gate, mode);
        drop(tx_dest); // closes the daemon; scope joins it
        match &run {
            Ok(_) => {}
            Err(_) => gate.shutdown(), // unblock any still-waiting loaders
        }
        let (out, mut stats) = run?;
        stats.peak_bytes = accountant.peak();
        stats.mem_stall_ms = *mem_stall_ms.lock().unwrap();
        stats.load_ms_total = *load_ms.lock().unwrap();
        if let (Some(c), Some(s0)) = (env.cache, stats0) {
            let s1 = c.stats();
            stats.cache_hits = s1.hits - s0.hits;
            stats.cache_misses = s1.misses - s0.misses;
        }
        Ok((out, stats))
    });

    result
}

/// The Inference Agent: strict stage-order compute with a pending queue.
///
/// In [`PassMode::Incremental`] every stage executes its `*_inc` entry:
/// the activation chain is `[B,1,H]`, body stages take the dense cached
/// K/V plus the position, and their `[B,3,H]` output is unpacked on the
/// host (row 0 continues the pass; rows 1–2 are the token's K/V, appended
/// to the sequence).  In [`PassMode::PrimeKv`] the pass runs the normal
/// full-sequence entries but each body stage also executes its `*_kv`
/// prime entry to seed the cache with the whole prefix.  Weight loading,
/// admission, and destruction are identical in every mode.
#[allow(clippy::too_many_arguments)]
fn inference_loop(
    ctx: &ExecCtx,
    profile: &Profile,
    input: &ModelInput,
    rx_load: mpsc::Receiver<Result<StageMsg>>,
    tx_dest: &mpsc::Sender<StageMsg>,
    gate: &OrderedGate,
    mode: &PassMode,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let accountant = gate.accountant();
    let mut stats = PassStats::default();
    let mut pending: HashMap<usize, StageMsg> = HashMap::new();
    let n_stages = profile.stages.len();
    let incremental = matches!(mode, PassMode::Incremental { .. });
    let body_kind = profile.body_kind();
    // ordinal of the current body stage among the KV sequence's layers
    let mut kv_layer = 0usize;

    // current activation buffer(s); starts as the model input
    let mut act: Option<xla::PjRtBuffer> = None; // built at stage 0
    let mut act_bytes: u64 = 0;
    let mut enc_out: Option<xla::PjRtBuffer> = None; // BART cross-attention
    let mut enc_out_bytes: u64 = 0;

    for k in 0..n_stages {
        // wait for S_comp(k) — the inference queue guarantees order
        while !pending.contains_key(&k) {
            let t0 = ctx.tracer.now_ms();
            match rx_load.recv() {
                Ok(Ok(msg)) => {
                    let t1 = ctx.tracer.now_ms();
                    // Only a recv that actually blocked is a pipeline stall
                    // (Fig 1b); a message that was already waiting returns
                    // in ~microseconds and must not inflate idle_fraction.
                    if t1 - t0 > STALL_EPS_MS {
                        ctx.tracer.record(Lane::Inference, Kind::StallWait, Some(k), t0, t1);
                        stats.wait_stall_ms += t1 - t0;
                    }
                    pending.insert(msg.stage, msg);
                }
                Ok(Err(e)) => {
                    gate.shutdown();
                    return Err(e.context("loading agent failed"));
                }
                Err(_) => {
                    return Err(anyhow!(
                        "loading agents exited before stage {k} arrived (of {n_stages})"
                    ));
                }
            }
        }
        let msg = pending.remove(&k).unwrap();
        let stage = &profile.stages[k];
        let is_body = stage.kind == body_kind;
        let entry = if incremental {
            profile
                .entry(&format!("{}_inc", stage.kind), ctx.batch)
                .with_context(|| format!("incremental decode entry for stage {k}"))?
        } else {
            profile.entry(&stage.kind, ctx.batch)?
        };

        // assemble activation inputs for this entry
        if k == 0 {
            let b = input.to_buffer(ctx.runtime, &entry.activations[0])?;
            act_bytes = entry.activations[0].num_bytes() as u64;
            accountant.force_add(act_bytes);
            act = Some(b);
        } else if stage.kind == "cross_decoder_layer" && enc_out.is_none() {
            // first decoder layer: the encoder output doubles as the
            // decoder seed (simplified seq2seq trace, DESIGN.md §2)
            enc_out_bytes = act_bytes;
            accountant.force_add(enc_out_bytes);
            enc_out = act.take();
            act = None;
        }

        // incremental-only inputs: position scalar + dense cached K/V
        let mut pos_buf: Option<xla::PjRtBuffer> = None;
        let mut kv_bufs: Option<(xla::PjRtBuffer, xla::PjRtBuffer)> = None;
        let mut kv_in_bytes = 0u64;
        if let PassMode::Incremental { kv, pos } = mode {
            if k == 0 || is_body {
                pos_buf = Some(ctx.runtime.buffer_i32(&[*pos as i32], &[1])?);
            }
            if is_body {
                // A sequence evicted mid-pass (S^stop pressure from this
                // very pass's weight admissions) cannot finish this token
                // incrementally; the caller recomputes it full-prefix.
                let (dk, dv) = kv
                    .dense_kv(kv_layer, profile.max_seq)
                    .ok_or_else(|| anyhow!("{KV_EVICTED_MIDPASS} at stage {k}"))?;
                kv_in_bytes = entry.activations[1].num_bytes() as u64
                    + entry.activations[2].num_bytes() as u64;
                accountant.force_add(kv_in_bytes);
                let shape = [ctx.batch, profile.max_seq, profile.hidden];
                kv_bufs = Some((
                    ctx.runtime.buffer_f32(&dk, &shape)?,
                    ctx.runtime.buffer_f32(&dv, &shape)?,
                ));
            }
        }

        let x_ref;
        let act_refs: Vec<&xla::PjRtBuffer> = if incremental {
            let x = act.as_ref().ok_or_else(|| anyhow!("no activation at stage {k}"))?;
            if k == 0 {
                vec![x, pos_buf.as_ref().unwrap()]
            } else if is_body {
                let (kb, vb) = kv_bufs.as_ref().unwrap();
                vec![x, kb, vb, pos_buf.as_ref().unwrap()]
            } else {
                vec![x]
            }
        } else if stage.kind == "cross_decoder_layer" {
            let enc = enc_out.as_ref().unwrap();
            match act.as_ref() {
                Some(x) => vec![x, enc],
                None => vec![enc, enc], // first cross layer: seed = enc out
            }
        } else {
            x_ref = act.as_ref().ok_or_else(|| anyhow!("no activation at stage {k}"))?;
            vec![x_ref]
        };

        // full-prefix K/V prime: seed the cache from this stage's input
        // activation before the main entry consumes it
        if let PassMode::PrimeKv { kv, prefix_len } = mode {
            if is_body {
                let kv_entry = profile.entry(&format!("{}_kv", stage.kind), ctx.batch)?;
                let kv_out_bytes = kv_entry.output.num_bytes() as u64;
                accountant.force_add(kv_out_bytes);
                let kv_out = ctx
                    .runtime
                    .execute_entry(profile, kv_entry, &act_refs, &msg.shard)
                    .with_context(|| format!("priming kv at stage {k}"))?;
                let host = ctx.runtime.buffer_to_f32(&kv_out)?;
                drop(kv_out);
                gate.free(kv_out_bytes);
                // [B, 2S, H] -> token-major [T][B][H] rows for K and V
                let (s_len, h, b_sz, n) = (profile.max_seq, profile.hidden, ctx.batch, *prefix_len);
                let mut kx = vec![0f32; n * b_sz * h];
                let mut vx = vec![0f32; n * b_sz * h];
                for row in 0..b_sz {
                    for t in 0..n {
                        let src_k = row * 2 * s_len * h + t * h;
                        let src_v = row * 2 * s_len * h + (s_len + t) * h;
                        let dst = t * b_sz * h + row * h;
                        kx[dst..dst + h].copy_from_slice(&host[src_k..src_k + h]);
                        vx[dst..dst + h].copy_from_slice(&host[src_v..src_v + h]);
                    }
                }
                kv.write_prefix(kv_layer, n, &kx, &vx);
            }
        }

        // transient copy of weights inside execute (device upload)
        accountant.force_add(msg.bytes);
        let t0 = ctx.tracer.now_ms();
        let out = ctx
            .runtime
            .execute_entry(profile, entry, &act_refs, &msg.shard)
            .with_context(|| format!("executing stage {k} ({})", entry.kind))?;
        let t1 = ctx.tracer.now_ms();
        ctx.tracer.record(Lane::Inference, Kind::Compute, Some(k), t0, t1);
        stats.compute_ms_total += t1 - t0;
        gate.free(msg.bytes);
        drop(act_refs);
        if kv_in_bytes > 0 {
            drop(kv_bufs.take()); // dense K/V uploads die with the stage
            gate.free(kv_in_bytes);
        }

        if incremental && is_body {
            // unpack [B,3,H]: row 0 continues the pass, rows 1–2 are the
            // token's K/V, appended to the cached sequence
            let out_bytes = entry.output.num_bytes() as u64;
            accountant.force_add(out_bytes);
            let host = ctx.runtime.buffer_to_f32(&out)?;
            drop(out);
            let (h, b_sz) = (profile.hidden, ctx.batch);
            let mut xr = vec![0f32; b_sz * h];
            let mut kr = vec![0f32; b_sz * h];
            let mut vr = vec![0f32; b_sz * h];
            for row in 0..b_sz {
                let base = row * 3 * h;
                xr[row * h..(row + 1) * h].copy_from_slice(&host[base..base + h]);
                kr[row * h..(row + 1) * h].copy_from_slice(&host[base + h..base + 2 * h]);
                vr[row * h..(row + 1) * h].copy_from_slice(&host[base + 2 * h..base + 3 * h]);
            }
            if let PassMode::Incremental { kv, pos } = mode {
                kv.write_token(kv_layer, *pos, &kr, &vr);
            }
            let new_act = ctx.runtime.buffer_f32(&xr, &[b_sz, 1, h])?;
            let new_bytes = (b_sz * h * 4) as u64;
            accountant.force_add(new_bytes);
            gate.free(out_bytes);
            gate.free(act_bytes);
            act_bytes = new_bytes;
            act = Some(new_act);
        } else {
            // swap activation accounting: new out replaces old act
            let out_bytes = entry.output.num_bytes() as u64;
            accountant.force_add(out_bytes);
            gate.free(act_bytes);
            act_bytes = out_bytes;
            act = Some(out);
        }
        if is_body {
            kv_layer += 1;
        }

        // S_dest: hand the layer to the Daemon for destruction (or pinning)
        ctx.signals.emit(Signal::Dest { stage: k });
        let _ = tx_dest.send(msg);
    }
    if enc_out.is_some() {
        gate.free(enc_out_bytes);
    }
    gate.free(act_bytes);
    ctx.signals.emit(Signal::Done);
    Ok((act.unwrap(), stats))
}
