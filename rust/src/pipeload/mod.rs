//! PIPELOAD: the paper's memory-efficient pipeline execution mechanism.
//!
//! Three worker kinds cooperate over one model pass (paper Fig. 4):
//!
//! * **Loading Agents** (m threads) — stream their assigned stage shards
//!   ([`assignment`]) disk→memory through the edge-storage simulator,
//!   gated by the Daemon's ordered memory admission ([`gate`]); emit
//!   `S_comp` when a layer is resident.
//! * **Inference Agent** (the calling thread — it owns the non-Send PJRT
//!   runtime) — maintains the inference queue (an index-ordered pending
//!   map), computes layers strictly in stage order, emits `S_dest`.
//! * **Daemon Agent** (one thread) — receives `S_dest`, destroys the
//!   layer's weights and returns their bytes to the budget; its admission
//!   gate embodies `S_stop` (loading pauses while memory is short).
//!
//! The same machinery with `destroy_after_compute = false` and one agent
//! is the PipeSwitch-style *standard pipeline* comparator: layers stay
//! resident, so peak memory equals the whole model.
//!
//! # Sessions, worker pool & caches
//!
//! [`run_pipeline`] is the one-shot entry point: it builds a fresh
//! accountant + gate + assignment + throwaway [`pool::WorkerPool`] per
//! pass (the paper's semantics, where every generated token reloads the
//! model).  Long-lived callers — the serving loop and the generative
//! decode loop — instead construct those once in an
//! [`engine::session::Session`] and drive [`run_pass`] directly, which
//! accepts a [`PassEnv`]:
//!
//! * a reusable [`gate::OrderedGate`] (rearmed with `begin_pass` per
//!   pass/epoch, so the budget and any pinned bytes persist across
//!   passes);
//! * a precomputed agent [`assignment`];
//! * a persistent [`pool::WorkerPool`] — Loading Agents and the Daemon
//!   are long-lived threads fed per-pass work descriptors, not per-pass
//!   spawns;
//! * an optional [`cache::LayerCache`].  With the cache attached, the
//!   Daemon *pins* computed layers (up to the pin budget) instead of
//!   destroying them, and the next pass's Loading Agents take pinned
//!   stages straight from memory — no disk read, no admission.  Under
//!   `S^stop` pressure the gate evicts pins LRU-first, so the cache only
//!   ever consumes budget slack;
//! * an optional [`prefetch::PrefetchBuffer`] + depth: while this pass's
//!   tail computes, idle loaders speculatively load the NEXT pass's head
//!   stages into the buffer (bounded by `--prefetch-depth`; admission
//!   never takes more than budget slack minus `max_stage` headroom);
//! * an optional [`device::DeviceCache`]: stages whose weight
//!   `PjRtBuffer`s were retained after a previous pass's execute skip the
//!   host→device upload entirely (the inference-side companion to the
//!   host-byte `LayerCache`).
//!
//! [`engine::session::Session`]: crate::engine::session::Session

pub mod assignment;
pub mod cache;
pub mod device;
pub mod gate;
pub mod pool;
pub mod prefetch;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::diskio::Disk;
use crate::faults::{FaultInjector, RetryPolicy};
use crate::kvcache::KvSeq;
use crate::memory::MemoryAccountant;
use crate::model::{Profile, StageSpec, TensorSpec};
use crate::runtime::{literal_for_spec, Runtime};
use crate::signals::{Signal, SignalLog};
use crate::telemetry::{worker, EvArgs, Telemetry};
use crate::trace::{Kind, Lane, Tracer};
use crate::weights::Shard;
use cache::LayerCache;
use device::DeviceCache;
use gate::OrderedGate;
use pool::{
    DaemonTask, LoadMsg, PassShared, PassTask, PrefetchTask, StageJob, TaskGroup, WorkerPool,
};
use prefetch::PrefetchBuffer;

/// Trace/stat threshold: spans shorter than this are scheduling noise, not
/// stalls (a `recv` that found its message already waiting is not a stall).
pub(crate) const STALL_EPS_MS: f64 = 0.05;

/// Input to one model pass.
#[derive(Debug, Clone)]
pub enum ModelInput {
    /// token ids (BERT / GPT-2 / GPT-J / BART), padded to max_seq * batch
    Ids(Vec<i32>),
    /// flattened image patches (ViT): batch * (seq-1) * patch_dim
    Patches(Vec<f32>),
}

impl ModelInput {
    pub fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        match self {
            ModelInput::Ids(v) => literal_for_spec(spec, None, Some(v)),
            ModelInput::Patches(v) => literal_for_spec(spec, Some(v), None),
        }
    }

    /// Upload directly to a device buffer (the hot-path entry point).
    pub fn to_buffer(&self, rt: &Runtime, spec: &TensorSpec) -> Result<xla::PjRtBuffer> {
        let n: usize = spec.shape.iter().product();
        match self {
            ModelInput::Ids(v) => {
                if v.len() != n {
                    anyhow::bail!("ids len {} != spec {:?}", v.len(), spec.shape);
                }
                rt.buffer_i32(v, &spec.shape)
            }
            ModelInput::Patches(v) => {
                if v.len() != n {
                    anyhow::bail!("patches len {} != spec {:?}", v.len(), spec.shape);
                }
                rt.buffer_f32(v, &spec.shape)
            }
        }
    }
}

/// Pipeline configuration knobs.
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    /// number of Loading Agents (m)
    pub agents: usize,
    /// PIPELOAD destroys weights after compute; PipeSwitch keeps them
    pub destroy_after_compute: bool,
    /// verify shard tensors against manifest specs while loading
    pub validate_shards: bool,
}

impl PipelineOpts {
    pub fn pipeload(agents: usize) -> PipelineOpts {
        PipelineOpts { agents, destroy_after_compute: true, validate_shards: false }
    }

    /// Standard pipeline (the paper's PipeSwitch comparator): one loading
    /// stream, layer-granularity overlap, no destruction.
    pub fn pipeswitch() -> PipelineOpts {
        PipelineOpts { agents: 1, destroy_after_compute: false, validate_shards: false }
    }
}

/// Everything one pass needs (runtime stays on the calling thread).
pub struct ExecCtx<'rt> {
    pub runtime: &'rt Runtime,
    pub profile: &'rt Profile,
    /// directory holding this profile's shards: <weights>/<profile>/
    pub shard_dir: PathBuf,
    pub disk: Disk,
    pub tracer: Tracer,
    /// structured event bus (off by default; attach via
    /// `Session::set_telemetry` or directly for one-shot passes)
    pub telemetry: Telemetry,
    pub signals: SignalLog,
    pub batch: usize,
    /// deterministic fault probes threaded down to loaders and the disk
    pub faults: FaultInjector,
    /// transient shard-load retry schedule
    pub retry: RetryPolicy,
}

impl<'rt> ExecCtx<'rt> {
    pub fn new(runtime: &'rt Runtime, profile_name: &str, weights_dir: &Path, disk: Disk) -> Result<ExecCtx<'rt>> {
        let profile = runtime.profile(profile_name)?;
        Ok(ExecCtx {
            runtime,
            profile,
            shard_dir: weights_dir.join(&profile.name),
            disk,
            tracer: Tracer::disabled(),
            telemetry: Telemetry::off(),
            signals: SignalLog::new(),
            batch: 1,
            faults: FaultInjector::off(),
            retry: RetryPolicy::default(),
        })
    }
}

/// Per-pass measurements (the engine aggregates these into a RunReport).
#[derive(Debug, Clone, Default)]
pub struct PassStats {
    pub peak_bytes: u64,
    pub mem_stall_ms: f64,
    pub wait_stall_ms: f64,
    pub load_ms_total: f64,
    pub compute_ms_total: f64,
    /// stages served from the hot-layer cache (sessions only)
    pub cache_hits: u64,
    /// stages loaded from disk while a cache was attached
    pub cache_misses: u64,
    /// stages executed from device-resident weights (upload skipped)
    pub device_cache_hits: u64,
}

/// Error marker for a KV sequence reclaimed while its incremental pass was
/// mid-flight (`S^stop` pressure from that pass's own weight admissions).
/// The session matches on this to fall back to full-prefix recompute;
/// every other pass failure propagates.
pub const KV_EVICTED_MIDPASS: &str = "kv sequence evicted mid-pass";

/// Long-lived pipeline state a pass runs against.  [`run_pipeline`] builds
/// a throwaway one; a `Session` owns one across passes.
pub struct PassEnv<'a> {
    pub gate: &'a OrderedGate,
    /// hot-layer cache (pin-instead-of-destroy); None = paper semantics
    pub cache: Option<&'a LayerCache>,
    /// stage-to-agent assignment; must cover `opts.agents` agents
    pub plan: &'a [Vec<usize>],
    /// persistent Loading Agent / Daemon threads
    pub pool: &'a WorkerPool,
    /// this pass's admission epoch (monotonic per session)
    pub epoch: u64,
    /// cross-pass prefetch buffer; None = no speculation
    pub prefetch: Option<&'a PrefetchBuffer>,
    /// head stages of the NEXT pass that idle loaders may load early
    pub prefetch_depth: usize,
    /// true when the caller knows another pass follows (decode loops);
    /// prefetch work is only dispatched then
    pub expect_next: bool,
    /// in-flight prefetch task counter (error recovery waits on it)
    pub prefetch_group: Option<&'a TaskGroup>,
    /// device-resident weight cache (inference-thread side)
    pub device: Option<&'a DeviceCache>,
}

/// What the Inference Agent computes during one pass.  Loading, admission,
/// and destruction are identical in every mode — the KV cache changes the
/// *compute* per stage, not the weight streaming the paper is about.
pub enum PassMode<'k> {
    /// full-sequence entries over the whole (padded) prefix — the paper's
    /// per-token semantics
    Full,
    /// full-sequence pass that additionally runs each body stage's `*_kv`
    /// prime entry and seeds `kv` with K/V for positions `0..prefix_len`
    PrimeKv { kv: &'k KvSeq, prefix_len: usize },
    /// single-token pass over the `*_inc` entries: the new token at
    /// position `pos` attends to the cached prefix, and each body stage
    /// appends its K/V row to `kv`.  Requires `kv.tokens() == pos` and
    /// reserved capacity for `pos + 1`.
    Incremental { kv: &'k KvSeq, pos: usize },
}

// Whether a shard came from disk, the hot-layer cache, or the prefetch
// buffer, its accounting is identical once in flight: bytes ride with the
// message, and the Daemon either pins them (stay accounted) or destroys
// them (freed via the gate).
pub(crate) struct StageMsg {
    pub(crate) stage: usize,
    #[allow(dead_code)]
    pub(crate) agent: usize,
    pub(crate) shard: Arc<Shard>,
    pub(crate) bytes: u64,
}

/// Run one full pipelined pass with throwaway state; returns the head
/// output buffer + stats.  (Sessions call [`run_pass`] with persistent
/// state instead.)
pub fn run_pipeline(
    ctx: &ExecCtx,
    opts: &PipelineOpts,
    budget: Option<u64>,
    input: &ModelInput,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let accountant = MemoryAccountant::new(budget);
    let gate = OrderedGate::new(accountant);
    let plan = assignment::assignment(ctx.profile.stages.len(), opts.agents.max(1));
    let pool = WorkerPool::new(opts.agents.max(1));
    let env = PassEnv {
        gate: &gate,
        cache: None,
        plan: &plan,
        pool: &pool,
        epoch: 0,
        prefetch: None,
        prefetch_depth: 0,
        expect_next: false,
        prefetch_group: None,
        device: None,
    };
    run_pass(ctx, opts, &env, input)
}

/// Run one pipelined pass against caller-owned state (gate, assignment,
/// optional hot-layer cache).  The gate must be rearmed (`reset`) by the
/// caller between passes.
pub fn run_pass(
    ctx: &ExecCtx,
    opts: &PipelineOpts,
    env: &PassEnv,
    input: &ModelInput,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    run_pass_mode(ctx, opts, env, input, &PassMode::Full)
}

/// Build the `'static` per-stage job descriptors one agent's task needs.
fn make_jobs(profile: &Profile, stages: &[usize], validate: bool) -> Result<Vec<StageJob>> {
    stages
        .iter()
        .map(|&stage_idx| {
            let stage: &StageSpec = &profile.stages[stage_idx];
            let params =
                if validate { Some(profile.stage_params(stage)?.to_vec()) } else { None };
            Ok(StageJob {
                stage: stage_idx,
                shard_file: stage.shard.clone(),
                bytes: profile.stage_bytes(stage),
                params,
            })
        })
        .collect()
}

/// [`run_pass`] with an explicit [`PassMode`] (the KV decode paths).
///
/// The pass dispatches work descriptors to the persistent
/// [`pool::WorkerPool`] (one [`PassTask`] per active agent + one
/// [`DaemonTask`]), then runs the Inference Agent on the calling thread.
/// When `env.expect_next` is set and a prefetch buffer is attached, the
/// NEXT pass's head stages are dispatched as [`PrefetchTask`]s right away:
/// they queue behind each agent's current-pass work, so idle loaders
/// overlap them with this pass's tail compute.  Before returning, the pass
/// waits for its loader done-markers and the daemon's ack — every
/// pin/destroy decision has landed when the next pass begins.
pub fn run_pass_mode(
    ctx: &ExecCtx,
    opts: &PipelineOpts,
    env: &PassEnv,
    input: &ModelInput,
    mode: &PassMode,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let profile = ctx.profile;
    if opts.agents == 0 {
        bail!("need at least one loading agent");
    }
    if !opts.destroy_after_compute {
        if let Some(b) = env.gate.accountant().budget() {
            if b < profile.total_weight_bytes {
                bail!(
                    "standard pipeline keeps all weights resident; model needs {} B > budget {} B",
                    profile.total_weight_bytes,
                    b
                );
            }
        }
    }

    let gate = env.gate;
    let accountant = gate.accountant().clone();
    let (tx_load, rx_load) = mpsc::channel::<LoadMsg>();
    let (tx_dest, rx_dest) = mpsc::channel::<StageMsg>();
    let (ack_tx, ack_rx) = mpsc::channel::<()>();
    let stats0 = env.cache.map(|c| c.stats());

    let shared = Arc::new(PassShared {
        gate: gate.clone(),
        cache: env.cache.cloned(),
        buffer: env.prefetch.cloned(),
        disk: ctx.disk.clone(),
        tracer: ctx.tracer.clone(),
        telemetry: ctx.telemetry.clone(),
        epoch: env.epoch,
        signals: ctx.signals.clone(),
        shard_dir: ctx.shard_dir.clone(),
        faults: ctx.faults.clone(),
        retry: ctx.retry,
    });

    // Build EVERY per-agent descriptor before dispatching anything: the
    // realistic dispatch-time failure (a manifest lookup in make_jobs)
    // must fail here, while no task is running yet — an early return
    // after a partial dispatch would strand loaders with no join path
    // (the guarantee the old thread::scope gave for free).
    let mut pass_work: Vec<(usize, Vec<StageJob>)> = Vec::new();
    for (agent, my_stages) in env.plan.iter().enumerate() {
        if my_stages.is_empty() {
            continue;
        }
        pass_work.push((agent, make_jobs(profile, my_stages, opts.validate_shards)?));
    }
    let mut prefetch_work: Vec<(usize, Vec<StageJob>)> = Vec::new();
    if env.expect_next && env.prefetch.is_some() && env.prefetch_depth > 0 {
        for (agent, my_stages) in env.plan.iter().enumerate() {
            let head: Vec<usize> =
                my_stages.iter().copied().filter(|&s| s < env.prefetch_depth).collect();
            if !head.is_empty() {
                prefetch_work.push((agent, make_jobs(profile, &head, opts.validate_shards)?));
            }
        }
    }

    // ---- Daemon Agent (persistent thread, per-pass stream) ---------------
    env.pool.submit_daemon(DaemonTask {
        rx: rx_dest,
        shared: shared.clone(),
        destroy: opts.destroy_after_compute,
        ack: ack_tx,
    })?;

    // ---- Loading Agents (persistent threads, per-pass descriptors) -------
    // A submit can only fail if a worker thread died; collect the error
    // instead of returning so already-dispatched tasks are still quiesced
    // below before this pass gives up.
    let mut dispatch_err: Option<anyhow::Error> = None;
    let mut active_agents = 0usize;
    for (agent, jobs) in pass_work {
        let task = PassTask {
            epoch: env.epoch,
            agent,
            jobs,
            tx: tx_load.clone(),
            shared: shared.clone(),
        };
        match env.pool.submit_pass(agent, task) {
            Ok(()) => active_agents += 1,
            Err(e) => {
                dispatch_err = Some(e);
                break;
            }
        }
    }
    drop(tx_load);
    env.pool.note_pass(active_agents as u64);

    // ---- Cross-pass prefetch (overlaps this pass's tail compute) ---------
    if dispatch_err.is_none() && !prefetch_work.is_empty() {
        let reserve = profile.max_stage_bytes();
        let group = env.prefetch_group.cloned().unwrap_or_default();
        for (agent, jobs) in prefetch_work {
            let task = PrefetchTask {
                agent,
                jobs,
                shared: shared.clone(),
                reserve,
                group: group.clone(),
            };
            if let Err(e) = env.pool.submit_prefetch(agent, task) {
                dispatch_err = Some(e);
                break;
            }
        }
    }

    // ---- Inference Agent (this thread owns the PJRT runtime) -------------
    let run = match dispatch_err {
        Some(e) => {
            // failed dispatch: abort the tasks that DID start (parked
            // admissions error out) and drain their done-markers, so the
            // caller's recovery never races a live loader
            gate.shutdown();
            let mut done = 0usize;
            while done < active_agents {
                match rx_load.recv() {
                    Ok(LoadMsg::AgentDone { .. }) => done += 1,
                    Ok(_) => {}
                    Err(_) => break,
                }
            }
            Err(e)
        }
        None => inference_loop(
            ctx,
            profile,
            input,
            rx_load,
            &tx_dest,
            gate,
            mode,
            env.device,
            active_agents,
        ),
    };
    drop(tx_dest); // closes this pass's daemon stream
    // the daemon ack guarantees every pin/destroy decision landed before
    // the caller inspects caches or starts the next pass
    let _ = ack_rx.recv();
    let (out, mut stats) = run?;
    stats.peak_bytes = accountant.peak();
    if let (Some(c), Some(s0)) = (env.cache, stats0) {
        let s1 = c.stats();
        stats.cache_hits = s1.hits - s0.hits;
        stats.cache_misses = s1.misses - s0.misses;
    }
    Ok((out, stats))
}

/// The Inference Agent: strict stage-order compute with a pending queue.
///
/// In [`PassMode::Incremental`] every stage executes its `*_inc` entry:
/// the activation chain is `[B,1,H]`, body stages take the dense cached
/// K/V plus the position, and their `[B,3,H]` output is unpacked on the
/// host (row 0 continues the pass; rows 1–2 are the token's K/V, appended
/// to the sequence).  In [`PassMode::PrimeKv`] the pass runs the normal
/// full-sequence entries but each body stage also executes its `*_kv`
/// prime entry to seed the cache with the whole prefix.  Weight loading,
/// admission, and destruction are identical in every mode.
///
/// A stage held by the [`DeviceCache`] executes straight from its retained
/// weight `PjRtBuffer`s — no host→device upload, and no transient
/// device-copy accounting (the resident copy's bytes are already
/// accounted).  Freshly uploaded stages may be *retained* into the cache
/// after compute, in which case their device-copy bytes stay accounted
/// instead of being freed.
///
/// Before returning — success or failure — the loop drains its loaders'
/// [`LoadMsg::AgentDone`] markers (shutting the gate down first on
/// failure), so the caller never races still-running pass tasks; the
/// markers carry each agent's locally-accumulated stall/load totals.
#[allow(clippy::too_many_arguments)]
fn inference_loop(
    ctx: &ExecCtx,
    profile: &Profile,
    input: &ModelInput,
    rx_load: mpsc::Receiver<LoadMsg>,
    tx_dest: &mpsc::Sender<StageMsg>,
    gate: &OrderedGate,
    mode: &PassMode,
    device: Option<&DeviceCache>,
    expected_agents: usize,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let mut stats = PassStats::default();
    let mut agents_done = 0usize;
    let mut run = inference_core(
        ctx,
        profile,
        input,
        &rx_load,
        tx_dest,
        gate,
        mode,
        device,
        &mut stats,
        &mut agents_done,
    );
    if run.is_err() {
        gate.shutdown(); // unblock loaders still parked on admission
    }
    // Quiesce this pass's loader tasks: every task ends with an AgentDone
    // marker carrying its local stall/load sums — one message per agent
    // per pass instead of two lock round-trips per stage.
    while agents_done < expected_agents {
        match rx_load.recv() {
            Ok(LoadMsg::AgentDone { mem_stall_ms, load_ms }) => {
                agents_done += 1;
                stats.mem_stall_ms += mem_stall_ms;
                stats.load_ms_total += load_ms;
            }
            Ok(LoadMsg::Failed(e)) => {
                if run.is_ok() {
                    run = Err(e.context("loading agent failed"));
                    gate.shutdown();
                }
            }
            Ok(LoadMsg::Stage(_)) => {} // surplus stage from an aborted pass
            Err(_) => break,            // all senders gone: tasks finished
        }
    }
    let (out, _) = run?;
    Ok((out, stats))
}

/// The per-stage compute body of [`inference_loop`] (split out so the
/// wrapper can always drain loader done-markers, on every exit path).
#[allow(clippy::too_many_arguments)]
fn inference_core(
    ctx: &ExecCtx,
    profile: &Profile,
    input: &ModelInput,
    rx_load: &mpsc::Receiver<LoadMsg>,
    tx_dest: &mpsc::Sender<StageMsg>,
    gate: &OrderedGate,
    mode: &PassMode,
    device: Option<&DeviceCache>,
    stats: &mut PassStats,
    agents_done: &mut usize,
) -> Result<(xla::PjRtBuffer, ())> {
    let mut pending: HashMap<usize, StageMsg> = HashMap::new();
    let n_stages = profile.stages.len();
    let tel_on = ctx.telemetry.is_on();
    let incremental = matches!(mode, PassMode::Incremental { .. });
    let body_kind = profile.body_kind();
    // ordinal of the current body stage among the KV sequence's layers
    let mut kv_layer = 0usize;
    if let Some(d) = device {
        d.sweep(); // drop buffers the eviction chain reclaimed since
    }

    // current activation buffer(s); starts as the model input
    let mut act: Option<xla::PjRtBuffer> = None; // built at stage 0
    let mut act_bytes: u64 = 0;
    let mut enc_out: Option<xla::PjRtBuffer> = None; // BART cross-attention
    let mut enc_out_bytes: u64 = 0;

    for k in 0..n_stages {
        // wait for S_comp(k) — the inference queue guarantees order
        while !pending.contains_key(&k) {
            let t0 = ctx.tracer.now_ms();
            let t0_us = if tel_on { ctx.telemetry.now_us() } else { 0 };
            match rx_load.recv() {
                Ok(LoadMsg::Stage(msg)) => {
                    let t1 = ctx.tracer.now_ms();
                    // Only a recv that actually blocked is a pipeline stall
                    // (Fig 1b); a message that was already waiting returns
                    // in ~microseconds and must not inflate idle_fraction.
                    if t1 - t0 > STALL_EPS_MS {
                        ctx.tracer.record(Lane::Inference, Kind::StallWait, Some(k), t0, t1);
                        stats.wait_stall_ms += t1 - t0;
                        if tel_on {
                            ctx.telemetry.span(
                                "stall_wait",
                                worker::INFER,
                                t0_us,
                                EvArgs::stage(k),
                            );
                        }
                    }
                    pending.insert(msg.stage, msg);
                }
                Ok(LoadMsg::AgentDone { mem_stall_ms, load_ms }) => {
                    *agents_done += 1;
                    stats.mem_stall_ms += mem_stall_ms;
                    stats.load_ms_total += load_ms;
                }
                Ok(LoadMsg::Failed(e)) => {
                    gate.shutdown();
                    return Err(e.context("loading agent failed"));
                }
                Err(_) => {
                    return Err(anyhow!(
                        "loading agents exited before stage {k} arrived (of {n_stages})"
                    ));
                }
            }
        }
        let msg = pending.remove(&k).unwrap();
        let stage = &profile.stages[k];
        let is_body = stage.kind == body_kind;
        let entry = if incremental {
            profile
                .entry(&format!("{}_inc", stage.kind), ctx.batch)
                .with_context(|| format!("incremental decode entry for stage {k}"))?
        } else {
            profile.entry(&stage.kind, ctx.batch)?
        };

        // assemble activation inputs for this entry
        if k == 0 {
            let b = input.to_buffer(ctx.runtime, &entry.activations[0])?;
            act_bytes = entry.activations[0].num_bytes() as u64;
            gate.force_add(act_bytes);
            act = Some(b);
        } else if stage.kind == "cross_decoder_layer" && enc_out.is_none() {
            // first decoder layer: the encoder output doubles as the
            // decoder seed (simplified seq2seq trace, DESIGN.md §2)
            enc_out_bytes = act_bytes;
            gate.force_add(enc_out_bytes);
            enc_out = act.take();
            act = None;
        }

        // incremental-only inputs: position scalar + dense cached K/V
        let mut pos_buf: Option<xla::PjRtBuffer> = None;
        let mut kv_bufs: Option<(xla::PjRtBuffer, xla::PjRtBuffer)> = None;
        let mut kv_in_bytes = 0u64;
        if let PassMode::Incremental { kv, pos } = mode {
            if k == 0 || is_body {
                pos_buf = Some(ctx.runtime.buffer_i32(&[*pos as i32], &[1])?);
            }
            if is_body {
                // A sequence evicted mid-pass (S^stop pressure from this
                // very pass's weight admissions) cannot finish this token
                // incrementally; the caller recomputes it full-prefix.
                let (dk, dv) = kv
                    .dense_kv(kv_layer, profile.max_seq)
                    .ok_or_else(|| anyhow!("{KV_EVICTED_MIDPASS} at stage {k}"))?;
                kv_in_bytes = entry.activations[1].num_bytes() as u64
                    + entry.activations[2].num_bytes() as u64;
                gate.force_add(kv_in_bytes);
                let shape = [ctx.batch, profile.max_seq, profile.hidden];
                kv_bufs = Some((
                    ctx.runtime.buffer_f32(&dk, &shape)?,
                    ctx.runtime.buffer_f32(&dv, &shape)?,
                ));
            }
        }

        let x_ref;
        let act_refs: Vec<&xla::PjRtBuffer> = if incremental {
            let x = act.as_ref().ok_or_else(|| anyhow!("no activation at stage {k}"))?;
            if k == 0 {
                vec![x, pos_buf.as_ref().unwrap()]
            } else if is_body {
                let (kb, vb) = kv_bufs.as_ref().unwrap();
                vec![x, kb, vb, pos_buf.as_ref().unwrap()]
            } else {
                vec![x]
            }
        } else if stage.kind == "cross_decoder_layer" {
            let enc = enc_out.as_ref().unwrap();
            match act.as_ref() {
                Some(x) => vec![x, enc],
                None => vec![enc, enc], // first cross layer: seed = enc out
            }
        } else {
            x_ref = act.as_ref().ok_or_else(|| anyhow!("no activation at stage {k}"))?;
            vec![x_ref]
        };

        // Weight buffers for this stage: device-resident (upload skipped,
        // bytes already accounted with the cache entry) or a fresh upload
        // (the transient device copy, accounted until freed or retained).
        // One upload serves every entry this stage executes (prime + main).
        let device_ref = device.and_then(|d| d.begin_use(k));
        let fresh_bufs: Option<Vec<xla::PjRtBuffer>> = if device_ref.is_some() {
            stats.device_cache_hits += 1;
            if tel_on {
                ctx.telemetry.instant("device_hit", worker::INFER, EvArgs::stage(k));
            }
            None
        } else {
            gate.force_add(msg.bytes);
            Some(
                ctx.runtime
                    .upload_shard(&msg.shard)
                    .with_context(|| format!("uploading weights for stage {k}"))?,
            )
        };
        let weights: &[xla::PjRtBuffer] = match &device_ref {
            Some(r) => r.as_slice(),
            None => fresh_bufs.as_ref().unwrap().as_slice(),
        };

        // full-prefix K/V prime: seed the cache from this stage's input
        // activation before the main entry consumes it
        if let PassMode::PrimeKv { kv, prefix_len } = mode {
            if is_body {
                let kv_entry = profile.entry(&format!("{}_kv", stage.kind), ctx.batch)?;
                let kv_out_bytes = kv_entry.output.num_bytes() as u64;
                gate.force_add(kv_out_bytes);
                let kv_out = ctx
                    .runtime
                    .execute_entry_with(profile, kv_entry, &act_refs, weights)
                    .with_context(|| format!("priming kv at stage {k}"))?;
                let host = ctx.runtime.buffer_to_f32(&kv_out)?;
                drop(kv_out);
                gate.free(kv_out_bytes);
                // [B, 2S, H] -> token-major [T][B][H] rows for K and V
                let (s_len, h, b_sz, n) = (profile.max_seq, profile.hidden, ctx.batch, *prefix_len);
                let mut kx = vec![0f32; n * b_sz * h];
                let mut vx = vec![0f32; n * b_sz * h];
                for row in 0..b_sz {
                    for t in 0..n {
                        let src_k = row * 2 * s_len * h + t * h;
                        let src_v = row * 2 * s_len * h + (s_len + t) * h;
                        let dst = t * b_sz * h + row * h;
                        kx[dst..dst + h].copy_from_slice(&host[src_k..src_k + h]);
                        vx[dst..dst + h].copy_from_slice(&host[src_v..src_v + h]);
                    }
                }
                kv.write_prefix(kv_layer, n, &kx, &vx);
            }
        }

        let t0 = ctx.tracer.now_ms();
        let t0_us = if tel_on { ctx.telemetry.now_us() } else { 0 };
        let out = ctx
            .runtime
            .execute_entry_with(profile, entry, &act_refs, weights)
            .with_context(|| format!("executing stage {k} ({})", entry.kind))?;
        let t1 = ctx.tracer.now_ms();
        ctx.tracer.record(Lane::Inference, Kind::Compute, Some(k), t0, t1);
        stats.compute_ms_total += t1 - t0;
        if tel_on {
            ctx.telemetry.span("compute", worker::INFER, t0_us, EvArgs::stage(k));
        }
        // Device-copy disposal: a cache hit just releases its in-use flag;
        // a fresh upload is either retained (bytes stay accounted with the
        // device cache, next pass skips the upload) or dropped + freed.
        if device_ref.is_some() {
            drop(device_ref);
            device.unwrap().end_use(k);
        } else {
            let bufs = fresh_bufs.unwrap();
            let retained = device.map(|d| d.retain(k, bufs, msg.bytes)).unwrap_or(false);
            if retained {
                // the device copy outlives this pass: its bytes become
                // device-cache-owned, off this pass's ledger
                gate.transfer_to_store(msg.bytes);
            } else {
                gate.free(msg.bytes);
            }
        }
        drop(act_refs);
        if kv_in_bytes > 0 {
            drop(kv_bufs.take()); // dense K/V uploads die with the stage
            gate.free(kv_in_bytes);
        }

        if incremental && is_body {
            // unpack [B,3,H]: row 0 continues the pass, rows 1–2 are the
            // token's K/V, appended to the cached sequence
            let out_bytes = entry.output.num_bytes() as u64;
            gate.force_add(out_bytes);
            let host = ctx.runtime.buffer_to_f32(&out)?;
            drop(out);
            let (h, b_sz) = (profile.hidden, ctx.batch);
            let mut xr = vec![0f32; b_sz * h];
            let mut kr = vec![0f32; b_sz * h];
            let mut vr = vec![0f32; b_sz * h];
            for row in 0..b_sz {
                let base = row * 3 * h;
                xr[row * h..(row + 1) * h].copy_from_slice(&host[base..base + h]);
                kr[row * h..(row + 1) * h].copy_from_slice(&host[base + h..base + 2 * h]);
                vr[row * h..(row + 1) * h].copy_from_slice(&host[base + 2 * h..base + 3 * h]);
            }
            if let PassMode::Incremental { kv, pos } = mode {
                kv.write_token(kv_layer, *pos, &kr, &vr);
            }
            let new_act = ctx.runtime.buffer_f32(&xr, &[b_sz, 1, h])?;
            let new_bytes = (b_sz * h * 4) as u64;
            gate.force_add(new_bytes);
            gate.free(out_bytes);
            gate.free(act_bytes);
            act_bytes = new_bytes;
            act = Some(new_act);
        } else {
            // swap activation accounting: new out replaces old act
            let out_bytes = entry.output.num_bytes() as u64;
            gate.force_add(out_bytes);
            gate.free(act_bytes);
            act_bytes = out_bytes;
            act = Some(out);
        }
        if is_body {
            kv_layer += 1;
        }

        // S_dest: hand the layer to the Daemon for destruction (or pinning)
        ctx.signals.emit(Signal::Dest { stage: k });
        let _ = tx_dest.send(msg);
    }
    if enc_out.is_some() {
        gate.free(enc_out_bytes);
    }
    gate.free(act_bytes);
    ctx.signals.emit(Signal::Done);
    Ok((act.unwrap(), ()))
}
