//! Device-resident weight cache: skip host→device re-upload on hot stages.
//!
//! The hot-layer [`LayerCache`] keeps a pinned stage's *host* bytes across
//! passes, but every pass still pays `buffer_from_tensor` to re-upload
//! those bytes to the device before execution.  This cache is the
//! inference-side companion: after a stage executes, its weight
//! `PjRtBuffer`s may be kept alive so the next pass executes straight from
//! the device copy — no upload at all.
//!
//! PJRT buffer types are **not Send**, so the buffers themselves live only
//! on the inference thread, inside [`DeviceCache`].  Byte accounting and
//! eviction, however, must be visible to the loader threads' `S^stop`
//! eviction chain and to the elastic controller — that Send half is the
//! [`DeviceLedger`].  The split works on a mark-and-sweep contract:
//!
//! * the ledger tracks per-stage byte counts; the eviction chain frees a
//!   stage's bytes from the accountant and marks the stage evicted;
//! * the inference thread **sweeps** at each pass boundary (and before
//!   every lookup), dropping the buffers of marked stages;
//! * a stage the inference agent is *currently executing from* is flagged
//!   in-use and skipped by the chain, so a buffer is never reclaimed out
//!   from under a running `execute`.
//!
//! Device bytes sit between speculative prefetch and pinned host layers in
//! the eviction order: re-creating them costs one upload (cheaper than a
//! disk read, dearer than nothing).
//!
//! [`LayerCache`]: crate::pipeload::cache::LayerCache

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::memory::MemoryAccountant;

/// Counters for the `device_cache_hits` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DeviceStats {
    /// stages executed from device-resident weights (upload skipped)
    pub hits: u64,
    /// stages whose weight buffers were retained after execution
    pub retained: u64,
    /// device entries reclaimed under memory pressure
    pub evictions: u64,
    /// bytes currently accounted to device-resident weights
    pub resident_bytes: u64,
}

#[derive(Debug)]
struct DevEntry {
    bytes: u64,
    last_use: u64,
    in_use: bool,
}

#[derive(Debug)]
struct LedgerState {
    live: HashMap<usize, DevEntry>,
    /// stages evicted by the chain, awaiting the inference-side sweep
    swept: Vec<usize>,
    cap: u64,
    bytes: u64,
    clock: u64,
    hits: u64,
    retained: u64,
    evictions: u64,
}

/// Send half of the device cache: byte accounting + eviction marks.
#[derive(Debug, Clone)]
pub struct DeviceLedger {
    inner: Arc<Mutex<LedgerState>>,
}

impl DeviceLedger {
    pub fn new(cap: u64) -> DeviceLedger {
        DeviceLedger {
            inner: Arc::new(Mutex::new(LedgerState {
                live: HashMap::new(),
                swept: Vec::new(),
                cap,
                bytes: 0,
                clock: 0,
                hits: 0,
                retained: 0,
                evictions: 0,
            })),
        }
    }

    pub fn cap(&self) -> u64 {
        self.inner.lock().unwrap().cap
    }

    /// Reserve ledger room for a stage's device copy.  The stage's bytes
    /// must already be accounted (the pass `force_add`s the device copy
    /// before executing); retention just stops the post-execute free.
    pub fn try_retain(&self, stage: usize, bytes: u64) -> bool {
        let mut s = self.inner.lock().unwrap();
        if s.live.contains_key(&stage) || s.bytes + bytes > s.cap {
            return false;
        }
        s.clock += 1;
        let clock = s.clock;
        s.bytes += bytes;
        s.retained += 1;
        s.live.insert(stage, DevEntry { bytes, last_use: clock, in_use: true });
        true
    }

    /// Mark a stage's device copy in use for the current execute (hit).
    /// Returns false when the stage is not resident (evicted since the
    /// caller last looked) — the caller re-uploads.
    pub fn begin_use(&self, stage: usize) -> bool {
        let mut s = self.inner.lock().unwrap();
        s.clock += 1;
        let clock = s.clock;
        match s.live.get_mut(&stage) {
            Some(e) => {
                e.in_use = true;
                e.last_use = clock;
                s.hits += 1;
                true
            }
            None => false,
        }
    }

    /// Release the in-use flag after execution.
    pub fn end_use(&self, stage: usize) {
        let mut s = self.inner.lock().unwrap();
        if let Some(e) = s.live.get_mut(&stage) {
            e.in_use = false;
        }
    }

    fn evict_one(s: &mut LedgerState) -> Option<u64> {
        let victim = s
            .live
            .iter()
            .filter(|(_, e)| !e.in_use)
            .min_by_key(|(_, e)| e.last_use)
            .map(|(&st, _)| st)?;
        let e = s.live.remove(&victim).unwrap();
        s.bytes -= e.bytes;
        s.evictions += 1;
        s.swept.push(victim);
        Some(e.bytes)
    }

    /// Pressure valve: reclaim device entries (LRU, skipping the one in
    /// use) until `bytes` fit the accountant's budget or nothing is left.
    /// Returns bytes freed.  The buffers die at the next inference sweep.
    pub fn evict_for(&self, bytes: u64, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        while accountant.would_block(bytes) {
            match Self::evict_one(&mut s) {
                Some(b) => {
                    freed += b;
                    accountant.free(b);
                }
                None => break,
            }
        }
        freed
    }

    /// Retarget the cap (elastic budget step): shrinking evicts LRU device
    /// entries until the new cap holds, returning their bytes through
    /// `accountant`.  Returns bytes freed.
    pub fn set_cap(&self, new_cap: u64, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        s.cap = new_cap;
        let mut freed = 0u64;
        while s.bytes > new_cap {
            match Self::evict_one(&mut s) {
                Some(b) => {
                    freed += b;
                    accountant.free(b);
                }
                None => break,
            }
        }
        freed
    }

    /// Drop every entry AND return its bytes to `accountant` (failed-pass
    /// recovery under a shared accountant).  Not counted as evictions.
    pub fn drain(&self, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        let stages: Vec<usize> = s.live.keys().copied().collect();
        for stage in stages {
            let e = s.live.remove(&stage).unwrap();
            freed += e.bytes;
            s.swept.push(stage);
            accountant.free(e.bytes);
        }
        s.bytes = 0;
        freed
    }

    /// Drop every entry without touching the accountant (owned-accountant
    /// wholesale reset).
    pub fn clear(&self) {
        let mut s = self.inner.lock().unwrap();
        let stages: Vec<usize> = s.live.keys().copied().collect();
        s.swept.extend(stages);
        s.live.clear();
        s.bytes = 0;
    }

    /// Stages evicted since the last sweep — the inference thread drops
    /// their buffers.
    pub fn take_swept(&self) -> Vec<usize> {
        std::mem::take(&mut self.inner.lock().unwrap().swept)
    }

    pub fn stats(&self) -> DeviceStats {
        let s = self.inner.lock().unwrap();
        DeviceStats {
            hits: s.hits,
            retained: s.retained,
            evictions: s.evictions,
            resident_bytes: s.bytes,
        }
    }
}

/// Inference-thread half: the actual `PjRtBuffer`s, keyed by stage.
/// NOT Send (PJRT buffers wrap raw pointers) — lives inside the `Session`.
pub struct DeviceCache {
    ledger: DeviceLedger,
    bufs: RefCell<HashMap<usize, Vec<xla::PjRtBuffer>>>,
}

impl DeviceCache {
    pub fn new(cap: u64) -> DeviceCache {
        DeviceCache { ledger: DeviceLedger::new(cap), bufs: RefCell::new(HashMap::new()) }
    }

    /// The Send accounting handle (for the gate's eviction chain and the
    /// elastic controller).
    pub fn ledger(&self) -> &DeviceLedger {
        &self.ledger
    }

    /// Drop the buffers of every stage the chain evicted since last sweep.
    pub fn sweep(&self) {
        for stage in self.ledger.take_swept() {
            self.bufs.borrow_mut().remove(&stage);
        }
    }

    /// Begin executing from the device copy of `stage`, if resident.
    /// The returned buffers stay alive until [`DeviceCache::end_use`];
    /// the ledger skips in-use entries during eviction.
    pub fn begin_use(&self, stage: usize) -> Option<std::cell::Ref<'_, Vec<xla::PjRtBuffer>>> {
        self.sweep();
        if !self.bufs.borrow().contains_key(&stage) {
            return None;
        }
        if !self.ledger.begin_use(stage) {
            // evicted between sweep and flag: drop our side too
            self.bufs.borrow_mut().remove(&stage);
            return None;
        }
        Some(std::cell::Ref::map(self.bufs.borrow(), |m| m.get(&stage).unwrap()))
    }

    pub fn end_use(&self, stage: usize) {
        self.ledger.end_use(stage);
    }

    /// Retain a freshly uploaded stage's weight buffers.  Returns true when
    /// the ledger had cap room — the caller must then SKIP freeing the
    /// stage's device-copy bytes (they stay accounted with the entry).
    pub fn retain(&self, stage: usize, bufs: Vec<xla::PjRtBuffer>, bytes: u64) -> bool {
        self.sweep();
        if !self.ledger.try_retain(stage, bytes) {
            return false;
        }
        self.bufs.borrow_mut().insert(stage, bufs);
        self.ledger.end_use(stage);
        true
    }

    pub fn stats(&self) -> DeviceStats {
        self.ledger.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_retain_respects_cap() {
        let l = DeviceLedger::new(500);
        assert!(l.try_retain(0, 300));
        assert!(!l.try_retain(1, 300), "cap 500 cannot hold 600");
        assert!(l.try_retain(2, 200));
        assert!(!l.try_retain(0, 1), "duplicate retain rejected");
        let st = l.stats();
        assert_eq!(st.resident_bytes, 500);
        assert_eq!(st.retained, 2);
    }

    #[test]
    fn ledger_eviction_skips_in_use_and_marks_sweep() {
        let accountant = MemoryAccountant::new(Some(600));
        assert!(accountant.try_acquire(600));
        let l = DeviceLedger::new(600);
        assert!(l.try_retain(0, 300));
        assert!(l.try_retain(1, 300));
        l.end_use(1);
        // stage 0 still in use (try_retain leaves it flagged until end_use)
        let freed = l.evict_for(100, &accountant);
        assert_eq!(freed, 300, "only the not-in-use entry is reclaimable");
        assert_eq!(accountant.used(), 300);
        assert_eq!(l.take_swept(), vec![1]);
        assert!(l.take_swept().is_empty(), "sweep list drains");
        l.end_use(0);
        let freed = l.evict_for(500, &accountant);
        assert_eq!(freed, 300);
        assert_eq!(l.stats().evictions, 2);
    }

    #[test]
    fn ledger_hits_count_begin_use() {
        let l = DeviceLedger::new(100);
        assert!(l.try_retain(7, 50));
        l.end_use(7);
        assert!(l.begin_use(7));
        l.end_use(7);
        assert!(!l.begin_use(99));
        assert_eq!(l.stats().hits, 1);
    }

    #[test]
    fn set_cap_shrink_evicts_lru() {
        let accountant = MemoryAccountant::new(Some(1000));
        assert!(accountant.try_acquire(900));
        let l = DeviceLedger::new(900);
        for stage in 0..3 {
            assert!(l.try_retain(stage, 300));
            l.end_use(stage);
        }
        let freed = l.set_cap(300, &accountant);
        assert_eq!(freed, 600);
        assert_eq!(accountant.used(), 300);
        assert_eq!(l.stats().evictions, 2);
        assert!(l.begin_use(2), "newest entry survives the shrink");
    }

    #[test]
    fn drain_frees_without_counting_evictions() {
        let accountant = MemoryAccountant::new(Some(500));
        assert!(accountant.try_acquire(400));
        let l = DeviceLedger::new(500);
        assert!(l.try_retain(0, 400));
        l.end_use(0);
        assert_eq!(l.drain(&accountant), 400);
        assert_eq!(accountant.used(), 0);
        assert_eq!(l.stats().evictions, 0);
        assert_eq!(l.take_swept(), vec![0]);
    }
}
