//! Layer-to-agent assignment (paper section III-B).
//!
//! The i-th Loading Agent (1-based in the paper) owns layers `L_{i+jm}`:
//! a round-robin partition by stage index.  With m agents the inference
//! time of m layers overlaps a single layer's loading time — the paper's
//! mechanism for closing the load≫compute gap (Obs II).

/// Stages owned by each of `agents` Loading Agents over `stages` stages.
/// 0-based: agent a gets a, a+m, a+2m, ...
pub fn assignment(stages: usize, agents: usize) -> Vec<Vec<usize>> {
    assert!(agents >= 1, "need at least one loading agent");
    let mut out = vec![Vec::new(); agents];
    for s in 0..stages {
        out[s % agents].push(s);
    }
    out
}

/// Which agent owns a stage.
pub fn owner(stage: usize, agents: usize) -> usize {
    stage % agents
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_example() {
        // Fig 5: LA1 -> L1,L4,L7..., LA2 -> L2,L5,L8..., LA3 -> L3,L6,L9...
        // (0-based here)
        let a = assignment(9, 3);
        assert_eq!(a[0], vec![0, 3, 6]);
        assert_eq!(a[1], vec![1, 4, 7]);
        assert_eq!(a[2], vec![2, 5, 8]);
    }

    #[test]
    fn partition_covers_all_exactly_once() {
        for stages in [1, 5, 26, 30] {
            for agents in [1, 2, 3, 6, 40] {
                let a = assignment(stages, agents);
                let mut seen = vec![0u32; stages];
                for (ai, list) in a.iter().enumerate() {
                    for &s in list {
                        seen[s] += 1;
                        assert_eq!(owner(s, agents), ai);
                    }
                }
                assert!(seen.iter().all(|&c| c == 1), "stages={stages} agents={agents}");
            }
        }
    }

    #[test]
    fn per_agent_lists_sorted() {
        for list in assignment(30, 4) {
            assert!(list.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn more_agents_than_stages() {
        let a = assignment(2, 6);
        assert_eq!(a[0], vec![0]);
        assert_eq!(a[1], vec![1]);
        assert!(a[2..].iter().all(|l| l.is_empty()));
    }
}
