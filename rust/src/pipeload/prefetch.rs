//! Cross-pass prefetch buffer: speculative weight residency.
//!
//! During pass k's tail compute, idle Loading Agents may read pass k+1's
//! head stages from disk ahead of time (bounded by `--prefetch-depth`).
//! Loaded shards park here; the next pass's Loading Agents take them like
//! hot-layer cache hits (skip disk AND admission — the bytes were acquired
//! when the prefetcher loaded them, via
//! [`OrderedGate::try_admit_prefetch`], which only ever takes budget slack
//! and always leaves `max_stage` headroom for the running pass).
//!
//! Prefetched bytes are the *cheapest* sacrifice in the eviction chain —
//! they are pure speculation — so the [`OrderedGate`] reclaims them before
//! pinned layers, device-resident weights, or KV sequences.  An evicted
//! entry is not an error: the pass that wanted it falls back to a normal
//! disk load through the ordinary admission path.
//!
//! [`OrderedGate`]: crate::pipeload::gate::OrderedGate
//! [`OrderedGate::try_admit_prefetch`]:
//!     crate::pipeload::gate::OrderedGate::try_admit_prefetch

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::memory::MemoryAccountant;
use crate::weights::Shard;

/// Counters for the `prefetched_stages` / `prefetch_wasted` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    /// stages loaded ahead of their pass
    pub prefetched: u64,
    /// prefetched stages consumed by a later pass (skipped disk)
    pub used: u64,
    /// prefetched stages reclaimed (evicted or drained) before any pass
    /// could use them — pure wasted I/O
    pub wasted: u64,
    /// bytes currently parked in the buffer
    pub buffered_bytes: u64,
}

#[derive(Debug)]
struct BufState {
    entries: HashMap<usize, (Arc<Shard>, u64)>,
    bytes: u64,
    prefetched: u64,
    used: u64,
    wasted: u64,
}

/// Shared speculative-stage store; clone freely (Arc inside).
#[derive(Debug, Clone)]
pub struct PrefetchBuffer {
    inner: Arc<Mutex<BufState>>,
}

impl Default for PrefetchBuffer {
    fn default() -> Self {
        Self::new()
    }
}

impl PrefetchBuffer {
    pub fn new() -> PrefetchBuffer {
        PrefetchBuffer {
            inner: Arc::new(Mutex::new(BufState {
                entries: HashMap::new(),
                bytes: 0,
                prefetched: 0,
                used: 0,
                wasted: 0,
            })),
        }
    }

    /// Park a prefetched shard.  The caller must already hold `bytes` in
    /// the pass accountant (acquired via `try_admit_prefetch`).  Returns
    /// false — and leaves the entry out — if the stage is already parked
    /// (the caller then frees its duplicate bytes).
    pub fn put(&self, stage: usize, shard: Arc<Shard>, bytes: u64) -> bool {
        let mut s = self.inner.lock().unwrap();
        if s.entries.contains_key(&stage) {
            return false;
        }
        s.entries.insert(stage, (shard, bytes));
        s.bytes += bytes;
        s.prefetched += 1;
        true
    }

    /// Is this stage already parked?  (Prefetch tasks skip work the buffer
    /// already holds.)
    pub fn contains(&self, stage: usize) -> bool {
        self.inner.lock().unwrap().entries.contains_key(&stage)
    }

    /// Take a prefetched stage (hit).  Its bytes stay accounted with the
    /// caller, exactly like a hot-layer cache take.
    pub fn take(&self, stage: usize) -> Option<(Arc<Shard>, u64)> {
        let mut s = self.inner.lock().unwrap();
        match s.entries.remove(&stage) {
            Some((shard, bytes)) => {
                s.bytes -= bytes;
                s.used += 1;
                Some((shard, bytes))
            }
            None => None,
        }
    }

    /// Drop a parked entry that became redundant (its stage was served
    /// from the pin cache instead).  Returns the entry's bytes — the
    /// CALLER must free them through the gate; counts as `wasted`.
    /// Without this, a prefetch that loses the race to a daemon pin would
    /// stay parked (and accounted) for the session's lifetime.
    pub fn discard(&self, stage: usize) -> Option<u64> {
        let mut s = self.inner.lock().unwrap();
        match s.entries.remove(&stage) {
            Some((shard, bytes)) => {
                s.bytes -= bytes;
                s.wasted += 1;
                drop(shard);
                Some(bytes)
            }
            None => None,
        }
    }

    /// Eviction valve: drop parked entries until `bytes` fit the
    /// accountant's budget or the buffer is empty.  Returns bytes freed;
    /// every reclaimed entry counts as `wasted` (loaded, never used).
    pub fn evict_for(&self, bytes: u64, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        while accountant.would_block(bytes) {
            let victim = match s.entries.keys().next().copied() {
                Some(stage) => stage,
                None => break,
            };
            let (shard, b) = s.entries.remove(&victim).unwrap();
            s.bytes -= b;
            s.wasted += 1;
            freed += b;
            drop(shard);
            accountant.free(b);
        }
        freed
    }

    /// Drop every parked entry AND return its bytes to `accountant`
    /// (failed-pass recovery under a shared accountant; counts as wasted).
    pub fn drain(&self, accountant: &MemoryAccountant) -> u64 {
        let mut s = self.inner.lock().unwrap();
        let mut freed = 0u64;
        for (_, (shard, b)) in s.entries.drain() {
            freed += b;
            s.wasted += 1;
            drop(shard);
            accountant.free(b);
        }
        s.bytes = 0;
        freed
    }

    /// Drop every parked entry without touching the accountant (used when a
    /// failed pass resets an owned accountant wholesale).
    pub fn clear(&self) {
        let mut s = self.inner.lock().unwrap();
        let n = s.entries.len() as u64;
        s.entries.clear();
        s.wasted += n;
        s.bytes = 0;
    }

    pub fn stats(&self) -> PrefetchStats {
        let s = self.inner.lock().unwrap();
        PrefetchStats {
            prefetched: s.prefetched,
            used: s.used,
            wasted: s.wasted,
            buffered_bytes: s.bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard(stage: u32) -> Arc<Shard> {
        Arc::new(Shard { kind: "decoder_layer".into(), stage, tensors: vec![] })
    }

    #[test]
    fn put_take_roundtrip_counts_use() {
        let b = PrefetchBuffer::new();
        assert!(b.put(3, shard(3), 100));
        assert!(b.contains(3));
        assert!(!b.put(3, shard(3), 100), "duplicate put rejected");
        let (s, bytes) = b.take(3).unwrap();
        assert_eq!(s.stage, 3);
        assert_eq!(bytes, 100);
        assert!(b.take(3).is_none());
        let st = b.stats();
        assert_eq!(st.prefetched, 1);
        assert_eq!(st.used, 1);
        assert_eq!(st.wasted, 0);
        assert_eq!(st.buffered_bytes, 0);
    }

    #[test]
    fn evict_for_counts_wasted_and_frees_accounting() {
        let accountant = MemoryAccountant::new(Some(300));
        assert!(accountant.try_acquire(200));
        let b = PrefetchBuffer::new();
        assert!(b.put(0, shard(0), 100));
        assert!(b.put(1, shard(1), 100));
        // wanting 300 more forces both speculative entries out
        let freed = b.evict_for(300, &accountant);
        assert_eq!(freed, 200);
        assert_eq!(accountant.used(), 0);
        let st = b.stats();
        assert_eq!(st.wasted, 2);
        assert_eq!(st.used, 0);
    }

    #[test]
    fn discard_counts_wasted_and_returns_bytes_to_caller() {
        let b = PrefetchBuffer::new();
        assert!(b.put(2, shard(2), 150));
        assert_eq!(b.discard(2), Some(150), "caller frees these through the gate");
        assert_eq!(b.discard(2), None);
        let st = b.stats();
        assert_eq!(st.wasted, 1);
        assert_eq!(st.used, 0);
        assert_eq!(st.buffered_bytes, 0);
    }

    #[test]
    fn drain_and_clear_both_count_wasted() {
        let accountant = MemoryAccountant::new(Some(300));
        assert!(accountant.try_acquire(100));
        let b = PrefetchBuffer::new();
        assert!(b.put(0, shard(0), 100));
        assert_eq!(b.drain(&accountant), 100);
        assert_eq!(accountant.used(), 0);
        assert_eq!(b.stats().wasted, 1);

        let b2 = PrefetchBuffer::new();
        assert!(b2.put(1, shard(1), 50));
        b2.clear();
        assert_eq!(b2.stats().wasted, 1);
        assert_eq!(b2.stats().buffered_bytes, 0);
    }
}
