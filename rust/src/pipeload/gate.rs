//! Ordered memory-admission gate.
//!
//! The Daemon's raw budget check ([`MemoryAccountant::acquire`]) admits
//! waiters in arbitrary wake-up order.  Under a tight budget that can
//! deadlock the pipeline: the budget fills with *future* layers while the
//! layer the Inference Agent needs next is still waiting; nothing can be
//! computed, so nothing is ever freed.
//!
//! This gate makes admission **strictly sequential by stage index**: stage
//! s is admitted only after stages 0..s-1 were admitted and the budget has
//! room.  Loading stays m-way parallel (admission is just accounting; the
//! actual disk reads overlap), but memory is granted in exactly the order
//! the Inference Agent will consume it.  Liveness: the next-needed stage k
//! is always the next admission; once admitted its agent loads it, the
//! Inference Agent computes it, the Daemon frees it, and admission k+1
//! proceeds.  This is the concrete realization of the paper's `S^stop`
//! protocol — "waiting for admission" == "paused by the Daemon".
//!
//! [`MemoryAccountant::acquire`]: crate::memory::MemoryAccountant::acquire

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::memory::MemoryAccountant;

#[derive(Debug)]
struct GateState {
    next_admit: usize,
    shutdown: bool,
}

/// Stage-ordered admission on top of a [`MemoryAccountant`].
///
/// One gate serves one pipeline pass (admissions 0..N in order); create a
/// fresh gate per pass (per generated token for GPT-style decode).
#[derive(Debug, Clone)]
pub struct OrderedGate {
    accountant: MemoryAccountant,
    state: Arc<(Mutex<GateState>, Condvar)>,
}

impl OrderedGate {
    pub fn new(accountant: MemoryAccountant) -> OrderedGate {
        OrderedGate {
            accountant,
            state: Arc::new((
                Mutex::new(GateState { next_admit: 0, shutdown: false }),
                Condvar::new(),
            )),
        }
    }

    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// Block until it is `stage`'s turn and `bytes` fit the budget, then
    /// account them.  Returns time spent stalled (the S^stop duration).
    pub fn admit(&self, stage: usize, bytes: u64) -> Result<Duration> {
        if let Some(b) = self.accountant.budget() {
            if bytes > b {
                bail!("stage {stage}: {bytes} B can never fit budget {b} B");
            }
        }
        let (lock, cv) = &*self.state;
        let t0 = Instant::now();
        let mut s = lock.lock().unwrap();
        loop {
            if s.shutdown {
                bail!("gate shut down");
            }
            if s.next_admit == stage && self.accountant.try_acquire(bytes) {
                s.next_admit += 1;
                cv.notify_all();
                return Ok(t0.elapsed());
            }
            // Short timeout: frees go through the accountant, whose condvar
            // we are not parked on; poll cheaply instead of missing wakeups.
            s = cv.wait_timeout(s, Duration::from_millis(2)).unwrap().0;
        }
    }

    /// Free bytes (daemon destruction) and wake admission waiters.
    pub fn free(&self, bytes: u64) {
        self.accountant.free(bytes);
        self.state.1.notify_all();
    }

    pub fn shutdown(&self) {
        self.state.0.lock().unwrap().shutdown = true;
        self.state.1.notify_all();
        self.accountant.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_in_stage_order_under_pressure() {
        // budget fits exactly one layer; stages 2,1,0 arrive out of order.
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for stage in [2usize, 1, 0] {
            let g = gate.clone();
            let ord = order.clone();
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(10 * (2 - stage) as u64));
                g.admit(stage, 100).unwrap();
                ord.lock().unwrap().push(stage);
            }));
        }
        // drain: free after each admission so the next can proceed
        for _ in 0..3 {
            while gate.accountant().used() < 100 {
                std::thread::sleep(Duration::from_millis(5));
            }
            gate.free(100);
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn no_deadlock_with_tight_budget() {
        // budget = 1 layer, 3 agents racing, consumer strictly in order.
        let gate = OrderedGate::new(MemoryAccountant::new(Some(10)));
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        let mut handles = Vec::new();
        for agent in 0..3usize {
            let g = gate.clone();
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for stage in (agent..12).step_by(3) {
                    g.admit(stage, 10).unwrap();
                    tx.send(stage).unwrap();
                }
            }));
        }
        drop(tx);
        let mut next = 0;
        let mut pending = std::collections::BTreeSet::new();
        while next < 12 {
            let s = rx.recv_timeout(Duration::from_secs(5)).expect("pipeline deadlocked");
            pending.insert(s);
            while pending.remove(&next) {
                gate.free(10); // "computed" -> daemon frees
                next += 1;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(gate.accountant().used(), 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(50)));
        assert!(gate.admit(0, 51).is_err());
    }

    #[test]
    fn shutdown_unblocks() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(10)));
        gate.admit(0, 10).unwrap();
        let g = gate.clone();
        let h = std::thread::spawn(move || g.admit(1, 10));
        std::thread::sleep(Duration::from_millis(30));
        gate.shutdown();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn sequential_admissions_fast_when_unconstrained() {
        let gate = OrderedGate::new(MemoryAccountant::unlimited());
        let t0 = Instant::now();
        for s in 0..50 {
            gate.admit(s, 1000).unwrap();
        }
        assert!(t0.elapsed().as_millis() < 200);
        assert_eq!(gate.accountant().used(), 50_000);
    }

    #[test]
    fn out_of_turn_request_waits_for_predecessor() {
        let gate = OrderedGate::new(MemoryAccountant::unlimited());
        let g = gate.clone();
        let h = std::thread::spawn(move || {
            let waited = g.admit(1, 10).unwrap();
            waited
        });
        std::thread::sleep(Duration::from_millis(40));
        gate.admit(0, 10).unwrap();
        let waited = h.join().unwrap();
        assert!(waited.as_millis() >= 30, "{waited:?}");
    }
}
