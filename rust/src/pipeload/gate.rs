//! Ordered memory-admission gate.
//!
//! The Daemon's raw budget check ([`MemoryAccountant::acquire`]) admits
//! waiters in arbitrary wake-up order.  Under a tight budget that can
//! deadlock the pipeline: the budget fills with *future* layers while the
//! layer the Inference Agent needs next is still waiting; nothing can be
//! computed, so nothing is ever freed.
//!
//! This gate makes admission **strictly sequential by stage index**: stage
//! s is admitted only after stages 0..s-1 were admitted and the budget has
//! room.  Loading stays m-way parallel (admission is just accounting; the
//! actual disk reads overlap), but memory is granted in exactly the order
//! the Inference Agent will consume it.  Liveness: the next-needed stage k
//! is always the next admission; once admitted its agent loads it, the
//! Inference Agent computes it, the Daemon frees it, and admission k+1
//! proceeds.  This is the concrete realization of the paper's `S^stop`
//! protocol — "waiting for admission" == "paused by the Daemon".
//!
//! Waiters park on the gate's own condvar — no polling.  This is sound
//! because every event that can unblock an admission notifies it: each
//! admission/skip (turn advance), [`OrderedGate::free`] (every
//! budget-relevant release in the pipeline routes through it), shutdown,
//! pass-boundary rearm ([`OrderedGate::begin_pass`]), and hot-layer
//! eviction (performed inline by the stalled admitter via the attached
//! [`LayerCache`], so it needs no wakeup at all).
//!
//! # Epochs
//!
//! One gate serves a whole [`Session`]: each pass is an **epoch**, and the
//! admission cursor is the pair `(epoch, stage)`.  A persistent
//! worker-pool loader tags its admissions with the epoch of the pass that
//! dispatched them, so
//!
//! * an admission for a *future* epoch parks until
//!   [`OrderedGate::begin_pass`] opens that epoch (this is how queued
//!   next-pass work waits out the current pass without corrupting its
//!   admission order), and
//! * an admission for a *stale* epoch (its pass already failed and a newer
//!   one started) errors out instead of admitting bytes nobody will free.
//!
//! Cross-pass **prefetch** does not ride the cursor at all:
//! [`OrderedGate::try_admit_prefetch`] takes budget slack non-blockingly,
//! always leaving `max_stage` headroom so the running pass's next
//! admission can never be starved by speculation — the `budget −
//! max_stage` liveness invariant holds across the pass boundary.
//! Prefetched bytes are first in the eviction chain.
//!
//! # Concurrent lanes
//!
//! When several sessions' passes run **concurrently** against one shared
//! accountant (the Router's lane executors), two disciplines keep the
//! victim chains safe:
//!
//! * every byte a pass holds transiently is charged through the gate's
//!   [`PassLedger`], so a failed pass drains exactly its own bytes while
//!   other lanes keep charging (see [`crate::memory`]).  Frees therefore
//!   split into [`OrderedGate::free`] (pass-owned bytes) and
//!   [`OrderedGate::free_store`] (bytes a durable store owned — displaced
//!   pins, discarded prefetch duplicates);
//! * full eviction-chain walks take a fleet-wide [`ReclaimToken`] — a
//!   reentrant lock shared by every lane's gate — so two lanes reclaiming
//!   each other's victims cannot interleave half-finished chains, and the
//!   gate-state mutex is NEVER held while the chain runs (lock order:
//!   token → store mutexes / gate state → accountant, each released
//!   before the next tier is taken from a different path);
//! * lanes are **peered** ([`OrderedGate::add_peer`]): every free or
//!   reclaim on one lane notifies all peer gates' condvars too, because
//!   the headroom it opens may be exactly what another lane's parked
//!   admission is waiting for.
//!
//! [`MemoryAccountant::acquire`]: crate::memory::MemoryAccountant::acquire
//! [`Session`]: crate::engine::session::Session

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::cache::LayerCache;
use super::device::DeviceLedger;
use super::prefetch::PrefetchBuffer;
use crate::kvcache::KvPool;
use crate::memory::{MemoryAccountant, PassLedger};
use crate::telemetry::{worker, EvArgs, Telemetry};

/// Fleet-wide reclaim token: serializes full eviction-chain walks across
/// concurrently-running lanes.  Two lanes evicting each other's victims
/// under one shared budget must not interleave half-finished chains (each
/// would see the other's partial progress and over-evict), and an elastic
/// budget step must not race a stalled admission's inline reclaim.  The
/// token is **reentrant** — a thread already holding it may re-enter
/// (`reclaim_to_budget` from a path that already took the token) — and is
/// shared by every gate of a Router via
/// [`OrderedGate::set_reclaim_token`]; a standalone gate gets its own.
#[derive(Debug, Clone, Default)]
pub struct ReclaimToken {
    inner: Arc<TokenInner>,
}

#[derive(Debug, Default)]
struct TokenInner {
    state: Mutex<TokenState>,
    cv: Condvar,
}

#[derive(Debug, Default)]
struct TokenState {
    owner: Option<std::thread::ThreadId>,
    depth: usize,
}

impl ReclaimToken {
    pub fn new() -> ReclaimToken {
        ReclaimToken::default()
    }

    /// Take the token, waiting for another lane's chain walk to finish;
    /// reentrant for the holding thread.
    pub fn acquire(&self) -> ReclaimGuard<'_> {
        let me = std::thread::current().id();
        let mut s = self.inner.state.lock().unwrap();
        loop {
            match s.owner {
                None => {
                    s.owner = Some(me);
                    s.depth = 1;
                    break;
                }
                Some(o) if o == me => {
                    s.depth += 1;
                    break;
                }
                Some(_) => s = self.inner.cv.wait(s).unwrap(),
            }
        }
        ReclaimGuard { token: self }
    }
}

/// RAII guard for a held [`ReclaimToken`].
pub struct ReclaimGuard<'a> {
    token: &'a ReclaimToken,
}

impl Drop for ReclaimGuard<'_> {
    fn drop(&mut self) {
        let mut s = self.token.inner.state.lock().unwrap();
        s.depth -= 1;
        if s.depth == 0 {
            s.owner = None;
            self.token.inner.cv.notify_one();
        }
    }
}

#[derive(Debug)]
struct GateState {
    epoch: u64,
    next_admit: usize,
    shutdown: bool,
}

/// Stage-ordered admission on top of a [`MemoryAccountant`].
#[derive(Debug, Clone)]
pub struct OrderedGate {
    accountant: MemoryAccountant,
    cache: Option<LayerCache>,
    /// Speculative cross-pass prefetch buffer — FIRST in the eviction
    /// chain (reclaiming speculation costs nothing but wasted I/O).
    prefetch: Option<PrefetchBuffer>,
    /// Device-resident weight ledger — second in the chain (re-creating a
    /// device copy is one upload, cheaper than the disk read a pin save).
    device: Option<DeviceLedger>,
    /// Other sessions' hot-layer caches on the same (shared) accountant.
    /// A stalled admission reclaims from these after its own cache — this
    /// is how one model's `S^stop` pressure evicts another model's pins
    /// when a Router multiplexes several sessions under one budget.
    victims: Vec<LayerCache>,
    /// Other sessions' device ledgers on the same shared accountant.
    victim_devices: Vec<DeviceLedger>,
    /// KV pools on the same shared accountant (own session's first, then
    /// other lanes').  Reclaimed after pinned layers: evicting KV is the
    /// costlier sacrifice (that sequence recomputes its full prefix for
    /// every remaining token, while an unpinned layer is one disk read).
    kv_pools: Vec<KvPool>,
    /// Per-pass byte ledger: every transient the running pass charges goes
    /// through here, so failed-pass recovery can drain exactly this pass's
    /// outstanding bytes without touching other lanes' charges.
    ledger: PassLedger,
    /// Event bus for evict-with-cause instants.  Per-clone: set before the
    /// gate's clones escape into worker tasks, so cross-lane evictions are
    /// attributed to the lane whose admission applied the pressure.
    telemetry: Telemetry,
    /// Fleet-wide eviction-chain lock (shared across a Router's lanes).
    reclaim: ReclaimToken,
    /// Other lanes' gate states on the same shared accountant.  A free on
    /// THIS lane may be exactly what a peer lane's stalled admission is
    /// waiting for, so every waiter-waking event notifies peers too —
    /// without this, concurrent lanes deadlock parked on their own gates.
    peers: Vec<Arc<(Mutex<GateState>, Condvar)>>,
    state: Arc<(Mutex<GateState>, Condvar)>,
}

impl OrderedGate {
    pub fn new(accountant: MemoryAccountant) -> OrderedGate {
        let ledger = accountant.pass_ledger();
        OrderedGate {
            accountant,
            cache: None,
            prefetch: None,
            device: None,
            victims: Vec::new(),
            victim_devices: Vec::new(),
            kv_pools: Vec::new(),
            ledger,
            telemetry: Telemetry::off(),
            reclaim: ReclaimToken::new(),
            peers: Vec::new(),
            state: Arc::new((
                Mutex::new(GateState { epoch: 0, next_admit: 0, shutdown: false }),
                Condvar::new(),
            )),
        }
    }

    /// Gate with a hot-layer cache attached: admissions that stall on the
    /// budget evict pinned layers (LRU) before parking.
    pub fn with_cache(accountant: MemoryAccountant, cache: LayerCache) -> OrderedGate {
        let mut g = OrderedGate::new(accountant);
        g.cache = Some(cache);
        g
    }

    /// Register another session's cache as an eviction target.  Its pins
    /// must be accounted in this gate's accountant (i.e. both sessions were
    /// opened against the same shared accountant), or eviction would free
    /// bytes this budget never held.
    pub fn add_victim(&mut self, cache: LayerCache) {
        self.victims.push(cache);
    }

    /// Bytes currently pinned across all registered victim caches.
    pub fn victim_pinned_bytes(&self) -> u64 {
        self.victims.iter().map(|c| c.stats().pinned_bytes).sum()
    }

    /// Register a KV pool as an eviction target.  Its blocks must be
    /// accounted in this gate's accountant (same shared accountant).
    pub fn add_kv_pool(&mut self, pool: KvPool) {
        self.kv_pools.push(pool);
    }

    /// Attach the session's cross-pass prefetch buffer: its entries become
    /// the first (cheapest) rung of the eviction chain.
    pub fn set_prefetch(&mut self, buffer: PrefetchBuffer) {
        self.prefetch = Some(buffer);
    }

    /// Attach the session's device-resident weight ledger (second rung of
    /// the eviction chain, before pinned host layers).
    pub fn set_device(&mut self, ledger: DeviceLedger) {
        self.device = Some(ledger);
    }

    /// Register another session's device ledger as an eviction target
    /// (same shared-accountant requirement as [`OrderedGate::add_victim`]).
    pub fn add_victim_device(&mut self, ledger: DeviceLedger) {
        self.victim_devices.push(ledger);
    }

    /// Bytes currently accounted to victim sessions' device caches.
    pub fn victim_device_bytes(&self) -> u64 {
        self.victim_devices.iter().map(|l| l.stats().resident_bytes).sum()
    }

    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// The gate's per-pass ledger.  Recovery drains it; stats read it.
    pub fn ledger(&self) -> &PassLedger {
        &self.ledger
    }

    /// Attach the structured event bus (lane-tagged).  Like `add_victim`,
    /// this must happen while the session is being wired — before the
    /// gate's clones escape into the worker pool.
    pub fn set_telemetry(&mut self, t: Telemetry) {
        self.telemetry = t;
    }

    /// Share one fleet-wide [`ReclaimToken`] across every lane's gate.
    /// Must be called before concurrent serving starts (while the session
    /// is still being wired, same as `add_victim`).
    pub fn set_reclaim_token(&mut self, token: ReclaimToken) {
        self.reclaim = token;
    }

    /// The token guarding this gate's eviction chain (for sharing).
    pub fn reclaim_token(&self) -> ReclaimToken {
        self.reclaim.clone()
    }

    /// Register another lane's gate for cross-lane wakeups.  Lanes sharing
    /// an accountant MUST be peered both ways: a free here can be the
    /// budget headroom a peer's parked admission needs, and its own gate
    /// condvar would otherwise never be notified.
    pub fn add_peer(&mut self, other: &OrderedGate) {
        self.peers.push(other.state.clone());
    }

    /// Wake admission waiters on this gate and every peered lane's gate.
    /// Each notify holds that gate's mutex (see [`OrderedGate::free`] for
    /// the lost-wakeup argument); the locks are taken strictly one at a
    /// time, never nested, so peering cannot introduce a lock cycle.
    fn notify_waiters(&self) {
        {
            let _guard = self.state.0.lock().unwrap();
            self.state.1.notify_all();
        }
        for peer in &self.peers {
            let _guard = peer.0.lock().unwrap();
            peer.1.notify_all();
        }
    }

    /// Charge bytes the pass computes into existence (activations, device
    /// upload copies, unpacked KV) — they may transiently exceed the
    /// budget, exactly like [`MemoryAccountant::force_add`], but are
    /// ledger-tracked so a failed pass drains them.
    pub fn force_add(&self, bytes: u64) {
        self.ledger.force_add(bytes);
    }

    /// Record bytes moving from a durable store INTO the pass (a cache or
    /// prefetch-buffer `take`): no accountant traffic — the bytes stay
    /// accounted — only ledger ownership changes.
    pub fn adopt(&self, bytes: u64) {
        self.ledger.adopt(bytes);
    }

    /// Record bytes moving from the pass INTO a durable store (a pin that
    /// stuck, a device copy retained across passes): the store now owns
    /// them, so a failed-pass drain must not free them.
    pub fn transfer_to_store(&self, bytes: u64) {
        self.ledger.release(bytes);
    }

    /// One rung at a time through the eviction chain, cheapest sacrifice
    /// first: speculative prefetch, own device copies, own pins, victim
    /// pins, victim device copies, then cached KV sequences.  Returns true
    /// if anything was reclaimed (the stalled admitter retries).
    fn evict_chain_for(&self, bytes: u64) -> bool {
        let reclaimed = self.evict_chain_step(bytes);
        if reclaimed && self.telemetry.is_on() {
            self.telemetry.instant(
                "evict",
                worker::DAEMON,
                EvArgs::default().with_reason("pressure"),
            );
        }
        reclaimed
    }

    /// The chain body of [`OrderedGate::evict_chain_for`], one rung per
    /// call (split so the wrapper can tag the reclaim's cause).
    fn evict_chain_step(&self, bytes: u64) -> bool {
        if let Some(p) = &self.prefetch {
            let freed = p.evict_for(bytes, &self.accountant);
            if freed > 0 {
                // speculative bytes sacrificed before they were used: the
                // live waste-rate signal (`DerivedSignals`) and the offline
                // analyzer both count these
                self.telemetry.instant(
                    "prefetch_waste",
                    worker::DAEMON,
                    EvArgs::default().with_bytes(freed).with_reason("evicted"),
                );
                return true;
            }
        }
        if let Some(d) = &self.device {
            if d.evict_for(bytes, &self.accountant) > 0 {
                return true;
            }
        }
        let own = self.cache.iter();
        if own.chain(self.victims.iter()).any(|c| c.evict_for(bytes, &self.accountant) > 0) {
            return true;
        }
        if self.victim_devices.iter().any(|l| l.evict_for(bytes, &self.accountant) > 0) {
            return true;
        }
        self.kv_pools.iter().any(|p| p.evict_for(bytes) > 0)
    }

    /// Block until it is `stage`'s turn and `bytes` fit the budget, then
    /// account them.  Returns time spent stalled (the S^stop duration).
    /// Epoch-agnostic (admits on the current epoch's cursor) — pool
    /// loaders use [`OrderedGate::admit_at`] instead.
    pub fn admit(&self, stage: usize, bytes: u64) -> Result<Duration> {
        self.admit_inner(None, stage, bytes)
    }

    /// Epoch-tagged admission: parks until `epoch` is the gate's current
    /// pass AND it is `stage`'s turn AND `bytes` fit; errors if the epoch
    /// is already stale (a newer pass began — the tagged pass failed).
    pub fn admit_at(&self, epoch: u64, stage: usize, bytes: u64) -> Result<Duration> {
        self.admit_inner(Some(epoch), stage, bytes)
    }

    fn admit_inner(&self, epoch: Option<u64>, stage: usize, bytes: u64) -> Result<Duration> {
        if let Some(b) = self.accountant.budget() {
            if bytes > b {
                bail!("stage {stage}: {bytes} B can never fit budget {b} B");
            }
        }
        let (lock, cv) = &*self.state;
        let t0 = Instant::now();
        let mut s = lock.lock().unwrap();
        loop {
            if s.shutdown {
                bail!("gate shut down");
            }
            if let Some(e) = epoch {
                if s.epoch > e {
                    bail!("stale admission: epoch {e} already superseded by {}", s.epoch);
                }
            }
            let turn = epoch.map(|e| s.epoch == e).unwrap_or(true) && s.next_admit == stage;
            if turn {
                if self.accountant.try_acquire(bytes) {
                    self.ledger.adopt(bytes);
                    s.next_admit += 1;
                    cv.notify_all();
                    return Ok(t0.elapsed());
                }
                // S^stop pressure: reclaim resident-but-rebuildable state
                // before parking — speculation, device copies, pins (own
                // then victims'), and as a last resort cached KV sequences,
                // whose owners fall back to full-prefix recompute.  The
                // gate mutex is dropped while the chain runs: the fleet
                // token serializes chains across lanes, and a lane holding
                // its gate mutex through a chain would deadlock against
                // another lane's reclaim notifying this gate.
                drop(s);
                let reclaimed = {
                    let _chain = self.reclaim.acquire();
                    self.evict_chain_for(bytes)
                };
                if reclaimed {
                    // the freed headroom may also admit a peer lane's
                    // parked stage — this lane only retries itself below
                    self.notify_waiters();
                }
                s = lock.lock().unwrap();
                if reclaimed || !self.accountant.would_block(bytes) {
                    continue; // retry with the reclaimed (or freed) headroom
                }
                // Nothing reclaimable and still no room.  Any free that
                // landed during the unlocked window is visible to the
                // would_block check above; later frees notify under this
                // mutex, so the wait below cannot miss them.
            }
            s = cv.wait(s).unwrap();
        }
    }

    /// Advance the admission order past `stage` without acquiring memory —
    /// used for cache hits, whose bytes are already resident and accounted.
    /// Blocks until it is `stage`'s turn so ordering stays intact; returns
    /// the time spent waiting (recorded like an admit() stall, so cache
    /// hits and misses report their ordering waits symmetrically).
    pub fn skip(&self, stage: usize) -> Result<Duration> {
        self.skip_inner(None, stage)
    }

    /// Epoch-tagged [`OrderedGate::skip`] (pool loaders).
    pub fn skip_at(&self, epoch: u64, stage: usize) -> Result<Duration> {
        self.skip_inner(Some(epoch), stage)
    }

    fn skip_inner(&self, epoch: Option<u64>, stage: usize) -> Result<Duration> {
        let (lock, cv) = &*self.state;
        let t0 = Instant::now();
        let mut s = lock.lock().unwrap();
        loop {
            if s.shutdown {
                bail!("gate shut down");
            }
            if let Some(e) = epoch {
                if s.epoch > e {
                    bail!("stale skip: epoch {e} already superseded by {}", s.epoch);
                }
            }
            if epoch.map(|e| s.epoch == e).unwrap_or(true) && s.next_admit == stage {
                s.next_admit += 1;
                cv.notify_all();
                return Ok(t0.elapsed());
            }
            s = cv.wait(s).unwrap();
        }
    }

    /// Non-blocking speculative admission for cross-pass prefetch: acquire
    /// `bytes` only if the budget can hold them AND still leave `reserve`
    /// (the profile's `max_stage`) of headroom for the running pass.  Never
    /// parks, never evicts — prefetch only ever takes free slack.  The
    /// bytes are ledger-charged until the prefetched shard lands in the
    /// buffer (a store hand-off via [`OrderedGate::transfer_to_store`]) or
    /// is freed.
    pub fn try_admit_prefetch(&self, bytes: u64, reserve: u64) -> bool {
        self.ledger.try_acquire_reserving(bytes, reserve)
    }

    /// Free pass-owned bytes (daemon destruction, transient uploads,
    /// activations) and wake admission waiters.  All budget-relevant
    /// releases inside a pipeline pass MUST route through here (or
    /// [`OrderedGate::free_store`] for store-owned bytes), not the raw
    /// accountant — admit() parks on this gate's condvar.
    ///
    /// The notify happens while holding the gate mutex: admit() checks the
    /// budget under that mutex before parking, so an unlocked notify could
    /// land in the window between a failed `try_acquire` and `cv.wait` and
    /// be lost forever (the classic lost-wakeup).  Taking the mutex
    /// serializes this free against that window.  No lock-order inversion:
    /// the ledger and accountant locks inside are each released before the
    /// gate mutex is taken.
    pub fn free(&self, bytes: u64) {
        self.ledger.free(bytes);
        self.notify_waiters();
    }

    /// Free bytes a durable store owned (a displaced pin the daemon hands
    /// back, a prefetched duplicate the pass discards): same accountant
    /// release and waiter wakeup as [`OrderedGate::free`], but NOT drawn
    /// from the pass ledger — the pass never owned these bytes, so a
    /// ledger discharge would corrupt failed-pass recovery.
    pub fn free_store(&self, bytes: u64) {
        self.accountant.free(bytes);
        self.notify_waiters();
    }

    /// Drive the full eviction chain — own pinned layers, then victim
    /// sessions' pins, then cached KV sequences (own pool first) — until
    /// the accountant's `used` fits back under its (just-shrunk) budget or
    /// nothing evictable remains.  This is the elastic memory controller's
    /// `S^stop`-from-outside: a budget step arriving between passes applies
    /// the same pressure an admission stall would, through the same chain
    /// and in the same order.  Returns `(bytes_freed, evictions)` where
    /// `evictions` counts reclaimed pins + KV blocks.  Waiters parked on
    /// the gate are woken — freed bytes (or a grown budget) may admit them.
    ///
    /// Holds the fleet [`ReclaimToken`] for the whole walk (reentrantly, so
    /// a caller already holding it nests), serializing it against other
    /// lanes' inline admission reclaims under a shared budget.
    pub fn reclaim_to_budget(&self) -> (u64, u64) {
        let _chain = self.reclaim.acquire();
        let ev0 = self.chain_eviction_count();
        let mut freed = 0u64;
        if self.accountant.would_block(0) {
            if let Some(p) = &self.prefetch {
                freed += p.evict_for(0, &self.accountant);
            }
        }
        if self.accountant.would_block(0) {
            if let Some(d) = &self.device {
                freed += d.evict_for(0, &self.accountant);
            }
        }
        for c in self.cache.iter().chain(self.victims.iter()) {
            if !self.accountant.would_block(0) {
                break;
            }
            freed += c.evict_for(0, &self.accountant);
        }
        for l in &self.victim_devices {
            if !self.accountant.would_block(0) {
                break;
            }
            freed += l.evict_for(0, &self.accountant);
        }
        for p in &self.kv_pools {
            if !self.accountant.would_block(0) {
                break;
            }
            freed += p.evict_for(0);
        }
        let ev1 = self.chain_eviction_count();
        self.notify_waiters();
        if freed > 0 && self.telemetry.is_on() {
            self.telemetry.instant(
                "evict",
                worker::DAEMON,
                EvArgs::default().with_bytes(freed).with_reason("elastic"),
            );
        }
        (freed, ev1 - ev0)
    }

    /// Reclaims performed by every rung of this gate's chain so far
    /// (prefetch waste + device evictions + pin evictions + KV blocks).
    fn chain_eviction_count(&self) -> u64 {
        self.prefetch.iter().map(|p| p.stats().wasted).sum::<u64>()
            + self.device.iter().map(|d| d.stats().evictions).sum::<u64>()
            + self
                .cache
                .iter()
                .chain(self.victims.iter())
                .map(|c| c.stats().evictions)
                .sum::<u64>()
            + self.victim_devices.iter().map(|l| l.stats().evictions).sum::<u64>()
            + self.kv_pools.iter().map(|p| p.stats().evicted_blocks).sum::<u64>()
    }

    /// Rearm for the next pass of the same session: admission restarts at
    /// stage 0.  The accountant is NOT touched — pinned hot layers keep
    /// their bytes accounted across passes.  (Epoch-agnostic compatibility
    /// wrapper; sessions use [`OrderedGate::begin_pass`].)
    pub fn reset(&self) {
        let (lock, cv) = &*self.state;
        let mut s = lock.lock().unwrap();
        s.next_admit = 0;
        s.shutdown = false;
        cv.notify_all();
    }

    /// Open admission epoch `epoch` (the pass about to run): the cursor
    /// moves to `(epoch, 0)`, waiters tagged with `epoch` wake, waiters
    /// tagged with older epochs will error out as stale.  Clears any
    /// shutdown a failed previous pass raised.
    pub fn begin_pass(&self, epoch: u64) {
        let (lock, cv) = &*self.state;
        let mut s = lock.lock().unwrap();
        debug_assert!(epoch >= s.epoch, "epochs must be monotonic");
        s.epoch = epoch;
        s.next_admit = 0;
        s.shutdown = false;
        cv.notify_all();
    }

    /// The admission epoch currently open.
    pub fn current_epoch(&self) -> u64 {
        self.state.0.lock().unwrap().epoch
    }

    pub fn shutdown(&self) {
        self.state.0.lock().unwrap().shutdown = true;
        self.state.1.notify_all();
        self.accountant.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_in_stage_order_under_pressure() {
        // budget fits exactly one layer; stages 2,1,0 arrive out of order.
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for stage in [2usize, 1, 0] {
            let g = gate.clone();
            let ord = order.clone();
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(10 * (2 - stage) as u64));
                g.admit(stage, 100).unwrap();
                ord.lock().unwrap().push(stage);
            }));
        }
        // drain: free after each admission so the next can proceed
        for _ in 0..3 {
            while gate.accountant().used() < 100 {
                std::thread::sleep(Duration::from_millis(5));
            }
            gate.free(100);
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn no_deadlock_with_tight_budget() {
        // budget = 1 layer, 3 agents racing, consumer strictly in order.
        let gate = OrderedGate::new(MemoryAccountant::new(Some(10)));
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        let mut handles = Vec::new();
        for agent in 0..3usize {
            let g = gate.clone();
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                for stage in (agent..12).step_by(3) {
                    g.admit(stage, 10).unwrap();
                    tx.send(stage).unwrap();
                }
            }));
        }
        drop(tx);
        let mut next = 0;
        let mut pending = std::collections::BTreeSet::new();
        while next < 12 {
            let s = rx.recv_timeout(Duration::from_secs(5)).expect("pipeline deadlocked");
            pending.insert(s);
            while pending.remove(&next) {
                gate.free(10); // "computed" -> daemon frees
                next += 1;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(gate.accountant().used(), 0);
    }

    #[test]
    fn oversized_request_rejected() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(50)));
        assert!(gate.admit(0, 51).is_err());
    }

    #[test]
    fn shutdown_unblocks() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(10)));
        gate.admit(0, 10).unwrap();
        let g = gate.clone();
        let h = std::thread::spawn(move || g.admit(1, 10));
        std::thread::sleep(Duration::from_millis(30));
        gate.shutdown();
        assert!(h.join().unwrap().is_err());
    }

    #[test]
    fn sequential_admissions_fast_when_unconstrained() {
        let gate = OrderedGate::new(MemoryAccountant::unlimited());
        let t0 = Instant::now();
        for s in 0..50 {
            gate.admit(s, 1000).unwrap();
        }
        assert!(t0.elapsed().as_millis() < 200);
        assert_eq!(gate.accountant().used(), 50_000);
    }

    #[test]
    fn out_of_turn_request_waits_for_predecessor() {
        let gate = OrderedGate::new(MemoryAccountant::unlimited());
        let g = gate.clone();
        let h = std::thread::spawn(move || {
            let waited = g.admit(1, 10).unwrap();
            waited
        });
        std::thread::sleep(Duration::from_millis(40));
        gate.admit(0, 10).unwrap();
        let waited = h.join().unwrap();
        assert!(waited.as_millis() >= 30, "{waited:?}");
    }

    #[test]
    fn skip_advances_order_without_memory() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(10)));
        gate.skip(0).unwrap();
        assert_eq!(gate.accountant().used(), 0);
        // stage 1 can now admit immediately
        gate.admit(1, 10).unwrap();
        assert_eq!(gate.accountant().used(), 10);
    }

    #[test]
    fn skip_waits_for_turn_and_unblocks_successor() {
        let gate = OrderedGate::new(MemoryAccountant::unlimited());
        let g = gate.clone();
        let h = std::thread::spawn(move || g.skip(1));
        std::thread::sleep(Duration::from_millis(20));
        gate.admit(0, 5).unwrap(); // unblocks the skipper
        h.join().unwrap().unwrap();
        gate.admit(2, 5).unwrap(); // order advanced past the skip
    }

    #[test]
    fn reset_rearms_for_next_pass() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        gate.admit(0, 40).unwrap();
        gate.admit(1, 40).unwrap();
        gate.free(80);
        gate.reset();
        // admission restarts at stage 0; budget intact
        gate.admit(0, 100).unwrap();
        assert_eq!(gate.accountant().used(), 100);
    }

    #[test]
    fn stalled_admit_evicts_victim_session_pins() {
        use crate::weights::Shard;
        // Two sessions share one accountant; session B's gate carries
        // session A's cache as a victim.  B's admission under pressure must
        // reclaim A's pins (cross-model S^stop contention).
        let accountant = MemoryAccountant::new(Some(100));
        let cache_a = LayerCache::new(100);
        let mut gate_b = OrderedGate::new(accountant.clone());
        gate_b.add_victim(cache_a.clone());
        assert!(accountant.try_acquire(90));
        assert!(cache_a.pin(2, Arc::new(Shard { kind: "k".into(), stage: 2, tensors: vec![] }), 90));
        assert_eq!(gate_b.victim_pinned_bytes(), 90);
        let waited = gate_b.admit(0, 60).unwrap();
        assert!(waited.as_millis() < 1000);
        assert_eq!(accountant.used(), 60);
        assert_eq!(cache_a.stats().evictions, 1);
        assert_eq!(gate_b.victim_pinned_bytes(), 0);
    }

    #[test]
    fn stalled_admit_evicts_kv_blocks_after_pins() {
        use crate::weights::Shard;
        // One accountant holds a pinned layer (40 B) and a KV sequence
        // (256 B).  An admission needing 90 B must reclaim the pin first;
        // one needing more must then also take the KV blocks.
        let accountant = MemoryAccountant::new(Some(300));
        let cache = LayerCache::new(300);
        let pool = KvPool::with_block_tokens(accountant.clone(), None, 4);
        let mut gate = OrderedGate::with_cache(accountant.clone(), cache.clone());
        gate.add_kv_pool(pool.clone());
        assert!(accountant.try_acquire(40));
        assert!(cache.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        let seq = pool.open_seq(1, 1, 8); // one block = 4*8*4*2 = 256 B
        assert!(seq.reserve(1));
        assert_eq!(accountant.used(), 296);
        // needs 90: evicting the 40 B pin is enough (296-40+90 = 346 > 300?
        // no: 256+90 = 346 > 300, so KV must go too)
        let waited = gate.admit(0, 90).unwrap();
        assert!(waited.as_millis() < 1000);
        assert_eq!(cache.stats().evictions, 1, "pin reclaimed first");
        assert!(!seq.valid(), "KV sequence reclaimed under pressure");
        assert_eq!(pool.stats().evicted_blocks, 1);
        assert_eq!(accountant.used(), 90);
    }

    #[test]
    fn reclaim_to_budget_drives_pins_then_kv_after_shrink() {
        use crate::weights::Shard;
        // 40 B pinned + one 256 B KV block under a 400 B budget; shrinking
        // to 200 B must evict the pin first, then the KV sequence.
        let accountant = MemoryAccountant::new(Some(400));
        let cache = LayerCache::new(400);
        let pool = KvPool::with_block_tokens(accountant.clone(), None, 4);
        let mut gate = OrderedGate::with_cache(accountant.clone(), cache.clone());
        gate.add_kv_pool(pool.clone());
        assert!(accountant.try_acquire(40));
        assert!(cache.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        let seq = pool.open_seq(1, 1, 8); // one block = 256 B
        assert!(seq.reserve(1));
        assert_eq!(accountant.used(), 296);

        // within budget: reclaim is a no-op
        assert_eq!(gate.reclaim_to_budget(), (0, 0));

        accountant.resize(Some(200));
        let (freed, evictions) = gate.reclaim_to_budget();
        assert_eq!(freed, 296, "pin AND kv must go to fit 200 B");
        assert_eq!(evictions, 2, "1 pin + 1 kv block");
        assert_eq!(accountant.used(), 0);
        assert!(!seq.valid());
        assert_eq!(cache.stats().evictions, 1);

        // growing back requires no reclaim at all
        accountant.resize(Some(400));
        assert_eq!(gate.reclaim_to_budget(), (0, 0));
    }

    #[test]
    fn epoch_ordered_admission_across_pass_boundary() {
        // A loader dispatched for the NEXT pass parks until begin_pass
        // opens its epoch — even though budget and stage turn are free.
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        gate.begin_pass(1);
        let g = gate.clone();
        let h = std::thread::spawn(move || g.admit_at(2, 0, 10));
        std::thread::sleep(Duration::from_millis(40));
        // pass 1 runs to completion in the meantime
        gate.admit_at(1, 0, 50).unwrap();
        gate.free(50);
        assert!(!h.is_finished(), "epoch-2 admission must wait for its pass");
        gate.begin_pass(2);
        let waited = h.join().unwrap().unwrap();
        assert!(waited.as_millis() >= 30, "{waited:?}");
        assert_eq!(gate.accountant().used(), 10);
    }

    #[test]
    fn stale_epoch_admission_and_skip_fail() {
        let gate = OrderedGate::new(MemoryAccountant::unlimited());
        gate.begin_pass(3);
        assert!(gate.admit_at(2, 0, 10).is_err(), "superseded epoch must not admit");
        assert!(gate.skip_at(2, 0).is_err());
        // the current epoch still works
        gate.admit_at(3, 0, 10).unwrap();
        gate.skip_at(3, 1).unwrap();
    }

    #[test]
    fn begin_pass_clears_shutdown_and_restarts_cursor() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        gate.begin_pass(1);
        gate.admit_at(1, 0, 40).unwrap();
        gate.shutdown();
        assert!(gate.admit_at(1, 1, 10).is_err());
        gate.free(40);
        // begin_pass rearms the gate; the accountant is revived separately
        // (sessions do this in their failed-pass recovery)
        gate.accountant().revive();
        gate.begin_pass(2);
        assert_eq!(gate.current_epoch(), 2);
        gate.admit_at(2, 0, 100).unwrap();
    }

    #[test]
    fn stalled_admit_evicts_prefetch_before_pins() {
        use crate::pipeload::prefetch::PrefetchBuffer;
        use crate::weights::Shard;
        // 40 B pinned + 50 B prefetched under a 100 B budget.  An admission
        // needing 60 must reclaim the SPECULATIVE bytes first and leave the
        // pin alone (prefetch is the cheapest sacrifice in the chain).
        let accountant = MemoryAccountant::new(Some(100));
        let cache = LayerCache::new(100);
        let buffer = PrefetchBuffer::new();
        let mut gate = OrderedGate::with_cache(accountant.clone(), cache.clone());
        gate.set_prefetch(buffer.clone());
        assert!(accountant.try_acquire(40));
        assert!(cache.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        assert!(gate.try_admit_prefetch(50, 0));
        assert!(buffer.put(5, Arc::new(Shard { kind: "k".into(), stage: 5, tensors: vec![] }), 50));
        let waited = gate.admit(0, 60).unwrap();
        assert!(waited.as_millis() < 1000);
        assert_eq!(buffer.stats().wasted, 1, "prefetched entry reclaimed first");
        assert_eq!(cache.stats().evictions, 0, "pin must survive");
        assert_eq!(accountant.used(), 100);
    }

    #[test]
    fn prefetch_admission_preserves_headroom_reserve() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        // reserve 30 for the running pass: only 70 of slack is speculative
        assert!(gate.try_admit_prefetch(70, 30));
        assert!(!gate.try_admit_prefetch(1, 30), "reserve must hold");
        gate.free(70);
        assert!(gate.try_admit_prefetch(1, 30));
    }

    #[test]
    fn stalled_admit_evicts_device_entries_before_pins() {
        use crate::pipeload::device::DeviceLedger;
        use crate::weights::Shard;
        let accountant = MemoryAccountant::new(Some(100));
        let cache = LayerCache::new(100);
        let ledger = DeviceLedger::new(100);
        let mut gate = OrderedGate::with_cache(accountant.clone(), cache.clone());
        gate.set_device(ledger.clone());
        assert!(accountant.try_acquire(40));
        assert!(cache.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        accountant.force_add(50); // the device copy's bytes
        assert!(ledger.try_retain(2, 50));
        ledger.end_use(2);
        let waited = gate.admit(0, 60).unwrap();
        assert!(waited.as_millis() < 1000);
        assert_eq!(ledger.stats().evictions, 1, "device copy reclaimed first");
        assert_eq!(cache.stats().evictions, 0, "pin must survive");
        assert_eq!(accountant.used(), 100);
    }

    #[test]
    fn stalled_admit_evicts_pinned_layers() {
        use crate::weights::Shard;
        let accountant = MemoryAccountant::new(Some(100));
        let cache = LayerCache::new(100);
        let gate = OrderedGate::with_cache(accountant.clone(), cache.clone());
        // a previous pass pinned 80 bytes
        assert!(accountant.try_acquire(80));
        assert!(cache.pin(7, Arc::new(Shard { kind: "k".into(), stage: 7, tensors: vec![] }), 80));
        // a new admission needing 60 must evict the pin, not deadlock
        let waited = gate.admit(0, 60).unwrap();
        assert!(waited.as_millis() < 1000);
        assert_eq!(accountant.used(), 60);
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn reclaim_token_reentrant_and_mutually_exclusive() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let token = ReclaimToken::new();
        let g1 = token.acquire();
        let g2 = token.acquire(); // same thread nests freely
        let t = token.clone();
        let entered = Arc::new(AtomicBool::new(false));
        let flag = entered.clone();
        let h = std::thread::spawn(move || {
            let _g = t.acquire();
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!entered.load(Ordering::SeqCst), "other thread must wait");
        drop(g2);
        std::thread::sleep(Duration::from_millis(30));
        assert!(!entered.load(Ordering::SeqCst), "outer guard still holds");
        drop(g1);
        h.join().unwrap();
        assert!(entered.load(Ordering::SeqCst));
    }

    #[test]
    fn ledger_charges_admissions_and_store_frees_bypass_it() {
        let gate = OrderedGate::new(MemoryAccountant::new(Some(100)));
        gate.admit(0, 30).unwrap();
        assert_eq!(gate.ledger().balance(), 30);
        gate.force_add(20); // activation transient
        assert_eq!(gate.ledger().balance(), 50);
        // a pin sticks: 30 of the pass's bytes become store-owned
        gate.transfer_to_store(30);
        assert_eq!(gate.ledger().balance(), 20);
        // the store's eventual release must not touch the ledger
        gate.free_store(30);
        assert_eq!(gate.ledger().balance(), 20);
        assert_eq!(gate.accountant().used(), 20);
        // a cache take moves store-owned bytes back into the pass
        gate.accountant().force_add(10);
        gate.adopt(10);
        assert_eq!(gate.ledger().balance(), 30);
        gate.free(30);
        assert_eq!(gate.ledger().balance(), 0);
        assert_eq!(gate.accountant().used(), 0);
        assert_eq!(gate.ledger().drain(), 0, "nothing outstanding to recover");
    }

    #[test]
    fn failed_pass_drain_frees_only_pass_bytes() {
        use crate::weights::Shard;
        let accountant = MemoryAccountant::new(Some(100));
        let cache = LayerCache::new(100);
        let gate = OrderedGate::with_cache(accountant.clone(), cache.clone());
        // durable pin from an earlier pass: 40 B store-owned
        assert!(accountant.try_acquire(40));
        assert!(cache.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        // the pass charges 50 B of transients, then dies mid-flight
        gate.admit(0, 20).unwrap();
        gate.force_add(30);
        assert_eq!(gate.ledger().drain(), 50, "recovery frees the pass's bytes");
        assert_eq!(accountant.used(), 40, "the pin survives recovery untouched");
    }

    #[test]
    fn concurrent_cross_lane_reclaim_shares_token_without_deadlock() {
        use crate::weights::Shard;
        // Two lanes under one 100 B budget, each carrying the other's cache
        // as a victim and sharing one reclaim token.  Both hammer stalled
        // admissions that must evict across lanes — no deadlock, no
        // double-free (the accountant's underflow assert would fire), and
        // the shared peak never exceeds the budget.
        let accountant = MemoryAccountant::new(Some(100));
        let cache_a = LayerCache::new(100);
        let cache_b = LayerCache::new(100);
        let mut gate_a = OrderedGate::with_cache(accountant.clone(), cache_a.clone());
        let mut gate_b = OrderedGate::with_cache(accountant.clone(), cache_b.clone());
        gate_a.add_victim(cache_b.clone());
        gate_b.add_victim(cache_a.clone());
        let token = ReclaimToken::new();
        gate_a.set_reclaim_token(token.clone());
        gate_b.set_reclaim_token(token);
        gate_a.add_peer(&gate_b);
        gate_b.add_peer(&gate_a);
        assert!(accountant.try_acquire(40));
        assert!(cache_a.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        assert!(accountant.try_acquire(40));
        assert!(cache_b.pin(1, Arc::new(Shard { kind: "k".into(), stage: 1, tensors: vec![] }), 40));
        std::thread::scope(|scope| {
            for gate in [&gate_a, &gate_b] {
                scope.spawn(move || {
                    for _ in 0..20 {
                        gate.admit(0, 60).unwrap();
                        gate.free(60);
                        gate.reset();
                    }
                });
            }
        });
        assert_eq!(accountant.used(), 0);
        assert!(accountant.peak() <= 100, "peak {} over budget", accountant.peak());
        assert_eq!(gate_a.ledger().balance() + gate_b.ledger().balance(), 0);
    }
}
