//! Persistent worker pool: long-lived Loading Agent + Daemon threads.
//!
//! The original per-pass machinery spawned `m` loader threads plus one
//! daemon thread inside a `std::thread::scope` for *every* pass — a
//! multi-token decode or a `serve()` batch stream paid N×(m+1) thread
//! spawn/joins of pure overhead on its hot path.  The pool inverts that:
//! threads are spawned once (when a `Session` opens, or lazily as an
//! elastic re-plan raises the agent count) and fed per-pass **work
//! descriptors** over channels.
//!
//! Three task kinds flow through the pool:
//!
//! * [`PassTask`] — one Loading Agent's stage list for one pass (epoch).
//!   The loader tags every gate operation with the epoch, so a task from a
//!   failed, superseded pass errors out instead of corrupting the next
//!   pass's admission order.  Stall and load time accumulate in **local**
//!   variables and are reported once, in the task's final
//!   [`LoadMsg::AgentDone`] marker — the old per-stage
//!   `Arc<Mutex<f64>>` round-trips were pure hot-path contention.
//! * [`PrefetchTask`] — speculative loads of the NEXT pass's head stages,
//!   queued behind the agent's current-pass work so it runs exactly when
//!   the loader would otherwise idle (the tail of the pass, when the
//!   Inference Agent is still computing).  Admission is non-blocking and
//!   headroom-preserving ([`OrderedGate::try_admit_prefetch`]); loaded
//!   shards park in the [`PrefetchBuffer`].
//! * [`DaemonTask`] — one pass's destruction stream.  The daemon acks when
//!   the stream closes, so the pass boundary still guarantees every
//!   pin/destroy decision landed before the next pass looks.
//!
//! [`OrderedGate::try_admit_prefetch`]:
//!     crate::pipeload::gate::OrderedGate::try_admit_prefetch
//! [`PrefetchBuffer`]: crate::pipeload::prefetch::PrefetchBuffer

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::cache::LayerCache;
use super::gate::OrderedGate;
use super::prefetch::PrefetchBuffer;
use super::{StageMsg, STALL_EPS_MS};
use crate::diskio::Disk;
use crate::faults::{FaultInjector, FaultKind, RetryPolicy};
use crate::model::TensorSpec;
use crate::signals::{Signal, SignalLog};
use crate::telemetry::{worker, EvArgs, Telemetry};
use crate::trace::{Kind, Lane, Tracer};
use crate::weights::{read_shard_from, validate_against, Shard};

/// One stage's loading work, made `'static` for the persistent threads
/// (the per-pass descriptor owns everything; no borrows of the profile).
pub(crate) struct StageJob {
    pub stage: usize,
    pub shard_file: String,
    pub bytes: u64,
    /// manifest specs to validate against (None = validation off)
    pub params: Option<Vec<TensorSpec>>,
}

/// Everything a pass's worker tasks share (cloned Arcs, no borrows).
pub(crate) struct PassShared {
    pub gate: OrderedGate,
    pub cache: Option<LayerCache>,
    pub buffer: Option<PrefetchBuffer>,
    pub disk: Disk,
    pub tracer: Tracer,
    pub telemetry: Telemetry,
    /// this pass's admission epoch — tags every worker-side event
    pub epoch: u64,
    pub signals: SignalLog,
    pub shard_dir: PathBuf,
    /// fault probes for this pass's workers (`agent_panic`, disk faults)
    pub faults: FaultInjector,
    /// transient-load retry schedule (deterministic jittered backoff)
    pub retry: RetryPolicy,
}

/// Loader → Inference channel messages.
pub(crate) enum LoadMsg {
    Stage(StageMsg),
    Failed(anyhow::Error),
    /// task finished: the agent's pass-local stall/load totals, summed
    /// once here instead of locked per stage
    AgentDone { mem_stall_ms: f64, load_ms: f64 },
}

/// One Loading Agent's work for one pass.
pub(crate) struct PassTask {
    pub epoch: u64,
    pub agent: usize,
    pub jobs: Vec<StageJob>,
    pub tx: mpsc::Sender<LoadMsg>,
    pub shared: Arc<PassShared>,
}

/// Speculative head-stage loads for the pass after the current one.
pub(crate) struct PrefetchTask {
    pub agent: usize,
    pub jobs: Vec<StageJob>,
    pub shared: Arc<PassShared>,
    /// headroom the running pass keeps (`max_stage`)
    pub reserve: u64,
    pub group: TaskGroup,
}

/// One pass's destruction stream for the Daemon.
pub(crate) struct DaemonTask {
    pub rx: mpsc::Receiver<StageMsg>,
    pub shared: Arc<PassShared>,
    pub destroy: bool,
    pub ack: mpsc::Sender<()>,
}

enum LoaderWork {
    Pass(PassTask),
    Prefetch(PrefetchTask),
}

/// Counts in-flight prefetch tasks so error recovery (and tests) can wait
/// for speculative work to quiesce before reasoning about accounting.
#[derive(Debug, Clone, Default)]
pub struct TaskGroup {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl TaskGroup {
    pub fn new() -> TaskGroup {
        TaskGroup::default()
    }

    fn enter(&self) {
        *self.inner.0.lock().unwrap() += 1;
    }

    fn exit(&self) {
        let mut n = self.inner.0.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        // saturating: a double-exit on a panic-recovery path must not take
        // down the monitor with an underflow
        *n = n.saturating_sub(1);
        self.inner.1.notify_all();
    }

    /// Block until every entered task has exited.
    pub fn wait_idle(&self) {
        let mut n = self.inner.0.lock().unwrap();
        while *n > 0 {
            n = self.inner.1.wait(n).unwrap();
        }
    }
}

/// Thread-spawn accounting for the `spawns_avoided` counter.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// threads this pool actually spawned (loaders + daemon)
    pub threads_spawned: u64,
    /// threads the old per-pass scope would have spawned for the same work
    pub legacy_spawns: u64,
    /// passes dispatched through the pool
    pub passes: u64,
}

impl PoolStats {
    /// Spawn/joins the persistent pool saved vs the per-pass design.
    pub fn spawns_avoided(&self) -> u64 {
        self.legacy_spawns.saturating_sub(self.threads_spawned)
    }
}

struct Worker<T> {
    tx: Option<mpsc::Sender<T>>,
    handle: Option<JoinHandle<()>>,
}

impl<T> Worker<T> {
    fn shutdown(&mut self) {
        self.tx.take(); // closing the channel ends the thread's loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Long-lived Loading Agent + Daemon threads, owned by a `Session` (or
/// built throwaway by `run_pipeline` for one-shot paper-semantics runs).
pub struct WorkerPool {
    loaders: Mutex<Vec<Worker<LoaderWork>>>,
    daemon: Mutex<Worker<DaemonTask>>,
    stats: Mutex<PoolStats>,
}

impl WorkerPool {
    /// Spawn the daemon and `agents` loader threads.  More loaders are
    /// spawned on demand if an elastic re-plan raises the agent count.
    pub fn new(agents: usize) -> WorkerPool {
        let pool = WorkerPool {
            loaders: Mutex::new(Vec::new()),
            daemon: Mutex::new(Self::spawn_daemon()),
            stats: Mutex::new(PoolStats { threads_spawned: 1, ..PoolStats::default() }),
        };
        pool.ensure_loaders(agents);
        pool
    }

    fn spawn_daemon() -> Worker<DaemonTask> {
        let (tx, rx) = mpsc::channel::<DaemonTask>();
        let handle = std::thread::spawn(move || {
            for task in rx {
                run_daemon_task(task);
            }
        });
        Worker { tx: Some(tx), handle: Some(handle) }
    }

    /// Make sure at least `agents` loader threads exist.
    pub fn ensure_loaders(&self, agents: usize) {
        let mut loaders = self.loaders.lock().unwrap();
        while loaders.len() < agents {
            let (tx, rx) = mpsc::channel::<LoaderWork>();
            let handle = std::thread::spawn(move || {
                for work in rx {
                    match work {
                        // Agent boundary containment: a panicking loader
                        // (injected or real) fails ITS pass via the normal
                        // `LoadMsg::Failed` path and the thread survives to
                        // serve the next one — one panic costs one pass,
                        // never the process.
                        LoaderWork::Pass(t) => {
                            let tx = t.tx.clone();
                            let agent = t.agent;
                            if catch_unwind(AssertUnwindSafe(|| run_pass_task(t))).is_err() {
                                let _ = tx.send(LoadMsg::Failed(anyhow!(
                                    "loading agent {agent} panicked (contained)"
                                )));
                            }
                        }
                        LoaderWork::Prefetch(t) => {
                            let group = t.group.clone();
                            if catch_unwind(AssertUnwindSafe(|| run_prefetch_task(t))).is_err()
                            {
                                // speculation never fails a pass; just make
                                // sure the quiesce counter can't leak
                                group.exit();
                            }
                        }
                    }
                }
            });
            loaders.push(Worker { tx: Some(tx), handle: Some(handle) });
            self.stats.lock().unwrap().threads_spawned += 1;
        }
    }

    pub(crate) fn submit_pass(&self, agent: usize, task: PassTask) -> Result<()> {
        self.ensure_loaders(agent + 1);
        let loaders = self.loaders.lock().unwrap();
        loaders[agent]
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("loader {agent} shut down"))?
            .send(LoaderWork::Pass(task))
            .map_err(|_| anyhow!("loader {agent} exited"))
    }

    pub(crate) fn submit_prefetch(&self, agent: usize, task: PrefetchTask) -> Result<()> {
        self.ensure_loaders(agent + 1);
        task.group.enter();
        let loaders = self.loaders.lock().unwrap();
        let tx = match loaders[agent].tx.as_ref() {
            Some(tx) => tx,
            None => {
                task.group.exit();
                return Err(anyhow!("loader {agent} shut down"));
            }
        };
        if let Err(mpsc::SendError(LoaderWork::Prefetch(t))) =
            tx.send(LoaderWork::Prefetch(task))
        {
            t.group.exit();
            return Err(anyhow!("loader {agent} exited"));
        }
        Ok(())
    }

    pub(crate) fn submit_daemon(&self, task: DaemonTask) -> Result<()> {
        let daemon = self.daemon.lock().unwrap();
        daemon
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("daemon shut down"))?
            .send(task)
            .map_err(|_| anyhow!("daemon exited"))
    }

    /// Record one pass dispatched with `agents_used` active loaders — the
    /// per-pass design would have spawned `agents_used + 1` threads here.
    pub fn note_pass(&self, agents_used: u64) {
        let mut s = self.stats.lock().unwrap();
        s.passes += 1;
        s.legacy_spawns += agents_used + 1;
    }

    pub fn stats(&self) -> PoolStats {
        *self.stats.lock().unwrap()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for w in self.loaders.lock().unwrap().iter_mut() {
            w.shutdown();
        }
        self.daemon.lock().unwrap().shutdown();
    }
}

/// Read one shard through the throttled edge-storage stream, retrying
/// transient failures under the pass's [`RetryPolicy`].  Admitted bytes
/// stay held across retries (no gate re-entry), so the accounting a retry
/// sees is exactly what the first attempt saw.
fn load_shard(shared: &PassShared, job: &StageJob) -> Result<Shard> {
    let mut attempt = 0u32;
    loop {
        match load_shard_once(shared, job) {
            Ok(shard) => return Ok(shard),
            Err(e) if attempt < shared.retry.max_retries => {
                attempt += 1;
                shared.faults.stats().note_load_retry();
                if shared.telemetry.is_on() {
                    shared.telemetry.instant(
                        "retry",
                        worker::DRIVER,
                        EvArgs::stage(job.stage).with_epoch(shared.epoch).with_reason("load"),
                    );
                }
                let _ = e; // superseded by the retry
                std::thread::sleep(Duration::from_millis(
                    shared.retry.backoff_ms(job.stage as u64, attempt),
                ));
            }
            Err(e) => {
                return Err(e.context(format!(
                    "loading {} (gave up after {attempt} retries)",
                    job.shard_file
                )))
            }
        }
    }
}

fn load_shard_once(shared: &PassShared, job: &StageJob) -> Result<Shard> {
    let reader = shared.disk.open(&shared.shard_dir.join(&job.shard_file))?;
    let shard =
        read_shard_from(reader).with_context(|| format!("shard {}", job.shard_file))?;
    if let Some(params) = &job.params {
        validate_against(&shard, params)?;
    }
    Ok(shard)
}

/// The Loading Agent body for one pass (the old per-pass closure, minus
/// the spawn, plus epoch tags, prefetch-buffer hits, and local stat
/// accumulation).
fn run_pass_task(t: PassTask) {
    let sh = &*t.shared;
    // Injected agent death fires BEFORE any admission or load: no bytes
    // held, no locks poisoned — the cleanest possible worker crash, which
    // is exactly what the containment boundary above must absorb.
    if sh.faults.fire(FaultKind::AgentPanic) {
        panic!("injected loading-agent panic (fault plan)");
    }
    let tel_on = sh.telemetry.is_on();
    let mut stall_ms = 0.0f64;
    let mut load_ms = 0.0f64;
    'jobs: for job in &t.jobs {
        let stage_idx = job.stage;
        // Cross-pass prefetch / hot-layer cache: either way the stage's
        // bytes are already resident and accounted, so it skips disk AND
        // admission, but still takes its slot in the admission order —
        // and its ordering wait is recorded exactly like a miss's.
        let mut resident = sh.cache.as_ref().and_then(|c| c.take(stage_idx));
        if resident.is_some() {
            // A pin won the race against a speculative load of the same
            // stage (the daemon pinned it after the prefetcher's
            // is_pinned check).  Release the redundant duplicate now, or
            // its bytes would stay parked for the session's lifetime.
            // The duplicate was buffer-owned, not this pass's charge.
            if let Some(dup_bytes) = sh.buffer.as_ref().and_then(|b| b.discard(stage_idx)) {
                sh.gate.free_store(dup_bytes);
                if tel_on {
                    sh.telemetry.instant(
                        "prefetch_waste",
                        worker::loader(t.agent),
                        EvArgs::stage(stage_idx)
                            .with_bytes(dup_bytes)
                            .with_reason("stale_duplicate"),
                    );
                }
            }
        } else {
            resident = sh.buffer.as_ref().and_then(|b| b.take(stage_idx));
        }
        if let Some((shard, bytes)) = resident {
            // the take moved store-owned bytes into this pass: the daemon
            // will free them through the pass ledger when the stage dies
            sh.gate.adopt(bytes);
            let t_gate0 = sh.tracer.now_ms();
            let t_gate0_us = if tel_on { sh.telemetry.now_us() } else { 0 };
            let waited = match sh.gate.skip_at(t.epoch, stage_idx) {
                Ok(w) => w,
                Err(e) => {
                    let _ = t.tx.send(LoadMsg::Failed(e));
                    break 'jobs;
                }
            };
            let waited_ms = waited.as_secs_f64() * 1000.0;
            if waited_ms > STALL_EPS_MS {
                sh.tracer.record(
                    Lane::Loader(t.agent),
                    Kind::StallMem,
                    Some(stage_idx),
                    t_gate0,
                    sh.tracer.now_ms(),
                );
                sh.signals.emit(Signal::Stop { agent: t.agent, ms: waited_ms });
                stall_ms += waited_ms;
                if tel_on {
                    sh.telemetry.span(
                        "stall_mem",
                        worker::loader(t.agent),
                        t_gate0_us,
                        EvArgs::stage(stage_idx).with_epoch(t.epoch),
                    );
                }
            }
            sh.signals.emit(Signal::Comp { stage: stage_idx, agent: t.agent });
            let _ = t.tx.send(LoadMsg::Stage(StageMsg {
                stage: stage_idx,
                agent: t.agent,
                shard,
                bytes,
            }));
            continue;
        }
        if let Some(cache) = &sh.cache {
            cache.record_miss();
        }
        // S^stop: wait for the Daemon's memory admission (epoch-ordered).
        let t_gate0 = sh.tracer.now_ms();
        let t_gate0_us = if tel_on { sh.telemetry.now_us() } else { 0 };
        let waited = match sh.gate.admit_at(t.epoch, stage_idx, job.bytes) {
            Ok(w) => w,
            Err(e) => {
                let _ = t
                    .tx
                    .send(LoadMsg::Failed(e.context(format!("admitting stage {stage_idx}"))));
                break 'jobs;
            }
        };
        let waited_ms = waited.as_secs_f64() * 1000.0;
        if waited_ms > STALL_EPS_MS {
            sh.tracer.record(
                Lane::Loader(t.agent),
                Kind::StallMem,
                Some(stage_idx),
                t_gate0,
                sh.tracer.now_ms(),
            );
            sh.signals.emit(Signal::Stop { agent: t.agent, ms: waited_ms });
            stall_ms += waited_ms;
            if tel_on {
                sh.telemetry.span(
                    "stall_mem",
                    worker::loader(t.agent),
                    t_gate0_us,
                    EvArgs::stage(stage_idx).with_epoch(t.epoch),
                );
            }
        }
        // Load disk -> memory through the throttled stream.
        let t0 = sh.tracer.now_ms();
        let t0_us = if tel_on { sh.telemetry.now_us() } else { 0 };
        match load_shard(sh, job) {
            Ok(shard) => {
                let t1 = sh.tracer.now_ms();
                sh.tracer.record(Lane::Loader(t.agent), Kind::Load, Some(stage_idx), t0, t1);
                load_ms += t1 - t0;
                if tel_on {
                    sh.telemetry.span(
                        "load",
                        worker::loader(t.agent),
                        t0_us,
                        EvArgs::stage(stage_idx).with_epoch(t.epoch).with_bytes(job.bytes),
                    );
                }
                // S_comp: layer ready for computation.
                sh.signals.emit(Signal::Comp { stage: stage_idx, agent: t.agent });
                let _ = t.tx.send(LoadMsg::Stage(StageMsg {
                    stage: stage_idx,
                    agent: t.agent,
                    shard: Arc::new(shard),
                    bytes: job.bytes,
                }));
            }
            Err(e) => {
                sh.gate.free(job.bytes);
                let _ = t.tx.send(LoadMsg::Failed(e));
                break 'jobs;
            }
        }
    }
    let _ = t.tx.send(LoadMsg::AgentDone { mem_stall_ms: stall_ms, load_ms });
}

/// Speculatively load next-pass head stages into the prefetch buffer.
/// Purely opportunistic: a stage already resident is skipped, and the
/// first budget refusal abandons the rest (the running pass owns the
/// memory; speculation only ever takes free slack).
fn run_prefetch_task(t: PrefetchTask) {
    let sh = &*t.shared;
    let tel_on = sh.telemetry.is_on();
    let Some(buffer) = sh.buffer.as_ref() else {
        t.group.exit();
        return;
    };
    for job in &t.jobs {
        if buffer.contains(job.stage)
            || sh.cache.as_ref().map(|c| c.is_pinned(job.stage)).unwrap_or(false)
        {
            continue;
        }
        if !sh.gate.try_admit_prefetch(job.bytes, t.reserve) {
            break;
        }
        let t0 = sh.tracer.now_ms();
        let t0_us = if tel_on { sh.telemetry.now_us() } else { 0 };
        match load_shard(sh, job) {
            Ok(shard) => {
                sh.tracer.record(
                    Lane::Loader(t.agent),
                    Kind::Prefetch,
                    Some(job.stage),
                    t0,
                    sh.tracer.now_ms(),
                );
                if tel_on {
                    sh.telemetry.span(
                        "prefetch",
                        worker::loader(t.agent),
                        t0_us,
                        EvArgs::stage(job.stage).with_epoch(sh.epoch).with_bytes(job.bytes),
                    );
                }
                if buffer.put(job.stage, Arc::new(shard), job.bytes) {
                    // parked in the buffer: now store-owned, not a charge
                    // failed-pass recovery may drain
                    sh.gate.transfer_to_store(job.bytes);
                } else {
                    sh.gate.free(job.bytes); // raced: someone parked it first
                }
            }
            Err(_) => {
                sh.gate.free(job.bytes);
                break; // speculation never fails a pass; just stop
            }
        }
    }
    t.group.exit();
}

/// The Daemon Agent body for one pass: pin-or-destroy each computed
/// stage, then ack so the pass boundary knows every decision landed.
fn run_daemon_task(t: DaemonTask) {
    let sh = &*t.shared;
    let tel_on = sh.telemetry.is_on();
    let mut kept: Vec<StageMsg> = Vec::new();
    for msg in t.rx {
        if t.destroy {
            let t0 = sh.tracer.now_ms();
            // Pin instead of destroy when the pin budget has room; the
            // layer's bytes stay accounted for the next pass.  The score
            // (predicted reload cost per byte) only matters under the
            // cost policy, where an expensive layer may displace cheaper
            // pins; displaced bytes go back to the budget through the gate.
            if let Some(cache) = &sh.cache {
                let score = sh.disk.est_load_ms(msg.bytes) / msg.bytes.max(1) as f64;
                let (pinned, displaced) =
                    cache.pin_scored(msg.stage, msg.shard.clone(), msg.bytes, score);
                if displaced > 0 {
                    // displaced pins were cache-owned, not this pass's
                    sh.gate.free_store(displaced);
                }
                if pinned {
                    // the pin keeps the stage's bytes across passes: they
                    // leave the pass ledger and become cache-owned
                    sh.gate.transfer_to_store(msg.bytes);
                    sh.tracer.record(
                        Lane::Daemon,
                        Kind::Pin,
                        Some(msg.stage),
                        t0,
                        sh.tracer.now_ms(),
                    );
                    if tel_on {
                        sh.telemetry.instant(
                            "pin",
                            worker::DAEMON,
                            EvArgs::stage(msg.stage).with_epoch(sh.epoch).with_bytes(msg.bytes),
                        );
                    }
                    continue;
                }
            }
            drop(msg.shard); // the destruction
            sh.gate.free(msg.bytes);
            sh.tracer.record(Lane::Daemon, Kind::Destroy, Some(msg.stage), t0, sh.tracer.now_ms());
            if tel_on {
                sh.telemetry.instant(
                    "destroy",
                    worker::DAEMON,
                    EvArgs::stage(msg.stage).with_epoch(sh.epoch).with_bytes(msg.bytes),
                );
            }
        } else {
            kept.push(msg); // standard pipeline: stays resident
        }
    }
    for msg in kept {
        sh.gate.free(msg.bytes);
    }
    let _ = t.ack.send(());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawns_avoided_accumulates_per_pass() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.stats().threads_spawned, 3, "2 loaders + daemon");
        assert_eq!(pool.stats().spawns_avoided(), 0);
        for _ in 0..5 {
            pool.note_pass(2);
        }
        let s = pool.stats();
        assert_eq!(s.passes, 5);
        assert_eq!(s.legacy_spawns, 15, "old design: 3 spawns per pass");
        assert_eq!(s.spawns_avoided(), 12);
    }

    #[test]
    fn ensure_loaders_grows_on_demand() {
        let pool = WorkerPool::new(1);
        pool.ensure_loaders(4);
        assert_eq!(pool.stats().threads_spawned, 5);
        pool.ensure_loaders(2); // never shrinks, never respawns
        assert_eq!(pool.stats().threads_spawned, 5);
    }

    #[test]
    fn task_group_waits_for_exits() {
        let g = TaskGroup::new();
        g.enter();
        let g2 = g.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            g2.exit();
        });
        let t0 = std::time::Instant::now();
        g.wait_idle();
        assert!(t0.elapsed().as_millis() >= 20);
        h.join().unwrap();
    }
}
