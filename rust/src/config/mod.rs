//! Repo paths + engine configuration defaults.

use std::path::PathBuf;

use anyhow::Result;

/// Standard repo locations, overridable via environment.
#[derive(Debug, Clone)]
pub struct Paths {
    pub root: PathBuf,
    pub artifacts: PathBuf,
    pub weights: PathBuf,
    pub results: PathBuf,
}

impl Paths {
    /// Resolve from `HERMES_ROOT` or the crate's source location (so tests,
    /// examples, and benches all find `artifacts/` regardless of cwd).
    pub fn detect() -> Paths {
        let root = std::env::var("HERMES_ROOT")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
        Paths {
            artifacts: root.join("artifacts"),
            weights: root.join("weights"),
            results: root.join("results"),
            root,
        }
    }
}

/// Execution mode for a run (paper section V-A2: the Execution Engine's
/// three operational modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// load the whole model, then infer (non-pipeline)
    Baseline,
    /// standard pipeline, one loading stream, no destruction (PipeSwitch-like)
    PipeSwitch,
    /// the paper's contribution
    PipeLoad,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "baseline" => Mode::Baseline,
            "pipeswitch" => Mode::PipeSwitch,
            "pipeload" => Mode::PipeLoad,
            _ => anyhow::bail!("unknown mode '{s}' (baseline|pipeswitch|pipeload)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Baseline => "baseline",
            Mode::PipeSwitch => "pipeswitch",
            Mode::PipeLoad => "pipeload",
        }
    }
}

/// Everything one engine run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub profile: String,
    pub mode: Mode,
    /// number of Loading Agents (PIPELOAD only)
    pub agents: usize,
    /// memory budget in bytes (None = unconstrained)
    pub budget: Option<u64>,
    /// hot-layer cache pin budget in bytes (PIPELOAD sessions only).
    /// None/0 reproduces the paper's always-destroy semantics; >0 lets the
    /// Daemon keep up to this many bytes of computed layers resident
    /// across passes when the memory budget has slack.
    pub pin_budget: Option<u64>,
    pub disk: String,
    pub batch: usize,
    pub seed: u64,
    pub trace: bool,
    /// generative models: tokens to generate (None = profile default)
    pub gen_tokens: Option<usize>,
    /// KV-cache extension (OFF reproduces the paper's per-token reload)
    pub kv_cache: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 4,
            budget: None,
            pin_budget: None,
            disk: "edge-emmc".into(),
            batch: 1,
            seed: 42,
            trace: false,
            gen_tokens: None,
            kv_cache: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [Mode::Baseline, Mode::PipeSwitch, Mode::PipeLoad] {
            assert_eq!(Mode::parse(m.name()).unwrap(), m);
        }
        assert!(Mode::parse("gpu").is_err());
    }

    #[test]
    fn paths_detect_contains_artifacts() {
        let p = Paths::detect();
        assert!(p.artifacts.ends_with("artifacts"));
        assert!(p.weights.ends_with("weights"));
    }

    #[test]
    fn default_config_sane() {
        let c = RunConfig::default();
        assert_eq!(c.mode, Mode::PipeLoad);
        assert!(c.agents >= 1);
        assert!(!c.kv_cache);
    }
}
