//! Repo paths + engine configuration defaults.

use std::path::PathBuf;

use anyhow::Result;

/// Standard repo locations, overridable via environment.
#[derive(Debug, Clone)]
pub struct Paths {
    pub root: PathBuf,
    pub artifacts: PathBuf,
    pub weights: PathBuf,
    pub results: PathBuf,
}

impl Paths {
    /// Resolve from `HERMES_ROOT` or the crate's source location (so tests,
    /// examples, and benches all find `artifacts/` regardless of cwd).
    pub fn detect() -> Paths {
        let root = std::env::var("HERMES_ROOT")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
        Paths {
            artifacts: root.join("artifacts"),
            weights: root.join("weights"),
            results: root.join("results"),
            root,
        }
    }
}

/// Execution mode for a run (paper section V-A2: the Execution Engine's
/// three operational modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// load the whole model, then infer (non-pipeline)
    Baseline,
    /// standard pipeline, one loading stream, no destruction (PipeSwitch-like)
    PipeSwitch,
    /// the paper's contribution
    PipeLoad,
}

impl Mode {
    pub fn parse(s: &str) -> Result<Mode> {
        Ok(match s {
            "baseline" => Mode::Baseline,
            "pipeswitch" => Mode::PipeSwitch,
            "pipeload" => Mode::PipeLoad,
            _ => anyhow::bail!("unknown mode '{s}' (baseline|pipeswitch|pipeload)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Baseline => "baseline",
            Mode::PipeSwitch => "pipeswitch",
            Mode::PipeLoad => "pipeload",
        }
    }
}

/// Hot-layer cache pin policy (which computed layers the Daemon keeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PinPolicy {
    /// pin in compute order until the pin budget fills (first come wins)
    #[default]
    Fifo,
    /// pin by load-cost-per-byte score: a newly computed layer displaces
    /// lower-scoring pins, so the bytes kept are the ones that are most
    /// expensive to re-read from the edge medium (seek-heavy small stages
    /// score above bandwidth-bound large ones)
    Cost,
}

impl PinPolicy {
    pub fn parse(s: &str) -> Result<PinPolicy> {
        Ok(match s {
            "fifo" => PinPolicy::Fifo,
            "cost" => PinPolicy::Cost,
            _ => anyhow::bail!("unknown pin policy '{s}' (fifo|cost)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PinPolicy::Fifo => "fifo",
            PinPolicy::Cost => "cost",
        }
    }
}

/// Everything one engine run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub profile: String,
    pub mode: Mode,
    /// number of Loading Agents (PIPELOAD only)
    pub agents: usize,
    /// memory budget in bytes (None = unconstrained)
    pub budget: Option<u64>,
    /// hot-layer cache pin budget in bytes (PIPELOAD sessions only).
    /// None/0 reproduces the paper's always-destroy semantics; >0 lets the
    /// Daemon keep up to this many bytes of computed layers resident
    /// across passes when the memory budget has slack.
    pub pin_budget: Option<u64>,
    /// which layers the Daemon pins when the pin budget is contended
    pub pin_policy: PinPolicy,
    pub disk: String,
    pub batch: usize,
    pub seed: u64,
    pub trace: bool,
    /// generative models: tokens to generate (None = profile default)
    pub gen_tokens: Option<usize>,
    /// KV-cache decode (OFF reproduces the paper's full-prefix re-execution
    /// per token; ON runs one full-prefix pass then incremental single-token
    /// passes against the paged KV pool — GPT-style profiles only)
    pub kv_cache: bool,
    /// KV pool byte cap (None = bounded only by the memory budget).
    /// Validated `pin_budget + kv_budget <= budget` so weights-in-flight,
    /// pins, and attention state are jointly planned.
    pub kv_budget: Option<u64>,
    /// KV pool allocation granularity in tokens per block (None = the
    /// pool's default).  Small blocks waste less memory on short tails;
    /// large blocks amortize reserve calls.  Validated >= 1.
    pub kv_block_tokens: Option<usize>,
    /// Cross-pass prefetch: while pass k's tail computes, idle Loading
    /// Agents may speculatively load the first `prefetch_depth` stages of
    /// pass k+1 (0 = off, the paper's strict per-pass semantics).
    /// PIPELOAD sessions only; speculation only ever takes budget slack
    /// and is first in the eviction chain.
    pub prefetch_depth: usize,
    /// Device-resident layer cache: keep hot stages' weight `PjRtBuffer`s
    /// alive across passes so pinned stages skip the host→device re-upload
    /// (on by default; only active when `pin_budget` > 0 leaves cap room).
    pub device_cache: bool,
    /// Continuous batching (`--continuous`): serving lanes re-form the
    /// active set at every token boundary — requests join a running
    /// decode with one prime pass and leave on completion, instead of
    /// the fixed-batch path's admit-then-drain.  Serving only.
    pub continuous: bool,
    /// Per-lane SLO target in milliseconds (`--slo-ms`): end-to-end
    /// latency goal used by the continuous scheduler for overload
    /// shedding and the `slo_attained_pct` counter.  Requires
    /// `continuous`.  Individual requests may override it on the wire.
    pub slo_ms: Option<f64>,
    /// Active-set cap per lane in continuous mode (`--max-active`):
    /// how many requests may decode concurrently before admission
    /// queues (elastic budget steps shrink this cap first, before any
    /// shared-block eviction).  Requires `continuous`; >= 1.
    pub max_active: Option<usize>,
    /// Fault plan (`--fault-plan <file|json|spec>`): a deterministic
    /// schedule of injected failures; see [`crate::faults::FaultPlan`].
    pub fault_plan: Option<String>,
    /// Pass watchdog deadline in milliseconds (`--pass-timeout-ms`): a
    /// pass running past it is quiesced (gate shutdown) and failed through
    /// the ordinary error-recovery path.  None = no watchdog.
    pub pass_timeout_ms: Option<u64>,
    /// Transient shard-load failures tolerated per stage before the pass
    /// fails (`--load-retries`; bounded retry with deterministic backoff).
    pub load_retries: u32,
    /// Base backoff in milliseconds between load retries
    /// (`--retry-backoff-ms`; exponential with deterministic jitter).
    pub retry_backoff_ms: u64,
    /// Lane supervisor restart cap (`--max-lane-restarts`): contained lane
    /// deaths beyond this mark the lane dead and shed its requests.
    pub max_lane_restarts: u32,
}

impl RunConfig {
    /// Central config validation — every entrypoint (`run`, `serve`, the
    /// Router, the TCP front-end) funnels through [`Session::open`], which
    /// calls this, so bad configs are rejected with one message everywhere.
    ///
    /// [`Session::open`]: crate::engine::Session
    pub fn validate(&self, profile: &crate::model::Profile) -> Result<()> {
        self.validate_with_budget(profile, self.budget)
    }

    /// Like [`RunConfig::validate`], with the budget overridden — sessions
    /// opened against a shared accountant are constrained by *its* budget,
    /// not the per-config one.
    pub fn validate_with_budget(
        &self,
        profile: &crate::model::Profile,
        budget: Option<u64>,
    ) -> Result<()> {
        if self.kv_cache && self.mode == Mode::Baseline {
            anyhow::bail!(
                "--kv-cache needs a pipelined mode (the baseline keeps the \
                 whole model resident and has no per-token reload to save)"
            );
        }
        if self.kv_budget.is_some() && !self.kv_cache {
            anyhow::bail!("--kv-budget-mb only makes sense with --kv-cache");
        }
        match self.kv_block_tokens {
            Some(0) => anyhow::bail!("--kv-block-tokens must be >= 1 (got 0)"),
            Some(_) if !self.kv_cache => {
                anyhow::bail!("--kv-block-tokens only makes sense with --kv-cache")
            }
            _ => {}
        }
        if self.agents == 0 {
            anyhow::bail!("agents must be >= 1 (got 0)");
        }
        if self.continuous && self.mode == Mode::Baseline {
            anyhow::bail!(
                "--continuous needs a pipelined mode (the baseline has no \
                 token-boundary iterations for requests to join or leave)"
            );
        }
        match self.max_active {
            Some(0) => anyhow::bail!("--max-active must be >= 1 (got 0)"),
            Some(_) if !self.continuous => anyhow::bail!(
                "--max-active only makes sense with --continuous (the fixed-batch \
                 path sizes batches from the profile's AOT batch list)"
            ),
            _ => {}
        }
        if let Some(slo) = self.slo_ms {
            if !self.continuous {
                anyhow::bail!(
                    "--slo-ms requires --continuous serving mode (the fixed-batch \
                     path has no iteration-level scheduler to enforce a target)"
                );
            }
            if !slo.is_finite() || slo <= 0.0 {
                anyhow::bail!("--slo-ms must be a positive number of milliseconds (got {slo})");
            }
        }
        if let Some(0) = self.pass_timeout_ms {
            anyhow::bail!("--pass-timeout-ms must be >= 1 (got 0)");
        }
        if let Some(plan) = &self.fault_plan {
            // parse errors surface at config time, not mid-serve
            crate::faults::FaultPlan::from_arg(plan)?;
        }
        if self.prefetch_depth > 0 && self.mode != Mode::PipeLoad {
            anyhow::bail!(
                "--prefetch-depth needs pipeload mode (the other modes keep \
                 or preload the whole model; there is no next-pass load to hide)"
            );
        }
        if !profile.batches.contains(&self.batch) {
            anyhow::bail!(
                "batch {} is not AOT-compiled for profile '{}' (available: {:?})",
                self.batch,
                profile.name,
                profile.batches
            );
        }
        if let Some(b) = budget {
            let pin = self.pin_budget.unwrap_or(0);
            let kv = self.kv_budget.unwrap_or(0);
            if pin + kv > b {
                if kv > 0 {
                    anyhow::bail!(
                        "pin budget {pin} B + kv budget {kv} B exceed memory budget {b} B"
                    );
                }
                anyhow::bail!("pin budget {pin} B exceeds memory budget {b} B");
            }
        }
        Ok(())
    }
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            profile: "tiny-bert".into(),
            mode: Mode::PipeLoad,
            agents: 4,
            budget: None,
            pin_budget: None,
            pin_policy: PinPolicy::Fifo,
            disk: "edge-emmc".into(),
            batch: 1,
            seed: 42,
            trace: false,
            gen_tokens: None,
            kv_cache: false,
            kv_budget: None,
            kv_block_tokens: None,
            prefetch_depth: 0,
            device_cache: true,
            continuous: false,
            slo_ms: None,
            max_active: None,
            fault_plan: None,
            pass_timeout_ms: None,
            load_retries: 2,
            retry_backoff_ms: 1,
            max_lane_restarts: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        for m in [Mode::Baseline, Mode::PipeSwitch, Mode::PipeLoad] {
            assert_eq!(Mode::parse(m.name()).unwrap(), m);
        }
        assert!(Mode::parse("gpu").is_err());
    }

    #[test]
    fn paths_detect_contains_artifacts() {
        let p = Paths::detect();
        assert!(p.artifacts.ends_with("artifacts"));
        assert!(p.weights.ends_with("weights"));
    }

    #[test]
    fn default_config_sane() {
        let c = RunConfig::default();
        assert_eq!(c.mode, Mode::PipeLoad);
        assert!(c.agents >= 1);
        assert!(!c.kv_cache);
    }

    fn profile_with_batches(batches: Vec<usize>) -> crate::model::Profile {
        crate::model::Profile {
            name: "p".into(),
            family: "bert".into(),
            arch: "encoder".into(),
            paper_model: String::new(),
            hidden: 8,
            heads: 2,
            ffn: 16,
            layers: 2,
            decoder_layers: 0,
            vocab: 10,
            max_seq: 4,
            num_classes: 0,
            patch_dim: 0,
            prompt_tokens: 2,
            gen_tokens: 0,
            batches,
            stages: Vec::new(),
            kinds: Default::default(),
            entries: Default::default(),
            total_weight_bytes: 0,
        }
    }

    #[test]
    fn validate_rejects_bad_configs_with_one_message_each() {
        let p = profile_with_batches(vec![1, 4]);
        let ok = RunConfig { batch: 1, ..RunConfig::default() };
        assert!(ok.validate(&p).is_ok());

        // kv-cache is live now; only the baseline mode rejects it
        let kv = RunConfig { kv_cache: true, ..ok.clone() };
        assert!(kv.validate(&p).is_ok());
        let kv_baseline = RunConfig { kv_cache: true, mode: Mode::Baseline, ..ok.clone() };
        let e = kv_baseline.validate(&p).unwrap_err().to_string();
        assert!(e.contains("pipelined mode"), "{e}");
        let kv_budget_alone = RunConfig { kv_budget: Some(64), ..ok.clone() };
        let e = kv_budget_alone.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--kv-cache"), "{e}");

        // block tokens: >= 1, and only with the kv cache on
        let zero_blocks =
            RunConfig { kv_cache: true, kv_block_tokens: Some(0), ..ok.clone() };
        let e = zero_blocks.validate(&p).unwrap_err().to_string();
        assert!(e.contains(">= 1"), "{e}");
        let blocks_alone = RunConfig { kv_block_tokens: Some(4), ..ok.clone() };
        let e = blocks_alone.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--kv-cache"), "{e}");
        let blocks_ok = RunConfig { kv_cache: true, kv_block_tokens: Some(4), ..ok.clone() };
        assert!(blocks_ok.validate(&p).is_ok());

        let zero_agents = RunConfig { agents: 0, ..ok.clone() };
        assert!(zero_agents.validate(&p).unwrap_err().to_string().contains("agents"));

        // prefetch is a PIPELOAD-only overlap
        let prefetch_ok = RunConfig { prefetch_depth: 4, ..ok.clone() };
        assert!(prefetch_ok.validate(&p).is_ok());
        let prefetch_baseline =
            RunConfig { prefetch_depth: 4, mode: Mode::Baseline, ..ok.clone() };
        let e = prefetch_baseline.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--prefetch-depth"), "{e}");

        // continuous batching: pipelined modes only, knobs require it
        let cont_ok = RunConfig { continuous: true, ..ok.clone() };
        assert!(cont_ok.validate(&p).is_ok());
        let cont_baseline = RunConfig { continuous: true, mode: Mode::Baseline, ..ok.clone() };
        let e = cont_baseline.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--continuous"), "{e}");
        let zero_active =
            RunConfig { continuous: true, max_active: Some(0), ..ok.clone() };
        let e = zero_active.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--max-active") && e.contains(">= 1"), "{e}");
        let active_alone = RunConfig { max_active: Some(4), ..ok.clone() };
        let e = active_alone.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--continuous"), "{e}");
        let slo_alone = RunConfig { slo_ms: Some(50.0), ..ok.clone() };
        let e = slo_alone.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--slo-ms") && e.contains("--continuous"), "{e}");
        let slo_bad = RunConfig { continuous: true, slo_ms: Some(-1.0), ..ok.clone() };
        let e = slo_bad.validate(&p).unwrap_err().to_string();
        assert!(e.contains("positive"), "{e}");
        let cont_full = RunConfig {
            continuous: true,
            slo_ms: Some(250.0),
            max_active: Some(4),
            ..ok.clone()
        };
        assert!(cont_full.validate(&p).is_ok());

        // fault plane knobs
        let wd_zero = RunConfig { pass_timeout_ms: Some(0), ..ok.clone() };
        let e = wd_zero.validate(&p).unwrap_err().to_string();
        assert!(e.contains("--pass-timeout-ms"), "{e}");
        let bad_plan = RunConfig { fault_plan: Some("explode@1".into()), ..ok.clone() };
        assert!(bad_plan.validate(&p).is_err());
        let good_plan =
            RunConfig { fault_plan: Some("disk_error@2x2".into()), ..ok.clone() };
        assert!(good_plan.validate(&p).is_ok());

        let bad_batch = RunConfig { batch: 3, ..ok.clone() };
        let e = bad_batch.validate(&p).unwrap_err().to_string();
        assert!(e.contains("not AOT-compiled"), "{e}");

        let pin_over = RunConfig {
            budget: Some(100),
            pin_budget: Some(200),
            ..ok.clone()
        };
        assert!(pin_over.validate(&p).unwrap_err().to_string().contains("pin budget"));
        // shared-accountant budget overrides the per-config one
        assert!(pin_over.validate_with_budget(&p, Some(400)).is_ok());
        // unconstrained budget never rejects a pin budget
        let pin_unbounded = RunConfig { pin_budget: Some(200), ..ok.clone() };
        assert!(pin_unbounded.validate(&p).is_ok());

        // pin + kv must jointly fit the budget
        let pin_kv_over = RunConfig {
            budget: Some(300),
            pin_budget: Some(200),
            kv_cache: true,
            kv_budget: Some(150),
            ..ok.clone()
        };
        let e = pin_kv_over.validate(&p).unwrap_err().to_string();
        assert!(e.contains("kv budget"), "{e}");
        let pin_kv_fits = RunConfig { budget: Some(400), ..pin_kv_over };
        assert!(pin_kv_fits.validate(&p).is_ok());
    }

    #[test]
    fn pin_policy_parse_roundtrip() {
        for p in [PinPolicy::Fifo, PinPolicy::Cost] {
            assert_eq!(PinPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(PinPolicy::parse("lru").is_err());
    }
}
