//! Execution Engine (paper section IV-3): mode dispatch + decode loop.
//!
//! Owns the PJRT runtime and executes a [`RunConfig`] in one of the three
//! operational modes (Baseline / PipeSwitch-style standard pipeline /
//! PIPELOAD).  For generative models it reproduces the paper's semantics
//! exactly: pipelined modes perform **one full load+infer pass per
//! generated token** (weights were destroyed after the previous token),
//! while the Baseline loads once and runs one resident forward per token —
//! the source of the paper's Table II crossover where pipelines lose to
//! the baseline at low agent counts.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::baseline;
use crate::config::{Mode, Paths, RunConfig};
use crate::diskio::Disk;
use crate::memory::MemoryAccountant;
use crate::metrics::RunReport;
use crate::model::Profile;
use crate::pipeload::{run_pipeline, ExecCtx, ModelInput, PassStats, PipelineOpts};
use crate::runtime::Runtime;
use crate::trace::Tracer;
use crate::util::rng::Rng;
use crate::weights::gen::gen_profile_weights;

/// Seed used for synthetic weights (fixed: weights are infrastructure,
/// inputs vary with `RunConfig::seed`).
pub const WEIGHTS_SEED: u64 = 0xBEEF;

/// Output of a run, beyond the metrics.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// generated token ids (generative) or empty
    pub generated: Vec<i32>,
    /// final head output values (pooled vector / class logits / last-token
    /// logits), truncated to at most 16 values for reporting
    pub head_sample: Vec<f32>,
}

pub struct Engine {
    pub runtime: Runtime,
    pub paths: Paths,
}

impl Engine {
    pub fn new(paths: Paths) -> Result<Engine> {
        let runtime = Runtime::new(&paths.artifacts)?;
        Ok(Engine { runtime, paths })
    }

    pub fn with_default_paths() -> Result<Engine> {
        Engine::new(Paths::detect())
    }

    /// Make sure shards exist for a profile (generates them if missing).
    pub fn ensure_weights(&self, profile_name: &str) -> Result<u64> {
        let profile = self.runtime.profile(profile_name)?;
        gen_profile_weights(profile, &self.paths.weights, WEIGHTS_SEED, 0.05, false)
    }

    /// Run one configuration end to end; returns metrics + outputs.
    pub fn run(&self, cfg: &RunConfig) -> Result<(RunReport, RunOutput)> {
        self.run_with(cfg, &Tracer::new(cfg.trace))
    }

    /// Like [`Engine::run`] but records into a caller-supplied tracer
    /// (shared buffer), so callers can render Gantt charts / stall stats.
    pub fn run_with(&self, cfg: &RunConfig, tracer: &Tracer) -> Result<(RunReport, RunOutput)> {
        let profile = self.runtime.profile(&cfg.profile)?;
        if cfg.kv_cache {
            bail!("--kv-cache is an ablation extension; see benches/ablation.rs");
        }
        self.ensure_weights(&cfg.profile)?;
        let disk = Disk::preset(&cfg.disk)?;
        let mut ctx = ExecCtx::new(&self.runtime, &cfg.profile, &self.paths.weights, disk)?;
        ctx.tracer = tracer.clone();
        ctx.batch = cfg.batch;
        // compile off the measured path (the paper's pre-run)
        self.runtime.prepare(profile)?;

        let (input, mut ids, prompt_len) = make_input(profile, cfg.batch, cfg.seed);
        let gen_tokens = if profile.is_generative() {
            cfg.gen_tokens.unwrap_or(profile.gen_tokens.max(1))
        } else {
            0
        };

        let t0 = Instant::now();
        let mut passes: Vec<PassStats> = Vec::new();
        let mut generated = Vec::new();
        let mut head: Vec<f32> = Vec::new();

        match (cfg.mode, profile.is_generative()) {
            (Mode::Baseline, false) => {
                let accountant = MemoryAccountant::new(cfg.budget);
                let model = baseline::load_all(&ctx, &accountant)?;
                let (out, stats) = baseline::forward_resident(&ctx, &model, &accountant, &input)?;
                head = self.runtime.buffer_to_f32(&out)?;
                passes.push(stats);
            }
            (Mode::Baseline, true) => {
                let accountant = MemoryAccountant::new(cfg.budget);
                let model = baseline::load_all(&ctx, &accountant)?;
                let mut cur_len = prompt_len;
                for _ in 0..gen_tokens {
                    let inp = ModelInput::Ids(ids.clone());
                    let (out, stats) =
                        baseline::forward_resident(&ctx, &model, &accountant, &inp)?;
                    let logits = self.runtime.buffer_to_f32(&out)?;
                    let next = argmax_at(&logits, profile, cur_len);
                    push_token(&mut ids, profile, cur_len, next);
                    generated.push(next);
                    cur_len += 1;
                    head = last_logits(&logits, profile, cur_len - 1);
                    passes.push(stats);
                }
            }
            (mode, false) => {
                let opts = opts_for(mode, cfg.agents);
                let (out, stats) = run_pipeline(&ctx, &opts, cfg.budget, &input)?;
                head = self.runtime.buffer_to_f32(&out)?;
                passes.push(stats);
            }
            (mode, true) => {
                let opts = opts_for(mode, cfg.agents);
                let mut cur_len = prompt_len;
                for _ in 0..gen_tokens {
                    let inp = ModelInput::Ids(ids.clone());
                    // fresh pass: weights were destroyed after the last token
                    let (out, stats) = run_pipeline(&ctx, &opts, cfg.budget, &inp)?;
                    let logits = self.runtime.buffer_to_f32(&out)?;
                    let next = argmax_at(&logits, profile, cur_len);
                    push_token(&mut ids, profile, cur_len, next);
                    generated.push(next);
                    cur_len += 1;
                    head = last_logits(&logits, profile, cur_len - 1);
                    passes.push(stats);
                }
            }
        }
        let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;

        let report = RunReport {
            model: cfg.profile.clone(),
            mode: cfg.mode.name().to_string(),
            agents: if cfg.mode == Mode::PipeLoad { cfg.agents } else { 1 },
            latency_ms,
            peak_bytes: passes.iter().map(|p| p.peak_bytes).max().unwrap_or(0),
            mem_stall_ms: passes.iter().map(|p| p.mem_stall_ms).sum(),
            wait_stall_ms: passes.iter().map(|p| p.wait_stall_ms).sum(),
            idle_fraction: ctx.tracer.inference_idle_fraction().unwrap_or(0.0),
            tokens: generated.len(),
        };
        head.truncate(16);
        Ok((report, RunOutput { generated, head_sample: head }))
    }
}

fn opts_for(mode: Mode, agents: usize) -> PipelineOpts {
    match mode {
        Mode::PipeSwitch => PipelineOpts::pipeswitch(),
        Mode::PipeLoad => PipelineOpts::pipeload(agents),
        Mode::Baseline => unreachable!("baseline handled separately"),
    }
}

/// Build the synthetic model input.  Returns (input, ids, prompt_len).
pub fn make_input(profile: &Profile, batch: usize, seed: u64) -> (ModelInput, Vec<i32>, usize) {
    let mut rng = Rng::new(seed);
    if profile.family == "vit" {
        let n = batch * (profile.max_seq - 1) * profile.patch_dim;
        let patches: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        (ModelInput::Patches(patches.clone()), Vec::new(), 0)
    } else {
        let prompt = if profile.is_generative() { profile.prompt_tokens.max(1) } else { profile.max_seq };
        let mut ids = vec![0i32; batch * profile.max_seq];
        for b in 0..batch {
            for t in 0..prompt.min(profile.max_seq) {
                ids[b * profile.max_seq + t] = rng.range(1, profile.vocab as u64) as i32;
            }
        }
        (ModelInput::Ids(ids.clone()), ids, prompt)
    }
}

/// argmax over the vocab at position `pos-1` of batch row 0.
fn argmax_at(logits: &[f32], profile: &Profile, cur_len: usize) -> i32 {
    let v = profile.vocab;
    let pos = cur_len.saturating_sub(1).min(profile.max_seq - 1);
    let row = &logits[pos * v..(pos + 1) * v];
    let mut best = 0usize;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best as i32
}

fn last_logits(logits: &[f32], profile: &Profile, cur_len: usize) -> Vec<f32> {
    let v = profile.vocab;
    let pos = cur_len.saturating_sub(1).min(profile.max_seq - 1);
    logits[pos * v..(pos + 1) * v].to_vec()
}

/// Append a generated token at `cur_len` in every batch row.
fn push_token(ids: &mut [i32], profile: &Profile, cur_len: usize, token: i32) {
    let s = profile.max_seq;
    if cur_len >= s {
        return; // sequence full; decode loop will stop via gen_tokens bound
    }
    let batch = ids.len() / s;
    for b in 0..batch {
        ids[b * s + cur_len] = token;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_profile() -> Profile {
        // minimal profile for pure-function tests (no manifest needed)
        Profile {
            name: "x".into(),
            family: "gpt2".into(),
            arch: "decoder".into(),
            paper_model: String::new(),
            hidden: 8,
            heads: 2,
            ffn: 16,
            layers: 2,
            decoder_layers: 0,
            vocab: 10,
            max_seq: 4,
            num_classes: 0,
            patch_dim: 0,
            prompt_tokens: 2,
            gen_tokens: 2,
            batches: vec![1],
            stages: Vec::new(),
            kinds: Default::default(),
            entries: Default::default(),
            total_weight_bytes: 0,
        }
    }

    #[test]
    fn argmax_reads_correct_row() {
        let p = fake_profile();
        // seq 4 x vocab 10; put max at pos 1 (cur_len=2), index 7
        let mut logits = vec![0.0f32; 40];
        logits[1 * 10 + 7] = 5.0;
        assert_eq!(argmax_at(&logits, &p, 2), 7);
    }

    #[test]
    fn push_token_fills_all_batch_rows() {
        let p = fake_profile();
        let mut ids = vec![0i32; 8]; // batch 2 x seq 4
        push_token(&mut ids, &p, 2, 9);
        assert_eq!(ids[2], 9);
        assert_eq!(ids[6], 9);
        // out of range is a no-op
        push_token(&mut ids, &p, 4, 3);
    }

    #[test]
    fn make_input_prompt_layout() {
        let p = fake_profile();
        let (inp, ids, prompt) = make_input(&p, 1, 7);
        assert_eq!(prompt, 2);
        assert_eq!(ids.len(), 4);
        assert!(ids[0] > 0 && ids[1] > 0);
        assert_eq!(ids[2], 0);
        matches!(inp, ModelInput::Ids(_));
    }
}
