//! Execution Engine (paper section IV-3): mode dispatch + decode loop.
//!
//! Owns the PJRT runtime and executes a [`RunConfig`] in one of the three
//! operational modes (Baseline / PipeSwitch-style standard pipeline /
//! PIPELOAD).  For generative models it reproduces the paper's semantics
//! exactly: pipelined modes perform **one full load+infer pass per
//! generated token** (weights were destroyed after the previous token),
//! while the Baseline loads once and runs one resident forward per token —
//! the source of the paper's Table II crossover where pipelines lose to
//! the baseline at low agent counts.
//!
//! # Sessions & hot-layer cache
//!
//! [`Engine::run`] is one-shot sugar over the [`session`] subsystem:
//! it opens a [`Session`] (profile resolution + weight validation +
//! [`Runtime::prepare`], each exactly once), runs one request, and drops
//! it.  Long-lived callers — the serving loop ([`crate::server::serve`])
//! and anything issuing repeated requests — keep the session instead and
//! call [`Session::run_batch`] per request, amortizing setup and letting
//! the hot-layer cache (`RunConfig::pin_budget`) keep layers resident
//! across decode tokens whenever the memory budget has slack.
//!
//! [`Runtime::prepare`]: crate::runtime::Runtime::prepare
//! [`Session::run_batch`]: session::Session::run_batch
//! [`Session`]: session::Session

pub mod session;

pub use session::{DecodeState, MemComponents, Session, SessionBuilder};

use anyhow::Result;

use crate::config::{Paths, RunConfig};
use crate::metrics::RunReport;
use crate::model::Profile;
use crate::pipeload::ModelInput;
use crate::runtime::Runtime;
use crate::trace::Tracer;
use crate::util::rng::Rng;
use crate::weights::gen::gen_profile_weights;

/// Seed used for synthetic weights (fixed: weights are infrastructure,
/// inputs vary with `RunConfig::seed`).
pub const WEIGHTS_SEED: u64 = 0xBEEF;

/// Output of a run, beyond the metrics.
#[derive(Debug, Clone)]
pub struct RunOutput {
    /// generated token ids of batch row 0 (generative) or empty —
    /// kept for callers that predate [`RunOutput::generated_rows`]
    pub generated: Vec<i32>,
    /// generated token ids per batch row (generative profiles; empty
    /// otherwise).  Row 0 equals [`RunOutput::generated`].
    pub generated_rows: Vec<Vec<i32>>,
    /// final head output values (pooled vector / class logits / last-token
    /// logits), truncated to at most 16 values for reporting
    pub head_sample: Vec<f32>,
}

pub struct Engine {
    pub runtime: Runtime,
    pub paths: Paths,
}

impl Engine {
    pub fn new(paths: Paths) -> Result<Engine> {
        let runtime = Runtime::new(&paths.artifacts)?;
        Ok(Engine { runtime, paths })
    }

    pub fn with_default_paths() -> Result<Engine> {
        Engine::new(Paths::detect())
    }

    /// Make sure shards exist for a profile (generates them if missing).
    pub fn ensure_weights(&self, profile_name: &str) -> Result<u64> {
        let profile = self.runtime.profile(profile_name)?;
        gen_profile_weights(profile, &self.paths.weights, WEIGHTS_SEED, 0.05, false)
    }

    /// Run one configuration end to end; returns metrics + outputs.
    pub fn run(&self, cfg: &RunConfig) -> Result<(RunReport, RunOutput)> {
        self.run_with(cfg, &Tracer::new(cfg.trace))
    }

    /// Like [`Engine::run`] but records into a caller-supplied tracer
    /// (shared buffer), so callers can render Gantt charts / stall stats.
    /// One-shot: opens a [`Session`], runs one request, drops it.
    pub fn run_with(&self, cfg: &RunConfig, tracer: &Tracer) -> Result<(RunReport, RunOutput)> {
        let mut session = self.open_session_with(cfg, tracer)?;
        session.run()
    }
}

/// Build the synthetic model input.  Returns (input, ids, prompt_len).
pub fn make_input(profile: &Profile, batch: usize, seed: u64) -> (ModelInput, Vec<i32>, usize) {
    let mut rng = Rng::new(seed);
    if profile.family == "vit" {
        let n = batch * (profile.max_seq - 1) * profile.patch_dim;
        let patches: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
        (ModelInput::Patches(patches.clone()), Vec::new(), 0)
    } else {
        let prompt = if profile.is_generative() { profile.prompt_tokens.max(1) } else { profile.max_seq };
        let mut ids = vec![0i32; batch * profile.max_seq];
        for b in 0..batch {
            for t in 0..prompt.min(profile.max_seq) {
                ids[b * profile.max_seq + t] = rng.range(1, profile.vocab as u64) as i32;
            }
        }
        (ModelInput::Ids(ids.clone()), ids, prompt)
    }
}

/// First-max argmax over one row of logits.  BOTH decode paths (full and
/// incremental) funnel through this, so their tie-breaking can never
/// diverge — a divergence would break the bit-identical token contract.
fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best as i32
}

/// Per-row argmax over the vocab at position `cur_len - 1`: one next-token
/// id for every batch row.  Logits are `[batch, max_seq, vocab]` flattened.
pub(crate) fn argmax_rows(
    logits: &[f32],
    profile: &Profile,
    batch: usize,
    cur_len: usize,
) -> Vec<i32> {
    let v = profile.vocab;
    let s = profile.max_seq;
    let pos = cur_len.saturating_sub(1).min(s - 1);
    (0..batch)
        .map(|b| argmax(&logits[b * s * v + pos * v..b * s * v + (pos + 1) * v]))
        .collect()
}

/// Per-row argmax over single-position logits `[batch, 1, vocab]` (the
/// incremental decode entries' output — no position indexing needed).
pub(crate) fn argmax_rows_flat(logits: &[f32], vocab: usize, batch: usize) -> Vec<i32> {
    (0..batch).map(|b| argmax(&logits[b * vocab..(b + 1) * vocab])).collect()
}

pub(crate) fn last_logits(logits: &[f32], profile: &Profile, cur_len: usize) -> Vec<f32> {
    let v = profile.vocab;
    let pos = cur_len.saturating_sub(1).min(profile.max_seq - 1);
    logits[pos * v..(pos + 1) * v].to_vec()
}

/// Append each batch row's own generated token at `cur_len`.  (A single
/// shared token here would silently collapse batch>1 decoding onto row 0's
/// continuation — every row must follow its own argmax.)
pub(crate) fn push_tokens(ids: &mut [i32], profile: &Profile, cur_len: usize, tokens: &[i32]) {
    let s = profile.max_seq;
    if cur_len >= s {
        return; // sequence full; decode loop will stop via gen_tokens bound
    }
    let batch = ids.len() / s;
    debug_assert_eq!(batch, tokens.len(), "one token per batch row");
    for b in 0..batch {
        ids[b * s + cur_len] = tokens[b];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_profile() -> Profile {
        // minimal profile for pure-function tests (no manifest needed)
        Profile {
            name: "x".into(),
            family: "gpt2".into(),
            arch: "decoder".into(),
            paper_model: String::new(),
            hidden: 8,
            heads: 2,
            ffn: 16,
            layers: 2,
            decoder_layers: 0,
            vocab: 10,
            max_seq: 4,
            num_classes: 0,
            patch_dim: 0,
            prompt_tokens: 2,
            gen_tokens: 2,
            batches: vec![1],
            stages: Vec::new(),
            kinds: Default::default(),
            entries: Default::default(),
            total_weight_bytes: 0,
        }
    }

    #[test]
    fn argmax_reads_correct_row_per_batch() {
        let p = fake_profile();
        // batch 2 x seq 4 x vocab 10; at pos 1 (cur_len=2) put the max at
        // index 7 for row 0 and index 3 for row 1
        let mut logits = vec![0.0f32; 80];
        logits[10 + 7] = 5.0; // row 0, pos 1
        logits[40 + 10 + 3] = 5.0; // row 1, pos 1
        assert_eq!(argmax_rows(&logits, &p, 2, 2), vec![7, 3]);
        assert_eq!(argmax_rows(&logits, &p, 1, 2), vec![7]);
    }

    #[test]
    fn push_tokens_writes_each_row_its_own_token() {
        let p = fake_profile();
        let mut ids = vec![0i32; 8]; // batch 2 x seq 4
        push_tokens(&mut ids, &p, 2, &[9, 5]);
        assert_eq!(ids[2], 9, "row 0 gets its own argmax");
        assert_eq!(ids[6], 5, "row 1 must NOT inherit row 0's token");
        // out of range is a no-op
        push_tokens(&mut ids, &p, 4, &[3, 3]);
        assert_eq!(&ids, &[0, 0, 9, 0, 0, 0, 5, 0]);
    }

    #[test]
    fn argmax_rows_flat_reads_per_row() {
        // batch 2 x vocab 5
        let mut logits = vec![0.0f32; 10];
        logits[3] = 2.0; // row 0 -> 3
        logits[5 + 1] = 2.0; // row 1 -> 1
        assert_eq!(argmax_rows_flat(&logits, 5, 2), vec![3, 1]);
    }

    #[test]
    fn make_input_prompt_layout() {
        let p = fake_profile();
        let (inp, ids, prompt) = make_input(&p, 1, 7);
        assert_eq!(prompt, 2);
        assert_eq!(ids.len(), 4);
        assert!(ids[0] > 0 && ids[1] > 0);
        assert_eq!(ids[2], 0);
        matches!(inp, ModelInput::Ids(_));
    }
}
