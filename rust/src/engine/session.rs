//! Reusable inference sessions: setup once, many passes.
//!
//! [`Engine::run`] reproduces the paper's per-run semantics — resolve the
//! profile, validate weights, AOT-prepare, build channels/threads, run one
//! request.  A serving loop doing that per batch (and a decode loop doing
//! it per token) pays the setup tax on every hot-path iteration.
//!
//! A [`Session`] hoists everything that survives a pass out of the loop:
//!
//! * profile resolution + weight generation/validation + [`Runtime::prepare`]
//!   run **exactly once** at [`Engine::open_session`];
//! * the [`MemoryAccountant`] persists, so the budget (and any pinned
//!   hot layers) carries across passes;
//! * the [`OrderedGate`] is rearmed with `begin_pass` (one admission
//!   epoch per pass) instead of rebuilt;
//! * the stage-to-agent [`assignment`] is precomputed;
//! * the Loading Agents and the Daemon are **persistent threads** in a
//!   [`WorkerPool`], fed per-pass work descriptors — a multi-token decode
//!   no longer spawns and joins m+1 threads per token;
//! * with `prefetch_depth > 0` idle loaders speculatively load the next
//!   decode pass's head stages ([`PrefetchBuffer`]), and with the
//!   device cache on, hot stages keep their weight `PjRtBuffer`s alive
//!   and skip the host→device re-upload ([`DeviceCache`]);
//! * an optional hot-layer [`LayerCache`] (`RunConfig::pin_budget`) lets
//!   the Daemon pin computed layers instead of destroying them, so the
//!   next decode token / serve batch skips disk for pinned stages;
//! * an optional paged [`KvPool`] (`RunConfig::kv_cache` /
//!   `RunConfig::kv_budget`) holds attention state for GPT-style decode:
//!   [`Session::run_batch`] then runs ONE full-prefix pass (priming a
//!   [`KvSeq`] via the `*_kv` entries) and incremental single-token
//!   passes for the rest, falling back to full-prefix recompute whenever
//!   blocks are denied or evicted — tokens never depend on residency.
//!
//! The pin budget is capped at `budget - max_stage_bytes` so a stalled
//! admission can always make progress: pinned-but-in-flight stages later
//! in the admission order are not evictable, so at least one unpinned
//! stage must always fit beside them (liveness; see `pipeload::gate`).
//!
//! # Elastic budgets
//!
//! A session opened with a memory-pressure trace
//! ([`SessionBuilder::memory_trace`], `--memory-trace`) re-reads its
//! budget between passes: each due [`crate::elastic::PressureStep`]
//! resizes the accountant, drives the eviction chain (pins, then KV
//! sequences) until `used` fits again, re-derives the pin/KV caps under
//! the `budget - max_stage` liveness rule, and — when a planner
//! [`Schedule`] is attached ([`SessionBuilder::schedule`]) — re-consults
//! [`Schedule::pick`] for the Loading Agent count (epoch re-planning).
//! Tokens stay bit-identical to a static-budget run: a shrink only evicts
//! state that every consumer can rebuild (pins reload, KV recomputes),
//! and a grow only widens headroom.
//!
//! # Shared accountants (multi-model serving)
//!
//! By default a session creates its own [`MemoryAccountant`] from
//! `RunConfig::budget`.  [`Engine::open_session_shared`] (or
//! [`SessionBuilder::accountant`]) opens the session against a
//! caller-supplied accountant instead, so N sessions — one per model
//! profile — contend for a single device-wide budget; the shared budget
//! outranks `RunConfig::budget`.  [`Session::add_eviction_victim`] lets one
//! session's `S^stop` pressure reclaim another session's pinned hot layers
//! (the [`crate::server::Router`] wires every pair).  Config validation is
//! centralized here through [`RunConfig::validate`], so every entrypoint
//! rejects bad configs with the same message.
//!
//! [`Runtime::prepare`]: crate::runtime::Runtime::prepare
//! [`assignment`]: crate::pipeload::assignment

use std::time::{Duration, Instant};

use anyhow::Result;

use super::{argmax_rows, argmax_rows_flat, last_logits, make_input, push_tokens, Engine, RunOutput};
use crate::baseline;
use crate::baseline::ResidentModel;
use crate::config::{Mode, RunConfig};
use crate::diskio::Disk;
use crate::elastic::{BudgetController, BudgetEpoch, ElasticStats, PressureTrace};
use crate::faults::{FaultInjector, FaultStatsSnapshot, RetryPolicy, Watchdog};
use crate::kvcache::{KvPool, KvPoolStats, KvSeq, DEFAULT_BLOCK_TOKENS};
use crate::memory::MemoryAccountant;
use crate::metrics::{LatencyRecorder, RunReport};
use crate::model::Profile;
use crate::pipeload::assignment::assignment;
use crate::pipeload::cache::{CacheStats, LayerCache};
use crate::pipeload::device::{DeviceCache, DeviceLedger, DeviceStats};
use crate::pipeload::gate::{OrderedGate, ReclaimToken};
use crate::pipeload::pool::{PoolStats, TaskGroup, WorkerPool};
use crate::pipeload::prefetch::{PrefetchBuffer, PrefetchStats};
use crate::pipeload::{
    run_pass_mode, ExecCtx, ModelInput, PassEnv, PassMode, PassStats, PipelineOpts,
    KV_EVICTED_MIDPASS,
};
use crate::planner::Schedule;
use crate::telemetry::{worker, EvArgs, Telemetry};
use crate::trace::Tracer;

/// One settled sample of where every accounted byte lives: durable
/// stores (pins, device copies, parked prefetch shards, KV blocks, the
/// baseline-resident model) plus the pass ledger's live balance.  At a
/// quiesced point their sum equals [`MemoryAccountant::used`] exactly —
/// the invariant the `mem_audit` telemetry event records and
/// `hermes analyze` re-checks offline.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemComponents {
    pub pins: u64,
    pub device: u64,
    pub prefetch: u64,
    pub kv: u64,
    pub live: u64,
    pub resident: u64,
}

impl MemComponents {
    pub fn total(&self) -> u64 {
        self.pins + self.device + self.prefetch + self.kv + self.live + self.resident
    }
}

/// Long-lived pipeline state for one (profile, mode, budget) configuration.
/// Obtained from [`Engine::open_session`]; run requests with
/// [`Session::run`] / [`Session::run_batch`].
pub struct Session<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    ctx: ExecCtx<'e>,
    /// None for Baseline (non-pipelined) mode
    opts: Option<PipelineOpts>,
    accountant: MemoryAccountant,
    /// false when the accountant was supplied by the caller (shared across
    /// sessions, e.g. by a [`crate::server::Router`]) — error recovery must
    /// then release only this session's bytes, never reset wholesale.
    owns_accountant: bool,
    gate: OrderedGate,
    plan: Vec<Vec<usize>>,
    cache: Option<LayerCache>,
    /// persistent Loading Agent + Daemon threads (pipelined modes only):
    /// passes dispatch work descriptors instead of spawning m+1 threads
    pool: Option<WorkerPool>,
    /// cross-pass prefetch buffer (`prefetch_depth` > 0, PIPELOAD only)
    prefetch: Option<PrefetchBuffer>,
    /// in-flight speculative loads; error recovery waits this out before
    /// reasoning about accounting
    prefetch_group: TaskGroup,
    /// device-resident layer cache (inference-side; the Send ledger half
    /// rides the gate's eviction chain)
    device: Option<DeviceCache>,
    /// monotonic admission epoch; one per attempted pass
    pass_epoch: u64,
    /// true when the caller knows more requests follow this one (a serving
    /// queue with depth): the LAST pass of a request then still prefetches
    /// the next request's head stages across the `run_batch` boundary
    expect_more: bool,
    /// Paged KV pool (Some when `kv_cache` is on and the profile ships the
    /// incremental decode entries); blocks charge the session accountant.
    kv_pool: Option<KvPool>,
    /// Baseline mode: the whole model, loaded on first use
    resident: Option<ResidentModel>,
    prepared_entries: usize,
    passes_run: usize,
    /// decode tokens served by incremental passes (cache hits)
    kv_inc_total: u64,
    /// decode tokens that fell back to full-prefix recompute after the
    /// cache was primed (eviction or exhausted KV budget)
    kv_recompute_total: u64,
    /// planner schedule consulted on elastic budget steps (epoch
    /// re-planning: the agent count follows the current constraint)
    schedule: Option<Schedule>,
    /// elastic controller walking a memory-pressure trace between passes
    elastic: Option<BudgetController>,
    /// one record per applied budget step
    epochs: Vec<BudgetEpoch>,
    elastic_totals: ElasticStats,
    /// structured event bus (off by default: every emit site is behind one
    /// relaxed atomic load, so an untraced run pays ~nothing)
    telemetry: Telemetry,
    /// fault probes + recovery counters (off by default; [`Session::set_faults`])
    faults: FaultInjector,
    /// per-pass hang monitor, present when `cfg.pass_timeout_ms` is set
    watchdog: Option<Watchdog>,
}

/// Options for opening a [`Session`] — sugar methods on [`Engine`] cover
/// the common cases ([`Engine::open_session`],
/// [`Engine::open_session_shared`]); the builder composes them.
///
/// ```ignore
/// let shared = MemoryAccountant::new(Some(budget));
/// let mut s = engine.session(&cfg).accountant(&shared).tracer(&t).open()?;
/// ```
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    tracer: Tracer,
    accountant: Option<MemoryAccountant>,
    schedule: Option<Schedule>,
    memory_trace: Option<PressureTrace>,
}

impl<'e> SessionBuilder<'e> {
    /// Record spans into a caller-supplied tracer (shared buffer), so the
    /// caller can render Gantt charts / stall stats afterwards.
    pub fn tracer(mut self, tracer: &Tracer) -> SessionBuilder<'e> {
        self.tracer = tracer.clone();
        self
    }

    /// Consult this planner schedule on every elastic budget step (epoch
    /// re-planning): `Schedule::pick(new_budget)` decides the Loading
    /// Agent count for the epoch.  Without a schedule, budget steps still
    /// resize/reclaim/re-cap but never change the agent count.
    pub fn schedule(mut self, schedule: Schedule) -> SessionBuilder<'e> {
        self.schedule = Some(schedule);
        self
    }

    /// React to this memory-pressure trace: between passes the session
    /// applies every due budget step (see [`crate::elastic`]).  Only
    /// meaningful for sessions that own their accountant — shared-budget
    /// fleets are resized by the [`crate::server::Router`] instead.
    pub fn memory_trace(mut self, trace: PressureTrace) -> SessionBuilder<'e> {
        self.memory_trace = Some(trace);
        self
    }

    /// Account this session's memory into a caller-supplied accountant
    /// instead of a private one.  The accountant's budget (not
    /// `RunConfig::budget`) constrains the session, so N sessions opened
    /// against the same accountant contend for one device-wide budget.
    pub fn accountant(mut self, accountant: &MemoryAccountant) -> SessionBuilder<'e> {
        self.accountant = Some(accountant.clone());
        self
    }

    pub fn open(self) -> Result<Session<'e>> {
        let mut session =
            Session::open(self.engine, &self.cfg, &self.tracer, self.accountant)?;
        session.schedule = self.schedule;
        session.elastic = self.memory_trace.map(BudgetController::new);
        Ok(session)
    }
}

/// One request's in-flight decode: everything [`Session::run_batch`] used
/// to keep in locals, reified so serving loops can hold MANY of these open
/// against one session and interleave their token steps (continuous
/// batching).  Dropping a state releases its KV blocks.
///
/// Obtained from [`Session::begin_decode`]; advanced one iteration at a
/// time by [`Session::decode_step`]; closed by [`Session::finish_decode`].
pub struct DecodeState {
    batch: usize,
    input: ModelInput,
    ids: Vec<i32>,
    cur_len: usize,
    step: usize,
    /// generative: tokens to produce; non-generative: the single pass
    total_steps: usize,
    generative: bool,
    kv_enabled: bool,
    n_body: usize,
    kv_seq: Option<KvSeq>,
    last_next: Vec<i32>,
    generated: Vec<i32>,
    generated_rows: Vec<Vec<i32>>,
    head: Vec<f32>,
    passes: Vec<PassStats>,
    kv_inc: u64,
    kv_rec: u64,
    /// per-token decode latency distribution (generative runs)
    token_lat: LatencyRecorder,
    t0: Instant,
    // counter baselines, so the per-request report stays delta-based even
    // when other requests advance the session's totals between our steps
    kv_evicted0: u64,
    kv_shared0: u64,
    kv_dedup0: u64,
    elastic0: ElasticStats,
    prefetch0: PrefetchStats,
    spawns_avoided0: u64,
    faults0: FaultStatsSnapshot,
}

impl DecodeState {
    /// All iterations run — harvest with [`Session::finish_decode`].
    pub fn done(&self) -> bool {
        self.step >= self.total_steps
    }

    /// The next [`Session::decode_step`] is this request's last.
    pub fn last_step(&self) -> bool {
        self.step + 1 >= self.total_steps
    }

    /// Iterations completed so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Tokens produced so far (0 for non-generative forwards).
    pub fn tokens_generated(&self) -> usize {
        self.generated.len()
    }

    /// The batch size this request decodes at.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

impl Engine {
    /// Start building a session; finish with [`SessionBuilder::open`].
    pub fn session(&self, cfg: &RunConfig) -> SessionBuilder<'_> {
        SessionBuilder {
            engine: self,
            cfg: cfg.clone(),
            tracer: Tracer::new(cfg.trace),
            accountant: None,
            schedule: None,
            memory_trace: None,
        }
    }

    /// Open a reusable session: profile resolution, weight generation, and
    /// AOT prepare happen here, once, instead of per run.
    pub fn open_session(&self, cfg: &RunConfig) -> Result<Session<'_>> {
        self.session(cfg).open()
    }

    /// Like [`Engine::open_session`] but records into a caller-supplied
    /// tracer (shared buffer), so callers can render Gantt charts.
    pub fn open_session_with(&self, cfg: &RunConfig, tracer: &Tracer) -> Result<Session<'_>> {
        self.session(cfg).tracer(tracer).open()
    }

    /// Open a session against a **shared** accountant: the session's loads
    /// and pins are admitted under `accountant`'s budget, alongside every
    /// other session opened against it.  `cfg.budget` is ignored (the
    /// shared budget outranks it).  This is the multi-model serving
    /// primitive: one `Session` per profile, one global budget.
    pub fn open_session_shared(
        &self,
        cfg: &RunConfig,
        accountant: &MemoryAccountant,
    ) -> Result<Session<'_>> {
        self.session(cfg).accountant(accountant).open()
    }
}

impl<'e> Session<'e> {
    fn open(
        engine: &'e Engine,
        cfg: &RunConfig,
        tracer: &Tracer,
        shared: Option<MemoryAccountant>,
    ) -> Result<Session<'e>> {
        let profile = engine.runtime.profile(&cfg.profile)?;
        // Central validation: every entrypoint (run / serve / Router / TCP)
        // opens a session, so every entrypoint rejects bad configs with the
        // same message.  A shared accountant's budget is the binding one.
        let budget = match &shared {
            Some(a) => a.budget(),
            None => cfg.budget,
        };
        cfg.validate_with_budget(profile, budget)?;
        engine.ensure_weights(&cfg.profile)?;
        let disk = Disk::preset(&cfg.disk)?;
        let mut ctx = ExecCtx::new(&engine.runtime, &cfg.profile, &engine.paths.weights, disk)?;
        ctx.tracer = tracer.clone();
        ctx.batch = cfg.batch;
        ctx.retry = RetryPolicy {
            max_retries: cfg.load_retries,
            base_backoff_ms: cfg.retry_backoff_ms.max(1),
            seed: 0,
        };
        // compile off the measured path (the paper's pre-run) — once
        let prepared_entries = engine.runtime.prepare(profile)?;

        let opts = match cfg.mode {
            Mode::Baseline => None,
            Mode::PipeSwitch => Some(PipelineOpts::pipeswitch()),
            Mode::PipeLoad => Some(PipelineOpts::pipeload(cfg.agents)),
        };
        let owns_accountant = shared.is_none();
        let accountant = shared.unwrap_or_else(|| MemoryAccountant::new(cfg.budget));
        let cache = Self::build_cache(cfg, profile, budget);
        let mut gate = match &cache {
            Some(c) => OrderedGate::with_cache(accountant.clone(), c.clone()),
            None => OrderedGate::new(accountant.clone()),
        };
        let kv_pool = Self::build_kv_pool(cfg, profile, &accountant);
        if let Some(pool) = &kv_pool {
            // this session's own weight admissions may reclaim its KV
            // blocks under S^stop pressure (after pinned layers)
            gate.add_kv_pool(pool.clone());
        }
        // cross-pass prefetch + device-resident cache (PIPELOAD only)
        let prefetch = (cfg.mode == Mode::PipeLoad && cfg.prefetch_depth > 0)
            .then(PrefetchBuffer::new);
        if let Some(buffer) = &prefetch {
            gate.set_prefetch(buffer.clone());
        }
        let pin_cap = cache.as_ref().map(|c| c.pin_budget()).unwrap_or(0);
        let device_cap = Self::device_cap(cfg, profile, budget, pin_cap);
        let device = (device_cap > 0).then(|| DeviceCache::new(device_cap));
        if let Some(d) = &device {
            gate.set_device(d.ledger().clone());
        }
        let agents = opts.as_ref().map(|o| o.agents.max(1)).unwrap_or(1);
        let plan = assignment(profile.stages.len(), agents);
        // the persistent worker pool: Loading Agents + Daemon spawned once
        // here, fed per-pass descriptors for the life of the session
        let pool = opts.as_ref().map(|_| WorkerPool::new(agents));
        Ok(Session {
            engine,
            cfg: cfg.clone(),
            ctx,
            opts,
            accountant,
            owns_accountant,
            gate,
            plan,
            cache,
            pool,
            prefetch,
            prefetch_group: TaskGroup::new(),
            device,
            pass_epoch: 0,
            expect_more: false,
            kv_pool,
            resident: None,
            prepared_entries,
            passes_run: 0,
            kv_inc_total: 0,
            kv_recompute_total: 0,
            schedule: None,
            elastic: None,
            epochs: Vec::new(),
            elastic_totals: ElasticStats::default(),
            telemetry: Telemetry::off(),
            faults: FaultInjector::off(),
            watchdog: cfg.pass_timeout_ms.map(|_| Watchdog::new()),
        })
    }

    /// Attach a telemetry bus (lane-tagged by the serving layer): the
    /// session emits `pass` spans, per-pass memory high-water counters,
    /// and `budget_epoch` instants, and threads the bus into the pass
    /// machinery (stage load/compute/stall/prefetch/evict spans) and the
    /// KV pool (dedup/COW instants).  Call before cloning gate/pool
    /// handles for cross-lane wiring so every consumer sees the bus.
    pub fn set_telemetry(&mut self, t: Telemetry) {
        self.ctx.telemetry = t.clone();
        self.gate.set_telemetry(t.clone());
        if let Some(p) = &self.kv_pool {
            p.set_telemetry(t.clone());
        }
        self.faults.set_telemetry(t.clone());
        self.telemetry = t;
    }

    /// Attach a fault injector: probes thread through the disk stream, the
    /// loading agents, and (for sessions that own their accountant) the
    /// memory admissions.  Shared-accountant fleets arm the accountant once
    /// at the router instead, so lane-scoped probes stay unambiguous.
    /// Call after [`Session::set_telemetry`] or before — either order wires
    /// fired faults to the session's bus.
    pub fn set_faults(&mut self, f: FaultInjector) {
        f.set_telemetry(self.telemetry.clone());
        if let Some(seed) = f.plan_seed() {
            self.ctx.retry.seed = seed;
        }
        self.ctx.faults = f.clone();
        self.ctx.disk.set_faults(f.clone());
        if self.owns_accountant {
            self.accountant.set_faults(f.clone());
        }
        self.faults = f;
    }

    /// This session's fault injector (probe/stat handle).
    pub fn faults(&self) -> &FaultInjector {
        &self.faults
    }

    /// One coherent read of the fault/recovery counters.
    pub fn fault_stats(&self) -> FaultStatsSnapshot {
        self.faults.snapshot()
    }

    /// Paged KV pool construction: only when the extension is on, the mode
    /// is pipelined, and the profile's artifacts ship the incremental
    /// decode entries (GPT-style families; BART/encoder profiles fall
    /// back to full-prefix decode even with `--kv-cache`).
    fn build_kv_pool(
        cfg: &RunConfig,
        profile: &Profile,
        accountant: &MemoryAccountant,
    ) -> Option<KvPool> {
        if !cfg.kv_cache || cfg.mode == Mode::Baseline || !profile.is_generative() {
            return None;
        }
        let body_inc = format!("{}_inc@", profile.body_kind());
        if !profile.entries.keys().any(|k| k.starts_with(&body_inc)) {
            return None;
        }
        Some(KvPool::with_block_tokens(
            accountant.clone(),
            cfg.kv_budget,
            cfg.kv_block_tokens.unwrap_or(DEFAULT_BLOCK_TOKENS),
        ))
    }

    /// Hot-layer cache sizing.  Only PIPELOAD destroys layers, so only it
    /// can pin; the pin budget is clipped below `budget - max_stage` so an
    /// unpinned admission always fits beside in-flight pinned stages.
    /// The cache is built whenever a pin budget was *asked for* — even if
    /// the current clip leaves it at 0 bytes — so an elastic budget grow
    /// can re-raise the cap on a live session.
    fn build_cache(cfg: &RunConfig, profile: &Profile, budget: Option<u64>) -> Option<LayerCache> {
        if cfg.mode != Mode::PipeLoad || cfg.pin_budget.unwrap_or(0) == 0 {
            return None;
        }
        let mut pin = cfg.pin_budget.unwrap_or(0);
        if let Some(budget) = budget {
            pin = pin.min(budget.saturating_sub(profile.max_stage_bytes()));
        }
        Some(LayerCache::with_policy(pin, cfg.pin_policy))
    }

    /// Device-resident cache sizing.  Device copies coexist with the host
    /// pins they mirror, so their cap comes out of the slack the budget
    /// has *beyond* the pin cap and the `max_stage` liveness headroom —
    /// `pin_cap + device_cap + max_stage <= budget` keeps the joint
    /// residency inside the same liveness rule the pin cap obeys alone.
    /// Unconstrained budgets mirror the configured pin budget.
    fn device_cap(cfg: &RunConfig, profile: &Profile, budget: Option<u64>, pin_cap: u64) -> u64 {
        if cfg.mode != Mode::PipeLoad || !cfg.device_cache {
            return 0;
        }
        let pin_cfg = cfg.pin_budget.unwrap_or(0);
        if pin_cfg == 0 {
            return 0;
        }
        match budget {
            None => pin_cfg,
            Some(b) => pin_cfg.min(b.saturating_sub(pin_cap + profile.max_stage_bytes())),
        }
    }

    pub fn profile(&self) -> &Profile {
        self.ctx.profile
    }

    /// Entries compiled by the session's single prepare call.
    pub fn prepared_entries(&self) -> usize {
        self.prepared_entries
    }

    /// Pipeline passes executed so far (tokens count individually).
    pub fn passes_run(&self) -> usize {
        self.passes_run
    }

    /// Hot-layer cache counters (zeros when no cache is attached).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// The accountant this session admits memory through (shared when the
    /// session was opened via [`Engine::open_session_shared`]).
    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// The session's hot-layer cache handle, if one is attached.
    pub fn layer_cache(&self) -> Option<&LayerCache> {
        self.cache.as_ref()
    }

    /// The configuration this session was opened with.
    pub fn run_config(&self) -> &RunConfig {
        &self.cfg
    }

    /// The session's paged KV pool, if the KV-cache extension is active.
    pub fn kv_pool(&self) -> Option<&KvPool> {
        self.kv_pool.as_ref()
    }

    /// KV pool counters (zeros when no pool is attached).
    pub fn kv_pool_stats(&self) -> KvPoolStats {
        self.kv_pool.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// Cumulative (incremental passes, full-prefix recomputes) across this
    /// session's decode loops — the `Runtime::prepare_calls`-style counters
    /// tests assert pass-shape with.
    pub fn kv_counters(&self) -> (u64, u64) {
        (self.kv_inc_total, self.kv_recompute_total)
    }

    /// Register another session's hot-layer cache as an eviction target:
    /// when an admission here stalls on the (shared) budget, it reclaims
    /// that session's pins after its own.  Only meaningful — and only
    /// sound — between sessions opened against the same shared accountant.
    pub fn add_eviction_victim(&mut self, cache: LayerCache) {
        self.gate.add_victim(cache);
    }

    /// Cross-pass prefetch counters (zeros when prefetch is off).
    pub fn prefetch_stats(&self) -> PrefetchStats {
        self.prefetch.as_ref().map(|b| b.stats()).unwrap_or_default()
    }

    /// Block until this session's speculative loads have settled.  Between
    /// passes this is ~free; the serving layer calls it before sampling
    /// memory attribution so no in-flight prefetch straddles the
    /// buffer/ledger hand-off mid-sample.
    pub fn quiesce_speculative(&self) {
        self.prefetch_group.wait_idle();
    }

    /// One settled sample of where every accounted byte lives.  Only
    /// meaningful at a quiesced point (pass start, or after
    /// [`Session::quiesce_speculative`] between passes).
    pub fn mem_components(&self) -> MemComponents {
        MemComponents {
            pins: self.cache.as_ref().map(|c| c.stats().pinned_bytes).unwrap_or(0),
            device: self.device.as_ref().map(|d| d.stats().resident_bytes).unwrap_or(0),
            prefetch: self.prefetch.as_ref().map(|b| b.stats().buffered_bytes).unwrap_or(0),
            kv: self.kv_pool.as_ref().map(|p| p.used_bytes()).unwrap_or(0),
            live: self.gate.ledger().balance(),
            resident: self.resident.as_ref().map(|m| m.bytes).unwrap_or(0),
        }
    }

    /// Emit this lane's memory-attribution component counters on the bus
    /// and return the sample (the serving layer sums samples across lanes
    /// into the global `mem_audit` event; single-session runs emit their
    /// own in `pass_mode`).  No-op (but still sampled) when the bus is
    /// off.
    pub fn emit_mem_components(&self) -> MemComponents {
        let c = self.mem_components();
        if self.telemetry.is_on() {
            for (name, v) in [
                ("mem_pins", c.pins),
                ("mem_device", c.device),
                ("mem_prefetch", c.prefetch),
                ("mem_kv", c.kv),
                ("mem_live", c.live),
                ("mem_resident", c.resident),
            ] {
                self.telemetry.counter(name, worker::DRIVER, v as f64, EvArgs::default().with_bytes(v));
            }
        }
        c
    }

    /// Device-resident cache counters (zeros when the cache is off).
    pub fn device_stats(&self) -> DeviceStats {
        self.device.as_ref().map(|d| d.stats()).unwrap_or_default()
    }

    /// Worker-pool thread accounting (zeros for baseline sessions).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// The Send half of the device cache, for cross-session victim wiring
    /// (None when the cache is off).
    pub fn device_ledger(&self) -> Option<DeviceLedger> {
        self.device.as_ref().map(|d| d.ledger().clone())
    }

    /// Register another session's device ledger as an eviction target
    /// (same shared-accountant requirement as
    /// [`Session::add_eviction_victim`]; the victim re-uploads on its next
    /// pass — degraded, never wrong).
    pub fn add_device_eviction_victim(&mut self, ledger: DeviceLedger) {
        self.gate.add_victim_device(ledger);
    }

    /// Register another session's KV pool as an eviction target (same
    /// shared-accountant requirement as [`Session::add_eviction_victim`]).
    /// The victim lane's evicted sequences fall back to full-prefix
    /// recompute — degraded, never wrong.
    ///
    /// NOTE: under today's per-request KV lifecycle (blocks freed when
    /// `run_batch` returns) a victim pool is only non-empty while that
    /// lane's request is in flight — which concurrent lanes make an
    /// everyday occurrence, so the chain is reclaim-token-guarded (see
    /// `pipeload::gate`).
    pub fn add_kv_eviction_victim(&mut self, pool: KvPool) {
        self.gate.add_kv_pool(pool);
    }

    /// A cloned handle of this session's admission gate, for cross-lane
    /// wiring (peer wakeups, the shared reclaim token).  All gate clones
    /// share state; the handle is Send.
    pub fn pipeline_gate(&self) -> OrderedGate {
        self.gate.clone()
    }

    /// Share a fleet-wide reclaim token (see
    /// [`crate::pipeload::gate::ReclaimToken`]): every lane of a
    /// concurrent Router must hold the SAME token so cross-lane eviction
    /// chains serialize instead of interleaving.
    pub fn set_reclaim_token(&mut self, token: ReclaimToken) {
        self.gate.set_reclaim_token(token);
    }

    /// Register another lane's gate for cross-lane waiter wakeups: a free
    /// on that lane may be the headroom an admission parked HERE needs.
    /// Required (both directions) between lanes serving concurrently on a
    /// shared accountant.
    pub fn add_gate_peer(&mut self, other: &OrderedGate) {
        self.gate.add_peer(other);
    }

    /// Tell the session whether more requests are queued behind the
    /// current one.  When true, the LAST pass of a `run_batch` keeps
    /// `expect_next` on, so idle loaders prefetch the NEXT request's head
    /// stages across the request boundary (serve-queue depth permitting).
    pub fn set_expect_more(&mut self, more: bool) {
        self.expect_more = more;
    }

    /// Attach a planner schedule after opening (see
    /// [`SessionBuilder::schedule`]).
    pub fn set_schedule(&mut self, schedule: Schedule) {
        self.schedule = Some(schedule);
    }

    /// Attach a memory-pressure trace after opening (see
    /// [`SessionBuilder::memory_trace`]).  Replaces any earlier trace;
    /// already-applied steps are not revisited.
    pub fn set_memory_trace(&mut self, trace: PressureTrace) {
        self.elastic = Some(BudgetController::new(trace));
    }

    /// Loading Agents currently in force (1 outside PIPELOAD).  Changes
    /// when an elastic budget step re-plans against the schedule.
    pub fn current_agents(&self) -> usize {
        self.opts.as_ref().map(|o| o.agents.max(1)).unwrap_or(1)
    }

    /// One record per applied elastic budget step, in application order.
    pub fn budget_epochs(&self) -> &[BudgetEpoch] {
        &self.epochs
    }

    /// Cumulative elastic counters across this session's lifetime.
    pub fn elastic_stats(&self) -> ElasticStats {
        self.elastic_totals
    }

    /// Cumulative own-state eviction count (pinned layers + KV blocks +
    /// device copies + wasted prefetches over this session's lifetime,
    /// from any pressure source) — the base the Router reconciles
    /// cross-lane elastic attribution from.
    pub fn own_eviction_count(&self) -> u64 {
        self.cache.as_ref().map(|c| c.stats().evictions).unwrap_or(0)
            + self.kv_pool.as_ref().map(|p| p.stats().evicted_blocks).unwrap_or(0)
            + self.device.as_ref().map(|d| d.stats().evictions).unwrap_or(0)
            + self.prefetch.as_ref().map(|b| b.stats().wasted).unwrap_or(0)
    }

    /// Credit elastic evictions observed OUTSIDE this session's own apply
    /// window: while a shared budget step settles, another lane's reclaim
    /// chain may take this session's pins/KV, and only the Router can see
    /// whose state went where.  (The corresponding [`BudgetEpoch`] keeps
    /// its in-window count; only the cumulative totals are corrected.)
    pub fn note_elastic_evictions(&mut self, n: u64) {
        self.elastic_totals.elastic_evictions += n;
    }

    /// Pin cap under the current constraint: the configured pin budget,
    /// clipped below `budget - max_stage` so a stalled admission can
    /// always make progress (the same liveness rule `Session::open`
    /// derives the cap from).
    fn pin_cap_for(&self, budget: u64) -> u64 {
        self.cfg
            .pin_budget
            .unwrap_or(0)
            .min(budget.saturating_sub(self.ctx.profile.max_stage_bytes()))
    }

    /// Smallest budget an elastic step may shrink this session to without
    /// wedging it: PIPELOAD must still admit its largest stage (the gate
    /// rejects any admission bigger than the whole budget), and the
    /// resident modes must keep the whole model.  Steps below the floor
    /// are clamped up — a device under that much real pressure has
    /// OOM-killed the process, not asked it to adapt.
    pub fn budget_floor(&self) -> u64 {
        match self.cfg.mode {
            Mode::PipeLoad => self.ctx.profile.max_stage_bytes(),
            Mode::Baseline | Mode::PipeSwitch => self.ctx.profile.total_weight_bytes,
        }
    }

    /// Apply a new memory budget to this session (an elastic step): resize
    /// the accountant (owned sessions only — a shared accountant is
    /// resized once by its [`crate::server::Router`]), drive the eviction
    /// chain until `used` fits again, re-derive the pin/KV caps, and
    /// re-plan the agent count against the schedule, if one is attached.
    /// Returns the recorded epoch.
    pub fn apply_budget(&mut self, new_budget: u64) -> &BudgetEpoch {
        let new_budget = new_budget.max(self.budget_floor());
        let pin_cap = self.pin_cap_for(new_budget);
        // the lane's KV allocation never grows past what was configured,
        // and shrinks so pins + KV still fit the new budget jointly (the
        // `pin + kv <= budget` validation rule, re-derived)
        let kv_cap = self
            .cfg
            .kv_budget
            .map(|orig| orig.min(new_budget.saturating_sub(pin_cap)));
        self.apply_budget_with_kv(new_budget, kv_cap)
    }

    /// [`Session::apply_budget`] with the KV pool cap dictated by the
    /// caller — the Router's rebalanced per-lane share of the global KV
    /// allocation.  `None` leaves the pool bounded by the accountant only.
    pub fn apply_budget_with_kv(
        &mut self,
        new_budget: u64,
        kv_cap: Option<u64>,
    ) -> &BudgetEpoch {
        // feasibility clamp (see [`Session::budget_floor`]): a step below
        // the floor would bail the next admission (PIPELOAD) or hang the
        // resident load, neither of which is "adapting"
        let new_budget = new_budget.max(self.budget_floor());
        if self.owns_accountant {
            self.accountant.resize(Some(new_budget));
        }
        // Eviction ATTRIBUTION is own-state only: the gate chain may also
        // reclaim victim lanes' pins/KV under a shared accountant, but
        // charging them here would make per-model `elastic_evictions`
        // blame the wrong lane — the Router reconciles those onto the
        // victims after the step ([`Session::note_elastic_evictions`]).
        // `freed` stays the total bytes this apply returned to the budget,
        // victim state included.
        let ev0 = self.own_eviction_count();
        let mut freed = 0u64;
        // caps first: a shrunk cap evicts its own overflow, then the gate
        // chain settles whatever is still over the accountant budget
        let pin_cap = self.pin_cap_for(new_budget);
        if let Some(cache) = &self.cache {
            freed += cache.set_pin_budget(pin_cap, &self.accountant);
        }
        let device_cap =
            Self::device_cap(&self.cfg, self.ctx.profile, Some(new_budget), pin_cap);
        if let Some(d) = &self.device {
            freed += d.ledger().set_cap(device_cap, &self.accountant);
        }
        if let Some(pool) = &self.kv_pool {
            freed += pool.set_kv_budget(kv_cap);
        }
        let (gate_freed, _chain_evictions) = self.gate.reclaim_to_budget();
        freed += gate_freed;
        let evictions = self.own_eviction_count() - ev0;

        // epoch re-planning: the schedule knows the best agent count for
        // the new constraint (paper Fig. 6c, consulted per epoch now)
        let mut replanned = false;
        if self.cfg.mode == Mode::PipeLoad {
            if let (Some(sched), Some(opts)) = (&self.schedule, self.opts.as_mut()) {
                if let Some(entry) = sched.pick(new_budget) {
                    let agents = entry.agents.max(1);
                    if agents != opts.agents {
                        opts.agents = agents;
                        self.plan = assignment(self.ctx.profile.stages.len(), agents);
                        if let Some(pool) = &self.pool {
                            pool.ensure_loaders(agents); // pool grows, never respawns
                        }
                        replanned = true;
                    }
                }
            }
        }

        // each epoch measures its own peaks against its own budget
        self.accountant.reset_peak_to_used();
        self.elastic_totals.budget_steps += 1;
        self.elastic_totals.elastic_evictions += evictions;
        if replanned {
            self.elastic_totals.replans += 1;
        }
        self.epochs.push(BudgetEpoch {
            at_pass: self.passes_run,
            budget_bytes: new_budget,
            freed_bytes: freed,
            evictions,
            used_after_bytes: self.accountant.used(),
            agents: self.current_agents(),
            pin_cap_bytes: self.cache.as_ref().map(|c| c.pin_budget()).unwrap_or(0),
            kv_cap_bytes: self.kv_pool.as_ref().and_then(|p| p.kv_budget()),
            replanned,
        });
        if self.telemetry.is_on() {
            self.telemetry.instant(
                "budget_epoch",
                worker::DRIVER,
                EvArgs::pass(self.passes_run as u64)
                    .with_epoch(self.epochs.len() as u64)
                    .with_bytes(new_budget),
            );
        }
        self.epochs.last().unwrap()
    }

    /// Set the Loading Agent count directly — the Router's rebalanced
    /// worker-pool slice for this lane after a fleet elastic step.  The
    /// persistent pool only ever grows (threads are cheap to keep, costly
    /// to respawn); the assignment shrinks/widens immediately, taking
    /// effect at the next pass boundary.  Returns true when the count
    /// actually changed (counted as a re-plan).
    pub fn set_agents(&mut self, agents: usize) -> bool {
        if self.cfg.mode != Mode::PipeLoad {
            return false;
        }
        let agents = agents.max(1);
        let Some(opts) = self.opts.as_mut() else { return false };
        if opts.agents == agents {
            return false;
        }
        opts.agents = agents;
        self.plan = assignment(self.ctx.profile.stages.len(), agents);
        if let Some(pool) = &self.pool {
            pool.ensure_loaders(agents);
        }
        self.elastic_totals.replans += 1;
        true
    }

    /// Pass-boundary hook: apply every trace step due at the current pass
    /// count.  Decode loops call this before each token's pass, so a
    /// budget step lands between passes — never mid-admission.
    fn poll_elastic(&mut self) {
        let Some(ctrl) = self.elastic.as_mut() else { return };
        let Some(step) = ctrl.poll(self.passes_run) else { return };
        self.apply_budget(step.budget_bytes);
    }

    /// Run one request with the session's configured batch and seed.
    pub fn run(&mut self) -> Result<(RunReport, RunOutput)> {
        let (batch, seed) = (self.cfg.batch, self.cfg.seed);
        self.run_batch(batch, seed)
    }

    /// Run one request (a full forward, or a whole decode loop for
    /// generative profiles) at the given batch size.  Setup, compiled
    /// executables, budget, and pinned layers are reused across calls.
    ///
    /// With `--kv-cache` the decode loop runs ONE full-prefix pass (which
    /// primes a [`KvSeq`] through the `*_kv` entries) and then incremental
    /// single-token passes; a sequence evicted under `S^stop` pressure —
    /// or denied blocks by the KV budget — falls back to full-prefix
    /// recompute for that token and re-primes, so generated tokens are
    /// identical to the cache-off path regardless of cache residency.
    /// The sequence's blocks are freed when this call returns (per-request
    /// lifecycle; the fixed-batch Router relies on it).
    ///
    /// This is a thin driver over the iteration-level API
    /// ([`Session::begin_decode`] / [`Session::decode_step`] /
    /// [`Session::finish_decode`]) that continuous-batching serving loops
    /// use directly to interleave many requests' steps — a request stepped
    /// there runs the exact same per-token code at the same batch and
    /// seed, so its tokens are bit-identical to a `run_batch` call.
    pub fn run_batch(&mut self, batch: usize, seed: u64) -> Result<(RunReport, RunOutput)> {
        let mut st = self.begin_decode(batch, seed);
        while !st.done() {
            let expect_next = !st.last_step() || self.expect_more;
            self.decode_step(&mut st, expect_next)?;
        }
        Ok(self.finish_decode(st))
    }

    /// Open a per-request decode state: input made from `(batch, seed)`,
    /// counters baselined, nothing run yet.  Step it with
    /// [`Session::decode_step`] until [`DecodeState::done`], then harvest
    /// with [`Session::finish_decode`].  Many states may be open at once —
    /// the continuous scheduler interleaves their steps at token
    /// granularity; each holds its own [`KvSeq`], so KV blocks live for
    /// the request's whole residence in the batch.
    pub fn begin_decode(&mut self, batch: usize, seed: u64) -> DecodeState {
        let profile = self.ctx.profile;
        let (input, ids, prompt_len) = make_input(profile, batch, seed);
        let generative = profile.is_generative();
        let gen_tokens = if generative {
            self.cfg.gen_tokens.unwrap_or(profile.gen_tokens.max(1))
        } else {
            0
        };
        let kv_enabled = generative
            && self.kv_pool.is_some()
            && self.opts.is_some()
            && profile.entry("embedding_inc", batch).is_ok()
            && profile.entry(&format!("{}_inc", profile.body_kind()), batch).is_ok()
            && profile.entry(&format!("{}_kv", profile.body_kind()), batch).is_ok()
            && profile.entry("lm_head_inc", batch).is_ok();
        let n_body = profile.stages.iter().filter(|s| s.kind == profile.body_kind()).count();
        let kv_stats0 = self.kv_pool_stats();
        DecodeState {
            batch,
            input,
            ids,
            cur_len: prompt_len,
            step: 0,
            total_steps: if generative { gen_tokens } else { 1 },
            generative,
            kv_enabled,
            n_body,
            kv_seq: None,
            last_next: Vec::new(),
            generated: Vec::new(),
            generated_rows: if generative { vec![Vec::new(); batch] } else { Vec::new() },
            head: Vec::new(),
            passes: Vec::new(),
            kv_inc: 0,
            kv_rec: 0,
            token_lat: LatencyRecorder::new(),
            t0: Instant::now(),
            kv_evicted0: kv_stats0.evicted_blocks,
            kv_shared0: kv_stats0.shared_total,
            kv_dedup0: kv_stats0.dedup_bytes,
            elastic0: self.elastic_totals,
            prefetch0: self.prefetch_stats(),
            spawns_avoided0: self.pool_stats().spawns_avoided(),
            faults0: self.faults.snapshot(),
        }
    }

    /// Advance one request by one iteration: its single forward pass
    /// (non-generative), or one token of its decode loop — the prime pass
    /// on the first step, incremental after, with the same
    /// eviction-recovery fallback as [`Session::run_batch`].  `expect_next`
    /// keeps cross-pass prefetch alive when any pass follows this one
    /// (continuous loops pass true whenever other requests remain active).
    pub fn decode_step(&mut self, st: &mut DecodeState, expect_next: bool) -> Result<()> {
        debug_assert!(!st.done(), "decode_step on a finished state");
        let profile = self.ctx.profile;
        // interleaved states may differ in batch; the pass reads ctx.batch
        self.ctx.batch = st.batch;

        if !st.generative {
            self.poll_elastic();
            let (out, stats) = if self.opts.is_none() {
                self.baseline_forward(&st.input)?
            } else {
                // a serving queue with more requests pending keeps prefetch
                // alive across the request boundary
                self.pass(&st.input, expect_next)?
            };
            st.head = self.engine.runtime.buffer_to_f32(&out)?;
            st.passes.push(stats);
            st.step += 1;
            return Ok(());
        }

        let t_tok = Instant::now();
        // elastic budget steps land here, between token passes
        self.poll_elastic();
        // Incremental when the cached prefix lines up exactly with the ids
        // (tokens == cur_len - 1: everything but the token appended after
        // the previous pass) and one more block row can be reserved.
        // Anything else recomputes full-prefix.
        let can_inc = st.kv_enabled
            && st.step > 0
            && st.last_next.len() == st.batch
            && st.cur_len <= profile.max_seq
            && st
                .kv_seq
                .as_ref()
                .map(|s| s.valid() && s.tokens() + 1 == st.cur_len && s.reserve(st.cur_len))
                .unwrap_or(false);

        let mut step_out: Option<(Vec<f32>, bool, PassStats)> = None;
        if can_inc {
            let seq = st.kv_seq.as_ref().unwrap();
            let inp = ModelInput::Ids(st.last_next.clone());
            let pos = st.cur_len - 1;
            match self.pass_mode(&inp, &PassMode::Incremental { kv: seq, pos }, expect_next) {
                Ok((out, stats)) => {
                    seq.set_tokens(st.cur_len);
                    st.kv_inc += 1;
                    let logits = self.engine.runtime.buffer_to_f32(&out)?;
                    step_out = Some((logits, true, stats));
                }
                Err(e) => {
                    // Mid-pass eviction is the ONLY recoverable failure:
                    // the token was not produced, so fall through to a
                    // full-prefix recompute.  Matched by marker, not by
                    // `seq.valid()` — the error recovery in `pass_mode`
                    // invalidates every sequence on ANY failure, so
                    // validity cannot distinguish eviction from a real
                    // error.
                    let evicted =
                        e.chain().any(|c| c.to_string().contains(KV_EVICTED_MIDPASS));
                    if !evicted {
                        return Err(e);
                    }
                }
            }
        }
        let (logits, incremental, stats) = match step_out {
            Some(x) => x,
            None => {
                // Count a recompute only where a cache COULD have served
                // (within max_seq); overrun steps are plain full passes on
                // either path, not cache misses.
                if st.kv_enabled && st.step > 0 && st.cur_len <= profile.max_seq {
                    st.kv_rec += 1; // primed cache could not serve this token
                }
                // (re)prime: a fresh sequence, if blocks are grantable
                let mut primed = false;
                if st.kv_enabled && st.cur_len <= profile.max_seq {
                    st.kv_seq = None; // free any stale sequence first
                    let pool = self.kv_pool.as_ref().unwrap();
                    let seq = pool.open_seq(st.n_body, st.batch, profile.hidden);
                    if seq.reserve(st.cur_len) {
                        st.kv_seq = Some(seq);
                        primed = true;
                    }
                }
                let inp = ModelInput::Ids(st.ids.clone());
                let (out, stats) = if self.opts.is_none() {
                    self.baseline_forward(&inp)?
                } else if primed {
                    let mode = PassMode::PrimeKv {
                        kv: st.kv_seq.as_ref().unwrap(),
                        prefix_len: st.cur_len,
                    };
                    let r = self.pass_mode(&inp, &mode, expect_next)?;
                    st.kv_seq.as_ref().unwrap().set_tokens(st.cur_len);
                    r
                } else {
                    self.pass(&inp, expect_next)?
                };
                (self.engine.runtime.buffer_to_f32(&out)?, false, stats)
            }
        };

        let next = if incremental {
            argmax_rows_flat(&logits, profile.vocab, st.batch)
        } else {
            argmax_rows(&logits, profile, st.batch, st.cur_len)
        };
        push_tokens(&mut st.ids, profile, st.cur_len, &next);
        st.generated.push(next[0]);
        for (row, t) in next.iter().enumerate() {
            st.generated_rows[row].push(*t);
        }
        st.cur_len += 1;
        st.head = if incremental {
            logits[..profile.vocab].to_vec()
        } else {
            last_logits(&logits, profile, st.cur_len - 1)
        };
        st.last_next = next;
        st.passes.push(stats);
        st.token_lat.record(t_tok.elapsed());
        st.step += 1;
        Ok(())
    }

    /// Close a finished (or abandoned) decode state: the request's KV
    /// blocks go back to the budget here, and the per-request report is
    /// assembled from the state's own counters against its baselines.
    pub fn finish_decode(&mut self, st: DecodeState) -> (RunReport, RunOutput) {
        let DecodeState {
            kv_seq,
            generated,
            generated_rows,
            mut head,
            passes,
            kv_inc,
            kv_rec,
            token_lat,
            t0,
            kv_evicted0,
            kv_shared0,
            kv_dedup0,
            elastic0,
            prefetch0,
            spawns_avoided0,
            faults0,
            ..
        } = st;
        // request over: blocks go back to the budget here
        drop(kv_seq);
        let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
        self.kv_inc_total += kv_inc;
        self.kv_recompute_total += kv_rec;
        let prefetch1 = self.prefetch_stats();
        let kv_stats1 = self.kv_pool_stats();
        let faults1 = self.faults.snapshot();
        let tokens_per_sec = if token_lat.is_empty() {
            0.0
        } else {
            token_lat.len() as f64 / (latency_ms / 1000.0).max(1e-9)
        };

        let report = RunReport {
            model: self.cfg.profile.clone(),
            mode: self.cfg.mode.name().to_string(),
            // the agents in force NOW — an elastic re-plan may have moved
            // this away from the configured count
            agents: if self.cfg.mode == Mode::PipeLoad { self.current_agents() } else { 1 },
            latency_ms,
            peak_bytes: passes.iter().map(|p| p.peak_bytes).max().unwrap_or(0),
            mem_stall_ms: passes.iter().map(|p| p.mem_stall_ms).sum(),
            wait_stall_ms: passes.iter().map(|p| p.wait_stall_ms).sum(),
            idle_fraction: self.ctx.tracer.inference_idle_fraction().unwrap_or(0.0),
            tokens: generated.len(),
            cache_hits: passes.iter().map(|p| p.cache_hits).sum(),
            cache_misses: passes.iter().map(|p| p.cache_misses).sum(),
            kv_inc_passes: kv_inc,
            kv_recomputes: kv_rec,
            kv_evicted_blocks: kv_stats1.evicted_blocks - kv_evicted0,
            shared_kv_blocks: kv_stats1.shared_total - kv_shared0,
            kv_dedup_bytes: kv_stats1.dedup_bytes - kv_dedup0,
            budget_steps: self.elastic_totals.budget_steps - elastic0.budget_steps,
            elastic_evictions: self.elastic_totals.elastic_evictions
                - elastic0.elastic_evictions,
            replans: self.elastic_totals.replans - elastic0.replans,
            prefetched_stages: prefetch1.prefetched - prefetch0.prefetched,
            prefetch_wasted: prefetch1.wasted - prefetch0.wasted,
            device_cache_hits: passes.iter().map(|p| p.device_cache_hits).sum(),
            spawns_avoided: self.pool_stats().spawns_avoided() - spawns_avoided0,
            decode_p50_ms: token_lat.p50(),
            decode_p95_ms: token_lat.p95(),
            tokens_per_sec,
            faults_injected: faults1.faults_injected.saturating_sub(faults0.faults_injected),
            load_retries: faults1.load_retries.saturating_sub(faults0.load_retries),
            passes_timed_out: faults1
                .passes_timed_out
                .saturating_sub(faults0.passes_timed_out),
        };
        head.truncate(16);
        (report, RunOutput { generated, generated_rows, head_sample: head })
    }

    /// One pipelined pass over persistent session state.  `expect_next`
    /// tells the pass machinery another pass follows (decode loops), so
    /// idle loaders may prefetch the next pass's head stages.
    fn pass(
        &mut self,
        input: &ModelInput,
        expect_next: bool,
    ) -> Result<(xla::PjRtBuffer, PassStats)> {
        self.pass_mode(input, &PassMode::Full, expect_next)
    }

    /// [`Session::pass`] with an explicit [`PassMode`] (KV decode paths).
    fn pass_mode(
        &mut self,
        input: &ModelInput,
        mode: &PassMode,
        expect_next: bool,
    ) -> Result<(xla::PjRtBuffer, PassStats)> {
        // Quiesce leftover speculative loads from the previous pass: each
        // agent's regular work queues behind its prefetch task anyway, so
        // this costs ~nothing, and it keeps the pass ledger's balance
        // meaningful (in-flight prefetches charge it).
        self.prefetch_group.wait_idle();
        // every attempted pass is a fresh admission epoch: stragglers from
        // a failed pass error out as stale instead of corrupting the order
        self.pass_epoch += 1;
        self.faults.tick_pass();
        self.gate.begin_pass(self.pass_epoch);
        let opts = self.opts.as_ref().expect("pass() requires a pipelined mode");
        let pool = self.pool.as_ref().expect("pipelined sessions own a worker pool");
        self.accountant.reset_peak_to_used();
        let env = PassEnv {
            gate: &self.gate,
            cache: self.cache.as_ref(),
            plan: &self.plan,
            pool,
            epoch: self.pass_epoch,
            prefetch: self.prefetch.as_ref(),
            prefetch_depth: self.cfg.prefetch_depth,
            expect_next,
            prefetch_group: Some(&self.prefetch_group),
            device: self.device.as_ref(),
        };
        let tel_on = self.telemetry.is_on();
        if tel_on && self.owns_accountant {
            // Memory-attribution audit sample at the settled point: the
            // quiesce above means every accounted byte is parked in a
            // store (pins / device / prefetch / KV) or the pass ledger,
            // so the component sum must equal the accountant exactly.
            // Shared-accountant lanes skip this — the router samples all
            // lanes at once instead (a one-lane sum can't reconcile a
            // fleet-wide accountant).
            let c = self.emit_mem_components();
            self.telemetry.counter(
                "mem_audit",
                worker::DRIVER,
                self.accountant.used() as f64,
                EvArgs::pass(self.pass_epoch).with_bytes(c.total()),
            );
        }
        if tel_on {
            self.telemetry.begin("pass", worker::DRIVER, EvArgs::pass(self.pass_epoch));
        }
        // Pass watchdog: if this pass hangs past its deadline the monitor
        // shuts the gate down, which errors out every parked admission and
        // pending load — the pass then fails through the ordinary error
        // path below and the NEXT pass rearms everything (`begin_pass`
        // clears the gate, recovery revives the accountant).
        let wd_guard = match (&self.watchdog, self.cfg.pass_timeout_ms) {
            (Some(wd), Some(ms)) => {
                let gate = self.gate.clone();
                let stats = self.faults.stats().clone();
                let tel = self.telemetry.clone();
                let epoch = self.pass_epoch;
                Some(wd.arm(Duration::from_millis(ms), move || {
                    stats.note_pass_timeout();
                    tel.instant(
                        "pass_timeout",
                        worker::DRIVER,
                        EvArgs::pass(epoch).with_reason("watchdog"),
                    );
                    gate.shutdown();
                }))
            }
            _ => None,
        };
        let mut r = run_pass_mode(&self.ctx, opts, &env, input, mode);
        let timed_out = wd_guard.as_ref().is_some_and(|g| g.expired());
        drop(wd_guard); // disarm before recovery work (it has no deadline)
        if timed_out {
            let msg = format!(
                "pass {} exceeded its {} ms watchdog deadline",
                self.pass_epoch,
                self.cfg.pass_timeout_ms.unwrap_or(0)
            );
            r = match r {
                // raced to completion: the pass finished as the quiesce
                // landed, but the gate/accountant are already torn down —
                // fail it so recovery below leaves clean state
                Ok(_) => Err(anyhow::anyhow!("{msg} (completed during quiesce)")),
                Err(e) => Err(e.context(msg)),
            };
        }
        if tel_on {
            self.telemetry.end("pass", worker::DRIVER);
            // per-pass accountant high-water sample (counter track in the
            // Chrome trace; the bench trajectory records the same series)
            self.telemetry.counter(
                "mem_high_water",
                worker::DRIVER,
                self.accountant.peak() as f64,
                EvArgs::pass(self.pass_epoch),
            );
        }
        if r.is_err() {
            self.recover_after_abort();
        } else {
            self.passes_run += 1;
        }
        r
    }

    /// Put the session's accounting back into a runnable state after an
    /// aborted pass — a pass error, a watchdog quiesce, or a contained lane
    /// panic (the lane supervisor's restart primitive).  Safe to call when
    /// nothing is wrong; the next pass proceeds normally either way.
    pub fn recover_after_abort(&mut self) {
        // speculative loads may still be mutating the accountant and
        // the pass ledger; wait them out before draining either
        self.prefetch_group.wait_idle();
        if self.owns_accountant {
            // A failed pass can leave in-flight bytes accounted; drop
            // any pins, speculative loads, device copies, and cached
            // KV, drain the pass ledger (so its balance stays in sync
            // with the accountant), then restart the accounting
            // wholesale.
            if let Some(c) = &self.cache {
                c.clear();
            }
            if let Some(b) = &self.prefetch {
                b.clear();
            }
            if let Some(d) = &self.device {
                d.ledger().clear();
                d.sweep();
            }
            if let Some(p) = &self.kv_pool {
                p.invalidate_all();
            }
            self.gate.ledger().drain();
            self.accountant.reset();
        } else {
            // Shared accountant: other lanes' charges are live in it —
            // possibly CHANGING right now (concurrent lanes), so no
            // snapshot arithmetic can be exact.  The pass ledger makes
            // recovery local instead: drain() frees exactly the bytes
            // THIS pass still holds (admitted-but-unfreed loads,
            // activation transients, adopted takes).  Durable stores —
            // pins, prefetched shards, device copies, ours and other
            // lanes' alike — were never the pass's charge and stay
            // resident.  Own KV sequences are invalidated: a failed
            // pass may leave one half-written, and its blocks are
            // pool-accounted (store-owned), not ledger-charged.
            if let Some(p) = &self.kv_pool {
                p.invalidate_all();
            }
            if let Some(d) = &self.device {
                d.sweep(); // drop buffers the chain evicted meanwhile
            }
            self.gate.ledger().drain();
            self.accountant.revive();
        }
    }

    /// Return every byte this session still accounts — pins, parked
    /// prefetch shards, device copies, KV blocks, the baseline-resident
    /// model, and any residual pass-ledger balance — to the accountant.
    /// Serving
    /// loops call this at lane/router shutdown so a shared accountant
    /// drains to exactly zero once every lane has released (the chaos
    /// soak's no-leak invariant).
    pub fn release_all(&mut self) {
        self.prefetch_group.wait_idle();
        if let Some(c) = &self.cache {
            c.drain(&self.accountant);
        }
        if let Some(b) = &self.prefetch {
            b.drain(&self.accountant);
        }
        if let Some(d) = &self.device {
            d.ledger().drain(&self.accountant);
            d.sweep();
        }
        if let Some(p) = &self.kv_pool {
            p.invalidate_all();
        }
        if let Some(m) = self.resident.take() {
            self.accountant.free(m.bytes);
        }
        self.gate.ledger().drain();
    }

    /// Baseline mode: load the whole model once per session, then run
    /// resident forwards (the paper's non-pipeline comparator).
    fn baseline_forward(&mut self, input: &ModelInput) -> Result<(xla::PjRtBuffer, PassStats)> {
        if self.resident.is_none() {
            self.resident = Some(baseline::load_all(&self.ctx, &self.accountant)?);
        }
        self.accountant.reset_peak_to_used();
        let model = self.resident.as_ref().unwrap();
        let r = baseline::forward_resident(&self.ctx, model, &self.accountant, input);
        if r.is_ok() {
            self.passes_run += 1;
        }
        r
    }
}
