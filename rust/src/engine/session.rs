//! Reusable inference sessions: setup once, many passes.
//!
//! [`Engine::run`] reproduces the paper's per-run semantics — resolve the
//! profile, validate weights, AOT-prepare, build channels/threads, run one
//! request.  A serving loop doing that per batch (and a decode loop doing
//! it per token) pays the setup tax on every hot-path iteration.
//!
//! A [`Session`] hoists everything that survives a pass out of the loop:
//!
//! * profile resolution + weight generation/validation + [`Runtime::prepare`]
//!   run **exactly once** at [`Engine::open_session`];
//! * the [`MemoryAccountant`] persists, so the budget (and any pinned
//!   hot layers) carries across passes;
//! * the [`OrderedGate`] is rearmed with `reset()` instead of rebuilt;
//! * the stage-to-agent [`assignment`] is precomputed;
//! * an optional hot-layer [`LayerCache`] (`RunConfig::pin_budget`) lets
//!   the Daemon pin computed layers instead of destroying them, so the
//!   next decode token / serve batch skips disk for pinned stages.
//!
//! The pin budget is capped at `budget - max_stage_bytes` so a stalled
//! admission can always make progress: pinned-but-in-flight stages later
//! in the admission order are not evictable, so at least one unpinned
//! stage must always fit beside them (liveness; see `pipeload::gate`).
//!
//! # Shared accountants (multi-model serving)
//!
//! By default a session creates its own [`MemoryAccountant`] from
//! `RunConfig::budget`.  [`Engine::open_session_shared`] (or
//! [`SessionBuilder::accountant`]) opens the session against a
//! caller-supplied accountant instead, so N sessions — one per model
//! profile — contend for a single device-wide budget; the shared budget
//! outranks `RunConfig::budget`.  [`Session::add_eviction_victim`] lets one
//! session's `S^stop` pressure reclaim another session's pinned hot layers
//! (the [`crate::server::Router`] wires every pair).  Config validation is
//! centralized here through [`RunConfig::validate`], so every entrypoint
//! rejects bad configs with the same message.
//!
//! [`Runtime::prepare`]: crate::runtime::Runtime::prepare
//! [`assignment`]: crate::pipeload::assignment

use std::time::Instant;

use anyhow::Result;

use super::{argmax_rows, last_logits, make_input, push_tokens, Engine, RunOutput};
use crate::baseline;
use crate::baseline::ResidentModel;
use crate::config::{Mode, RunConfig};
use crate::diskio::Disk;
use crate::memory::MemoryAccountant;
use crate::metrics::RunReport;
use crate::model::Profile;
use crate::pipeload::assignment::assignment;
use crate::pipeload::cache::{CacheStats, LayerCache};
use crate::pipeload::gate::OrderedGate;
use crate::pipeload::{run_pass, ExecCtx, ModelInput, PassEnv, PassStats, PipelineOpts};
use crate::trace::Tracer;

/// Long-lived pipeline state for one (profile, mode, budget) configuration.
/// Obtained from [`Engine::open_session`]; run requests with
/// [`Session::run`] / [`Session::run_batch`].
pub struct Session<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    ctx: ExecCtx<'e>,
    /// None for Baseline (non-pipelined) mode
    opts: Option<PipelineOpts>,
    accountant: MemoryAccountant,
    /// false when the accountant was supplied by the caller (shared across
    /// sessions, e.g. by a [`crate::server::Router`]) — error recovery must
    /// then release only this session's bytes, never reset wholesale.
    owns_accountant: bool,
    gate: OrderedGate,
    plan: Vec<Vec<usize>>,
    cache: Option<LayerCache>,
    /// Baseline mode: the whole model, loaded on first use
    resident: Option<ResidentModel>,
    prepared_entries: usize,
    passes_run: usize,
}

/// Options for opening a [`Session`] — sugar methods on [`Engine`] cover
/// the common cases ([`Engine::open_session`],
/// [`Engine::open_session_shared`]); the builder composes them.
///
/// ```ignore
/// let shared = MemoryAccountant::new(Some(budget));
/// let mut s = engine.session(&cfg).accountant(&shared).tracer(&t).open()?;
/// ```
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    tracer: Tracer,
    accountant: Option<MemoryAccountant>,
}

impl<'e> SessionBuilder<'e> {
    /// Record spans into a caller-supplied tracer (shared buffer), so the
    /// caller can render Gantt charts / stall stats afterwards.
    pub fn tracer(mut self, tracer: &Tracer) -> SessionBuilder<'e> {
        self.tracer = tracer.clone();
        self
    }

    /// Account this session's memory into a caller-supplied accountant
    /// instead of a private one.  The accountant's budget (not
    /// `RunConfig::budget`) constrains the session, so N sessions opened
    /// against the same accountant contend for one device-wide budget.
    pub fn accountant(mut self, accountant: &MemoryAccountant) -> SessionBuilder<'e> {
        self.accountant = Some(accountant.clone());
        self
    }

    pub fn open(self) -> Result<Session<'e>> {
        Session::open(self.engine, &self.cfg, &self.tracer, self.accountant)
    }
}

impl Engine {
    /// Start building a session; finish with [`SessionBuilder::open`].
    pub fn session(&self, cfg: &RunConfig) -> SessionBuilder<'_> {
        SessionBuilder {
            engine: self,
            cfg: cfg.clone(),
            tracer: Tracer::new(cfg.trace),
            accountant: None,
        }
    }

    /// Open a reusable session: profile resolution, weight generation, and
    /// AOT prepare happen here, once, instead of per run.
    pub fn open_session(&self, cfg: &RunConfig) -> Result<Session<'_>> {
        self.session(cfg).open()
    }

    /// Like [`Engine::open_session`] but records into a caller-supplied
    /// tracer (shared buffer), so callers can render Gantt charts.
    pub fn open_session_with(&self, cfg: &RunConfig, tracer: &Tracer) -> Result<Session<'_>> {
        self.session(cfg).tracer(tracer).open()
    }

    /// Open a session against a **shared** accountant: the session's loads
    /// and pins are admitted under `accountant`'s budget, alongside every
    /// other session opened against it.  `cfg.budget` is ignored (the
    /// shared budget outranks it).  This is the multi-model serving
    /// primitive: one `Session` per profile, one global budget.
    pub fn open_session_shared(
        &self,
        cfg: &RunConfig,
        accountant: &MemoryAccountant,
    ) -> Result<Session<'_>> {
        self.session(cfg).accountant(accountant).open()
    }
}

impl<'e> Session<'e> {
    fn open(
        engine: &'e Engine,
        cfg: &RunConfig,
        tracer: &Tracer,
        shared: Option<MemoryAccountant>,
    ) -> Result<Session<'e>> {
        let profile = engine.runtime.profile(&cfg.profile)?;
        // Central validation: every entrypoint (run / serve / Router / TCP)
        // opens a session, so every entrypoint rejects bad configs with the
        // same message.  A shared accountant's budget is the binding one.
        let budget = match &shared {
            Some(a) => a.budget(),
            None => cfg.budget,
        };
        cfg.validate_with_budget(profile, budget)?;
        engine.ensure_weights(&cfg.profile)?;
        let disk = Disk::preset(&cfg.disk)?;
        let mut ctx = ExecCtx::new(&engine.runtime, &cfg.profile, &engine.paths.weights, disk)?;
        ctx.tracer = tracer.clone();
        ctx.batch = cfg.batch;
        // compile off the measured path (the paper's pre-run) — once
        let prepared_entries = engine.runtime.prepare(profile)?;

        let opts = match cfg.mode {
            Mode::Baseline => None,
            Mode::PipeSwitch => Some(PipelineOpts::pipeswitch()),
            Mode::PipeLoad => Some(PipelineOpts::pipeload(cfg.agents)),
        };
        let owns_accountant = shared.is_none();
        let accountant = shared.unwrap_or_else(|| MemoryAccountant::new(cfg.budget));
        let cache = Self::build_cache(cfg, profile, budget);
        let gate = match &cache {
            Some(c) => OrderedGate::with_cache(accountant.clone(), c.clone()),
            None => OrderedGate::new(accountant.clone()),
        };
        let agents = opts.as_ref().map(|o| o.agents.max(1)).unwrap_or(1);
        let plan = assignment(profile.stages.len(), agents);
        Ok(Session {
            engine,
            cfg: cfg.clone(),
            ctx,
            opts,
            accountant,
            owns_accountant,
            gate,
            plan,
            cache,
            resident: None,
            prepared_entries,
            passes_run: 0,
        })
    }

    /// Hot-layer cache sizing.  Only PIPELOAD destroys layers, so only it
    /// can pin; the pin budget is clipped below `budget - max_stage` so an
    /// unpinned admission always fits beside in-flight pinned stages.
    fn build_cache(cfg: &RunConfig, profile: &Profile, budget: Option<u64>) -> Option<LayerCache> {
        if cfg.mode != Mode::PipeLoad {
            return None;
        }
        let mut pin = cfg.pin_budget.unwrap_or(0);
        if let Some(budget) = budget {
            let max_stage =
                profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap_or(0);
            pin = pin.min(budget.saturating_sub(max_stage));
        }
        if pin == 0 {
            None
        } else {
            Some(LayerCache::new(pin))
        }
    }

    pub fn profile(&self) -> &Profile {
        self.ctx.profile
    }

    /// Entries compiled by the session's single prepare call.
    pub fn prepared_entries(&self) -> usize {
        self.prepared_entries
    }

    /// Pipeline passes executed so far (tokens count individually).
    pub fn passes_run(&self) -> usize {
        self.passes_run
    }

    /// Hot-layer cache counters (zeros when no cache is attached).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// The accountant this session admits memory through (shared when the
    /// session was opened via [`Engine::open_session_shared`]).
    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// The session's hot-layer cache handle, if one is attached.
    pub fn layer_cache(&self) -> Option<&LayerCache> {
        self.cache.as_ref()
    }

    /// The configuration this session was opened with.
    pub fn run_config(&self) -> &RunConfig {
        &self.cfg
    }

    /// Register another session's hot-layer cache as an eviction target:
    /// when an admission here stalls on the (shared) budget, it reclaims
    /// that session's pins after its own.  Only meaningful — and only
    /// sound — between sessions opened against the same shared accountant.
    pub fn add_eviction_victim(&mut self, cache: LayerCache) {
        self.gate.add_victim(cache);
    }

    /// Run one request with the session's configured batch and seed.
    pub fn run(&mut self) -> Result<(RunReport, RunOutput)> {
        let (batch, seed) = (self.cfg.batch, self.cfg.seed);
        self.run_batch(batch, seed)
    }

    /// Run one request (a full forward, or a whole decode loop for
    /// generative profiles) at the given batch size.  Setup, compiled
    /// executables, budget, and pinned layers are reused across calls.
    pub fn run_batch(&mut self, batch: usize, seed: u64) -> Result<(RunReport, RunOutput)> {
        let profile = self.ctx.profile;
        self.ctx.batch = batch;
        let (input, mut ids, prompt_len) = make_input(profile, batch, seed);
        let gen_tokens = if profile.is_generative() {
            self.cfg.gen_tokens.unwrap_or(profile.gen_tokens.max(1))
        } else {
            0
        };

        let t0 = Instant::now();
        let mut passes: Vec<PassStats> = Vec::new();
        let mut generated = Vec::new();
        let mut head: Vec<f32> = Vec::new();

        if !profile.is_generative() {
            let (out, stats) = if self.opts.is_none() {
                self.baseline_forward(&input)?
            } else {
                self.pass(&input)?
            };
            head = self.engine.runtime.buffer_to_f32(&out)?;
            passes.push(stats);
        } else {
            let mut cur_len = prompt_len;
            for _ in 0..gen_tokens {
                let inp = ModelInput::Ids(ids.clone());
                // pipelined modes: fresh pass per token (weights were
                // destroyed — or pinned — after the previous one)
                let (out, stats) = if self.opts.is_none() {
                    self.baseline_forward(&inp)?
                } else {
                    self.pass(&inp)?
                };
                let logits = self.engine.runtime.buffer_to_f32(&out)?;
                let next = argmax_rows(&logits, profile, batch, cur_len);
                push_tokens(&mut ids, profile, cur_len, &next);
                generated.push(next[0]);
                cur_len += 1;
                head = last_logits(&logits, profile, cur_len - 1);
                passes.push(stats);
            }
        }
        let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;

        let report = RunReport {
            model: self.cfg.profile.clone(),
            mode: self.cfg.mode.name().to_string(),
            agents: if self.cfg.mode == Mode::PipeLoad { self.cfg.agents } else { 1 },
            latency_ms,
            peak_bytes: passes.iter().map(|p| p.peak_bytes).max().unwrap_or(0),
            mem_stall_ms: passes.iter().map(|p| p.mem_stall_ms).sum(),
            wait_stall_ms: passes.iter().map(|p| p.wait_stall_ms).sum(),
            idle_fraction: self.ctx.tracer.inference_idle_fraction().unwrap_or(0.0),
            tokens: generated.len(),
            cache_hits: passes.iter().map(|p| p.cache_hits).sum(),
            cache_misses: passes.iter().map(|p| p.cache_misses).sum(),
        };
        head.truncate(16);
        Ok((report, RunOutput { generated, head_sample: head }))
    }

    /// One pipelined pass over persistent session state.
    fn pass(&mut self, input: &ModelInput) -> Result<(xla::PjRtBuffer, PassStats)> {
        let opts = self.opts.as_ref().expect("pass() requires a pipelined mode");
        self.gate.reset();
        // Snapshots for shared-accountant error recovery (see below).
        let used0 = self.accountant.used();
        let own_pins0 = self.cache.as_ref().map(|c| c.stats().pinned_bytes).unwrap_or(0);
        let victim_pins0 = self.gate.victim_pinned_bytes();
        self.accountant.reset_peak_to_used();
        let env = PassEnv { gate: &self.gate, cache: self.cache.as_ref(), plan: &self.plan };
        let r = run_pass(&self.ctx, opts, &env, input);
        if r.is_err() {
            if self.owns_accountant {
                // A failed pass can leave in-flight bytes accounted; drop
                // any pins and restart the accounting wholesale.
                if let Some(c) = &self.cache {
                    c.clear();
                }
                self.accountant.reset();
            } else {
                // Shared accountant: other sessions' pins and residents are
                // still accounted in it, so release exactly what this pass
                // left behind — our pins plus any in-flight bytes — and
                // clear the shutdown the failed pass raised.  Other
                // sessions' bytes after the pass = what they held before,
                // minus any of their pins we evicted while running; the
                // router runs one pass at a time, so the snapshots are
                // exact.
                if let Some(c) = &self.cache {
                    c.drain(&self.accountant);
                }
                let victims_evicted =
                    victim_pins0.saturating_sub(self.gate.victim_pinned_bytes());
                let others_now = used0.saturating_sub(own_pins0).saturating_sub(victims_evicted);
                let leaked = self.accountant.used().saturating_sub(others_now);
                if leaked > 0 {
                    self.accountant.free(leaked);
                }
                self.accountant.revive();
            }
        } else {
            self.passes_run += 1;
        }
        r
    }

    /// Baseline mode: load the whole model once per session, then run
    /// resident forwards (the paper's non-pipeline comparator).
    fn baseline_forward(&mut self, input: &ModelInput) -> Result<(xla::PjRtBuffer, PassStats)> {
        if self.resident.is_none() {
            self.resident = Some(baseline::load_all(&self.ctx, &self.accountant)?);
        }
        self.accountant.reset_peak_to_used();
        let model = self.resident.as_ref().unwrap();
        let r = baseline::forward_resident(&self.ctx, model, &self.accountant, input);
        if r.is_ok() {
            self.passes_run += 1;
        }
        r
    }
}
