//! Reusable inference sessions: setup once, many passes.
//!
//! [`Engine::run`] reproduces the paper's per-run semantics — resolve the
//! profile, validate weights, AOT-prepare, build channels/threads, run one
//! request.  A serving loop doing that per batch (and a decode loop doing
//! it per token) pays the setup tax on every hot-path iteration.
//!
//! A [`Session`] hoists everything that survives a pass out of the loop:
//!
//! * profile resolution + weight generation/validation + [`Runtime::prepare`]
//!   run **exactly once** at [`Engine::open_session`];
//! * the [`MemoryAccountant`] persists, so the budget (and any pinned
//!   hot layers) carries across passes;
//! * the [`OrderedGate`] is rearmed with `reset()` instead of rebuilt;
//! * the stage-to-agent [`assignment`] is precomputed;
//! * an optional hot-layer [`LayerCache`] (`RunConfig::pin_budget`) lets
//!   the Daemon pin computed layers instead of destroying them, so the
//!   next decode token / serve batch skips disk for pinned stages;
//! * an optional paged [`KvPool`] (`RunConfig::kv_cache` /
//!   `RunConfig::kv_budget`) holds attention state for GPT-style decode:
//!   [`Session::run_batch`] then runs ONE full-prefix pass (priming a
//!   [`KvSeq`] via the `*_kv` entries) and incremental single-token
//!   passes for the rest, falling back to full-prefix recompute whenever
//!   blocks are denied or evicted — tokens never depend on residency.
//!
//! The pin budget is capped at `budget - max_stage_bytes` so a stalled
//! admission can always make progress: pinned-but-in-flight stages later
//! in the admission order are not evictable, so at least one unpinned
//! stage must always fit beside them (liveness; see `pipeload::gate`).
//!
//! # Shared accountants (multi-model serving)
//!
//! By default a session creates its own [`MemoryAccountant`] from
//! `RunConfig::budget`.  [`Engine::open_session_shared`] (or
//! [`SessionBuilder::accountant`]) opens the session against a
//! caller-supplied accountant instead, so N sessions — one per model
//! profile — contend for a single device-wide budget; the shared budget
//! outranks `RunConfig::budget`.  [`Session::add_eviction_victim`] lets one
//! session's `S^stop` pressure reclaim another session's pinned hot layers
//! (the [`crate::server::Router`] wires every pair).  Config validation is
//! centralized here through [`RunConfig::validate`], so every entrypoint
//! rejects bad configs with the same message.
//!
//! [`Runtime::prepare`]: crate::runtime::Runtime::prepare
//! [`assignment`]: crate::pipeload::assignment

use std::time::Instant;

use anyhow::Result;

use super::{argmax_rows, argmax_rows_flat, last_logits, make_input, push_tokens, Engine, RunOutput};
use crate::baseline;
use crate::baseline::ResidentModel;
use crate::config::{Mode, RunConfig};
use crate::diskio::Disk;
use crate::kvcache::{KvPool, KvPoolStats, KvSeq};
use crate::memory::MemoryAccountant;
use crate::metrics::RunReport;
use crate::model::Profile;
use crate::pipeload::assignment::assignment;
use crate::pipeload::cache::{CacheStats, LayerCache};
use crate::pipeload::gate::OrderedGate;
use crate::pipeload::{
    run_pass_mode, ExecCtx, ModelInput, PassEnv, PassMode, PassStats, PipelineOpts,
    KV_EVICTED_MIDPASS,
};
use crate::trace::Tracer;

/// Long-lived pipeline state for one (profile, mode, budget) configuration.
/// Obtained from [`Engine::open_session`]; run requests with
/// [`Session::run`] / [`Session::run_batch`].
pub struct Session<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    ctx: ExecCtx<'e>,
    /// None for Baseline (non-pipelined) mode
    opts: Option<PipelineOpts>,
    accountant: MemoryAccountant,
    /// false when the accountant was supplied by the caller (shared across
    /// sessions, e.g. by a [`crate::server::Router`]) — error recovery must
    /// then release only this session's bytes, never reset wholesale.
    owns_accountant: bool,
    gate: OrderedGate,
    plan: Vec<Vec<usize>>,
    cache: Option<LayerCache>,
    /// Paged KV pool (Some when `kv_cache` is on and the profile ships the
    /// incremental decode entries); blocks charge the session accountant.
    kv_pool: Option<KvPool>,
    /// Other lanes' KV pools registered as eviction victims (snapshots for
    /// shared-accountant error recovery).
    kv_victims: Vec<KvPool>,
    /// Baseline mode: the whole model, loaded on first use
    resident: Option<ResidentModel>,
    prepared_entries: usize,
    passes_run: usize,
    /// decode tokens served by incremental passes (cache hits)
    kv_inc_total: u64,
    /// decode tokens that fell back to full-prefix recompute after the
    /// cache was primed (eviction or exhausted KV budget)
    kv_recompute_total: u64,
}

/// Options for opening a [`Session`] — sugar methods on [`Engine`] cover
/// the common cases ([`Engine::open_session`],
/// [`Engine::open_session_shared`]); the builder composes them.
///
/// ```ignore
/// let shared = MemoryAccountant::new(Some(budget));
/// let mut s = engine.session(&cfg).accountant(&shared).tracer(&t).open()?;
/// ```
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    cfg: RunConfig,
    tracer: Tracer,
    accountant: Option<MemoryAccountant>,
}

impl<'e> SessionBuilder<'e> {
    /// Record spans into a caller-supplied tracer (shared buffer), so the
    /// caller can render Gantt charts / stall stats afterwards.
    pub fn tracer(mut self, tracer: &Tracer) -> SessionBuilder<'e> {
        self.tracer = tracer.clone();
        self
    }

    /// Account this session's memory into a caller-supplied accountant
    /// instead of a private one.  The accountant's budget (not
    /// `RunConfig::budget`) constrains the session, so N sessions opened
    /// against the same accountant contend for one device-wide budget.
    pub fn accountant(mut self, accountant: &MemoryAccountant) -> SessionBuilder<'e> {
        self.accountant = Some(accountant.clone());
        self
    }

    pub fn open(self) -> Result<Session<'e>> {
        Session::open(self.engine, &self.cfg, &self.tracer, self.accountant)
    }
}

impl Engine {
    /// Start building a session; finish with [`SessionBuilder::open`].
    pub fn session(&self, cfg: &RunConfig) -> SessionBuilder<'_> {
        SessionBuilder {
            engine: self,
            cfg: cfg.clone(),
            tracer: Tracer::new(cfg.trace),
            accountant: None,
        }
    }

    /// Open a reusable session: profile resolution, weight generation, and
    /// AOT prepare happen here, once, instead of per run.
    pub fn open_session(&self, cfg: &RunConfig) -> Result<Session<'_>> {
        self.session(cfg).open()
    }

    /// Like [`Engine::open_session`] but records into a caller-supplied
    /// tracer (shared buffer), so callers can render Gantt charts.
    pub fn open_session_with(&self, cfg: &RunConfig, tracer: &Tracer) -> Result<Session<'_>> {
        self.session(cfg).tracer(tracer).open()
    }

    /// Open a session against a **shared** accountant: the session's loads
    /// and pins are admitted under `accountant`'s budget, alongside every
    /// other session opened against it.  `cfg.budget` is ignored (the
    /// shared budget outranks it).  This is the multi-model serving
    /// primitive: one `Session` per profile, one global budget.
    pub fn open_session_shared(
        &self,
        cfg: &RunConfig,
        accountant: &MemoryAccountant,
    ) -> Result<Session<'_>> {
        self.session(cfg).accountant(accountant).open()
    }
}

impl<'e> Session<'e> {
    fn open(
        engine: &'e Engine,
        cfg: &RunConfig,
        tracer: &Tracer,
        shared: Option<MemoryAccountant>,
    ) -> Result<Session<'e>> {
        let profile = engine.runtime.profile(&cfg.profile)?;
        // Central validation: every entrypoint (run / serve / Router / TCP)
        // opens a session, so every entrypoint rejects bad configs with the
        // same message.  A shared accountant's budget is the binding one.
        let budget = match &shared {
            Some(a) => a.budget(),
            None => cfg.budget,
        };
        cfg.validate_with_budget(profile, budget)?;
        engine.ensure_weights(&cfg.profile)?;
        let disk = Disk::preset(&cfg.disk)?;
        let mut ctx = ExecCtx::new(&engine.runtime, &cfg.profile, &engine.paths.weights, disk)?;
        ctx.tracer = tracer.clone();
        ctx.batch = cfg.batch;
        // compile off the measured path (the paper's pre-run) — once
        let prepared_entries = engine.runtime.prepare(profile)?;

        let opts = match cfg.mode {
            Mode::Baseline => None,
            Mode::PipeSwitch => Some(PipelineOpts::pipeswitch()),
            Mode::PipeLoad => Some(PipelineOpts::pipeload(cfg.agents)),
        };
        let owns_accountant = shared.is_none();
        let accountant = shared.unwrap_or_else(|| MemoryAccountant::new(cfg.budget));
        let cache = Self::build_cache(cfg, profile, budget);
        let mut gate = match &cache {
            Some(c) => OrderedGate::with_cache(accountant.clone(), c.clone()),
            None => OrderedGate::new(accountant.clone()),
        };
        let kv_pool = Self::build_kv_pool(cfg, profile, &accountant);
        if let Some(pool) = &kv_pool {
            // this session's own weight admissions may reclaim its KV
            // blocks under S^stop pressure (after pinned layers)
            gate.add_kv_pool(pool.clone());
        }
        let agents = opts.as_ref().map(|o| o.agents.max(1)).unwrap_or(1);
        let plan = assignment(profile.stages.len(), agents);
        Ok(Session {
            engine,
            cfg: cfg.clone(),
            ctx,
            opts,
            accountant,
            owns_accountant,
            gate,
            plan,
            cache,
            kv_pool,
            kv_victims: Vec::new(),
            resident: None,
            prepared_entries,
            passes_run: 0,
            kv_inc_total: 0,
            kv_recompute_total: 0,
        })
    }

    /// Paged KV pool construction: only when the extension is on, the mode
    /// is pipelined, and the profile's artifacts ship the incremental
    /// decode entries (GPT-style families; BART/encoder profiles fall
    /// back to full-prefix decode even with `--kv-cache`).
    fn build_kv_pool(
        cfg: &RunConfig,
        profile: &Profile,
        accountant: &MemoryAccountant,
    ) -> Option<KvPool> {
        if !cfg.kv_cache || cfg.mode == Mode::Baseline || !profile.is_generative() {
            return None;
        }
        let body_inc = format!("{}_inc@", profile.body_kind());
        if !profile.entries.keys().any(|k| k.starts_with(&body_inc)) {
            return None;
        }
        Some(KvPool::new(accountant.clone(), cfg.kv_budget))
    }

    /// Hot-layer cache sizing.  Only PIPELOAD destroys layers, so only it
    /// can pin; the pin budget is clipped below `budget - max_stage` so an
    /// unpinned admission always fits beside in-flight pinned stages.
    fn build_cache(cfg: &RunConfig, profile: &Profile, budget: Option<u64>) -> Option<LayerCache> {
        if cfg.mode != Mode::PipeLoad {
            return None;
        }
        let mut pin = cfg.pin_budget.unwrap_or(0);
        if let Some(budget) = budget {
            let max_stage =
                profile.stages.iter().map(|s| profile.stage_bytes(s)).max().unwrap_or(0);
            pin = pin.min(budget.saturating_sub(max_stage));
        }
        if pin == 0 {
            None
        } else {
            Some(LayerCache::with_policy(pin, cfg.pin_policy))
        }
    }

    pub fn profile(&self) -> &Profile {
        self.ctx.profile
    }

    /// Entries compiled by the session's single prepare call.
    pub fn prepared_entries(&self) -> usize {
        self.prepared_entries
    }

    /// Pipeline passes executed so far (tokens count individually).
    pub fn passes_run(&self) -> usize {
        self.passes_run
    }

    /// Hot-layer cache counters (zeros when no cache is attached).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// The accountant this session admits memory through (shared when the
    /// session was opened via [`Engine::open_session_shared`]).
    pub fn accountant(&self) -> &MemoryAccountant {
        &self.accountant
    }

    /// The session's hot-layer cache handle, if one is attached.
    pub fn layer_cache(&self) -> Option<&LayerCache> {
        self.cache.as_ref()
    }

    /// The configuration this session was opened with.
    pub fn run_config(&self) -> &RunConfig {
        &self.cfg
    }

    /// The session's paged KV pool, if the KV-cache extension is active.
    pub fn kv_pool(&self) -> Option<&KvPool> {
        self.kv_pool.as_ref()
    }

    /// KV pool counters (zeros when no pool is attached).
    pub fn kv_pool_stats(&self) -> KvPoolStats {
        self.kv_pool.as_ref().map(|p| p.stats()).unwrap_or_default()
    }

    /// Cumulative (incremental passes, full-prefix recomputes) across this
    /// session's decode loops — the `Runtime::prepare_calls`-style counters
    /// tests assert pass-shape with.
    pub fn kv_counters(&self) -> (u64, u64) {
        (self.kv_inc_total, self.kv_recompute_total)
    }

    /// Register another session's hot-layer cache as an eviction target:
    /// when an admission here stalls on the (shared) budget, it reclaims
    /// that session's pins after its own.  Only meaningful — and only
    /// sound — between sessions opened against the same shared accountant.
    pub fn add_eviction_victim(&mut self, cache: LayerCache) {
        self.gate.add_victim(cache);
    }

    /// Register another session's KV pool as an eviction target (same
    /// shared-accountant requirement as [`Session::add_eviction_victim`]).
    /// The victim lane's evicted sequences fall back to full-prefix
    /// recompute — degraded, never wrong.
    ///
    /// NOTE: under today's per-request KV lifecycle (blocks freed when
    /// `run_batch` returns) a victim pool is empty whenever this lane
    /// runs a pass, so cross-lane KV eviction cannot fire yet.  It is
    /// wired — and the failed-pass recovery snapshots victim-KV bytes —
    /// so the accounting stays exact the day sequences outlive requests
    /// (the ROADMAP's prefix-sharing follow-up).
    pub fn add_kv_eviction_victim(&mut self, pool: KvPool) {
        self.kv_victims.push(pool.clone());
        self.gate.add_kv_pool(pool);
    }

    /// Run one request with the session's configured batch and seed.
    pub fn run(&mut self) -> Result<(RunReport, RunOutput)> {
        let (batch, seed) = (self.cfg.batch, self.cfg.seed);
        self.run_batch(batch, seed)
    }

    /// Run one request (a full forward, or a whole decode loop for
    /// generative profiles) at the given batch size.  Setup, compiled
    /// executables, budget, and pinned layers are reused across calls.
    ///
    /// With `--kv-cache` the decode loop runs ONE full-prefix pass (which
    /// primes a [`KvSeq`] through the `*_kv` entries) and then incremental
    /// single-token passes; a sequence evicted under `S^stop` pressure —
    /// or denied blocks by the KV budget — falls back to full-prefix
    /// recompute for that token and re-primes, so generated tokens are
    /// identical to the cache-off path regardless of cache residency.
    /// The sequence's blocks are freed when this call returns (per-request
    /// lifecycle; the Router relies on it).
    pub fn run_batch(&mut self, batch: usize, seed: u64) -> Result<(RunReport, RunOutput)> {
        let profile = self.ctx.profile;
        self.ctx.batch = batch;
        let (input, mut ids, prompt_len) = make_input(profile, batch, seed);
        let gen_tokens = if profile.is_generative() {
            self.cfg.gen_tokens.unwrap_or(profile.gen_tokens.max(1))
        } else {
            0
        };

        let t0 = Instant::now();
        let mut passes: Vec<PassStats> = Vec::new();
        let mut generated = Vec::new();
        let mut generated_rows: Vec<Vec<i32>> = Vec::new();
        let mut head: Vec<f32> = Vec::new();
        let mut kv_inc = 0u64;
        let mut kv_rec = 0u64;
        let kv_evicted0 = self.kv_pool_stats().evicted_blocks;

        if !profile.is_generative() {
            let (out, stats) = if self.opts.is_none() {
                self.baseline_forward(&input)?
            } else {
                self.pass(&input)?
            };
            head = self.engine.runtime.buffer_to_f32(&out)?;
            passes.push(stats);
        } else {
            generated_rows = vec![Vec::new(); batch];
            let kv_enabled = self.kv_pool.is_some()
                && self.opts.is_some()
                && profile.entry("embedding_inc", batch).is_ok()
                && profile.entry(&format!("{}_inc", profile.body_kind()), batch).is_ok()
                && profile.entry(&format!("{}_kv", profile.body_kind()), batch).is_ok()
                && profile.entry("lm_head_inc", batch).is_ok();
            let n_body = profile.stages.iter().filter(|s| s.kind == profile.body_kind()).count();
            let mut kv_seq: Option<KvSeq> = None;
            let mut last_next: Vec<i32> = Vec::new();
            let mut cur_len = prompt_len;

            for step in 0..gen_tokens {
                // Incremental when the cached prefix lines up exactly with
                // the ids (tokens == cur_len - 1: everything but the token
                // appended after the previous pass) and one more block row
                // can be reserved.  Anything else recomputes full-prefix.
                let can_inc = kv_enabled
                    && step > 0
                    && last_next.len() == batch
                    && cur_len <= profile.max_seq
                    && kv_seq
                        .as_ref()
                        .map(|s| s.valid() && s.tokens() + 1 == cur_len && s.reserve(cur_len))
                        .unwrap_or(false);

                let mut step_out: Option<(Vec<f32>, bool, PassStats)> = None;
                if can_inc {
                    let seq = kv_seq.as_ref().unwrap();
                    let inp = ModelInput::Ids(last_next.clone());
                    let pos = cur_len - 1;
                    match self.pass_mode(&inp, &PassMode::Incremental { kv: seq, pos }) {
                        Ok((out, stats)) => {
                            seq.set_tokens(cur_len);
                            kv_inc += 1;
                            let logits = self.engine.runtime.buffer_to_f32(&out)?;
                            step_out = Some((logits, true, stats));
                        }
                        Err(e) => {
                            // Mid-pass eviction is the ONLY recoverable
                            // failure: the token was not produced, so fall
                            // through to a full-prefix recompute.  Matched
                            // by marker, not by `seq.valid()` — the error
                            // recovery in `pass_mode` invalidates every
                            // sequence on ANY failure, so validity cannot
                            // distinguish eviction from a real error.
                            let evicted = e
                                .chain()
                                .any(|c| c.to_string().contains(KV_EVICTED_MIDPASS));
                            if !evicted {
                                return Err(e);
                            }
                        }
                    }
                }
                let (logits, incremental, stats) = match step_out {
                    Some(x) => x,
                    None => {
                        // Count a recompute only where a cache COULD have
                        // served (within max_seq); overrun steps are plain
                        // full passes on either path, not cache misses.
                        if kv_enabled && step > 0 && cur_len <= profile.max_seq {
                            kv_rec += 1; // primed cache could not serve this token
                        }
                        // (re)prime: a fresh sequence, if blocks are grantable
                        let mut primed = false;
                        if kv_enabled && cur_len <= profile.max_seq {
                            kv_seq = None; // free any stale sequence first
                            let pool = self.kv_pool.as_ref().unwrap();
                            let seq = pool.open_seq(n_body, batch, profile.hidden);
                            if seq.reserve(cur_len) {
                                kv_seq = Some(seq);
                                primed = true;
                            }
                        }
                        let inp = ModelInput::Ids(ids.clone());
                        let (out, stats) = if self.opts.is_none() {
                            self.baseline_forward(&inp)?
                        } else if primed {
                            let mode = PassMode::PrimeKv {
                                kv: kv_seq.as_ref().unwrap(),
                                prefix_len: cur_len,
                            };
                            let r = self.pass_mode(&inp, &mode)?;
                            kv_seq.as_ref().unwrap().set_tokens(cur_len);
                            r
                        } else {
                            self.pass(&inp)?
                        };
                        (self.engine.runtime.buffer_to_f32(&out)?, false, stats)
                    }
                };

                let next = if incremental {
                    argmax_rows_flat(&logits, profile.vocab, batch)
                } else {
                    argmax_rows(&logits, profile, batch, cur_len)
                };
                push_tokens(&mut ids, profile, cur_len, &next);
                generated.push(next[0]);
                for (row, t) in next.iter().enumerate() {
                    generated_rows[row].push(*t);
                }
                cur_len += 1;
                head = if incremental {
                    logits[..profile.vocab].to_vec()
                } else {
                    last_logits(&logits, profile, cur_len - 1)
                };
                last_next = next;
                passes.push(stats);
            }
            // request over: blocks go back to the budget here
            drop(kv_seq);
        }
        let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
        self.kv_inc_total += kv_inc;
        self.kv_recompute_total += kv_rec;

        let report = RunReport {
            model: self.cfg.profile.clone(),
            mode: self.cfg.mode.name().to_string(),
            agents: if self.cfg.mode == Mode::PipeLoad { self.cfg.agents } else { 1 },
            latency_ms,
            peak_bytes: passes.iter().map(|p| p.peak_bytes).max().unwrap_or(0),
            mem_stall_ms: passes.iter().map(|p| p.mem_stall_ms).sum(),
            wait_stall_ms: passes.iter().map(|p| p.wait_stall_ms).sum(),
            idle_fraction: self.ctx.tracer.inference_idle_fraction().unwrap_or(0.0),
            tokens: generated.len(),
            cache_hits: passes.iter().map(|p| p.cache_hits).sum(),
            cache_misses: passes.iter().map(|p| p.cache_misses).sum(),
            kv_inc_passes: kv_inc,
            kv_recomputes: kv_rec,
            kv_evicted_blocks: self.kv_pool_stats().evicted_blocks - kv_evicted0,
        };
        head.truncate(16);
        Ok((report, RunOutput { generated, generated_rows, head_sample: head }))
    }

    /// One pipelined pass over persistent session state.
    fn pass(&mut self, input: &ModelInput) -> Result<(xla::PjRtBuffer, PassStats)> {
        self.pass_mode(input, &PassMode::Full)
    }

    /// [`Session::pass`] with an explicit [`PassMode`] (KV decode paths).
    fn pass_mode(
        &mut self,
        input: &ModelInput,
        mode: &PassMode,
    ) -> Result<(xla::PjRtBuffer, PassStats)> {
        let opts = self.opts.as_ref().expect("pass() requires a pipelined mode");
        self.gate.reset();
        // Snapshots for shared-accountant error recovery (see below).
        let used0 = self.accountant.used();
        let own_pins0 = self.cache.as_ref().map(|c| c.stats().pinned_bytes).unwrap_or(0);
        let own_kv0 = self.kv_pool.as_ref().map(|p| p.used_bytes()).unwrap_or(0);
        let victim_pins0 = self.gate.victim_pinned_bytes();
        let victim_kv0: u64 = self.kv_victims.iter().map(|p| p.used_bytes()).sum();
        self.accountant.reset_peak_to_used();
        let env = PassEnv { gate: &self.gate, cache: self.cache.as_ref(), plan: &self.plan };
        let r = run_pass_mode(&self.ctx, opts, &env, input, mode);
        if r.is_err() {
            if self.owns_accountant {
                // A failed pass can leave in-flight bytes accounted; drop
                // any pins and cached KV, then restart the accounting
                // wholesale (the pool frees BEFORE the reset so its own
                // byte tracking stays consistent with the accountant's).
                if let Some(c) = &self.cache {
                    c.clear();
                }
                if let Some(p) = &self.kv_pool {
                    p.invalidate_all();
                }
                self.accountant.reset();
            } else {
                // Shared accountant: other sessions' pins and residents are
                // still accounted in it, so release exactly what this pass
                // left behind — our pins, our KV blocks, and any in-flight
                // bytes — and clear the shutdown the failed pass raised.
                // Other sessions' bytes after the pass = what they held
                // before, minus any of their pins/KV we evicted while
                // running; the router runs one pass at a time, so the
                // snapshots are exact.
                if let Some(c) = &self.cache {
                    c.drain(&self.accountant);
                }
                if let Some(p) = &self.kv_pool {
                    p.invalidate_all();
                }
                let victims_evicted =
                    victim_pins0.saturating_sub(self.gate.victim_pinned_bytes());
                let victim_kv_now: u64 = self.kv_victims.iter().map(|p| p.used_bytes()).sum();
                let victim_kv_evicted = victim_kv0.saturating_sub(victim_kv_now);
                let others_now = used0
                    .saturating_sub(own_pins0)
                    .saturating_sub(own_kv0)
                    .saturating_sub(victims_evicted)
                    .saturating_sub(victim_kv_evicted);
                let leaked = self.accountant.used().saturating_sub(others_now);
                if leaked > 0 {
                    self.accountant.free(leaked);
                }
                self.accountant.revive();
            }
        } else {
            self.passes_run += 1;
        }
        r
    }

    /// Baseline mode: load the whole model once per session, then run
    /// resident forwards (the paper's non-pipeline comparator).
    fn baseline_forward(&mut self, input: &ModelInput) -> Result<(xla::PjRtBuffer, PassStats)> {
        if self.resident.is_none() {
            self.resident = Some(baseline::load_all(&self.ctx, &self.accountant)?);
        }
        self.accountant.reset_peak_to_used();
        let model = self.resident.as_ref().unwrap();
        let r = baseline::forward_resident(&self.ctx, model, &self.accountant, input);
        if r.is_ok() {
            self.passes_run += 1;
        }
        r
    }
}
