//! `hermes` CLI — leader entrypoint for the Hermes framework.
//!
//! Subcommands mirror the framework components (paper Fig. 6):
//!
//! * `gen-weights` — synthesize `.hws` stage shards for a profile
//! * `profile`     — Layer Profiler pre-run (per-layer load/compute/mem)
//! * `plan`        — Pipeline Planner: budgets -> optimal #Loading-Agents
//! * `run`         — Execution Engine: one run in a chosen mode
//! * `serve`       — batched serving session with SLO report
//! * `report`      — regenerate the paper's tables and figures
//! * `list`        — show available model profiles

use anyhow::{bail, Result};

use hermes::analyze::Analysis;
use hermes::config::{Mode, PinPolicy, RunConfig};
use hermes::elastic::PressureTrace;
use hermes::engine::Engine;
use hermes::planner;
use hermes::report;
use hermes::server::{serve, RouterConfig, ServeConfig, TcpFrontend};
use hermes::telemetry::{chrome, Telemetry};
use hermes::trace::Tracer;
use hermes::util::cli::{render_help, Args, Opt};
use hermes::util::{human_bytes, human_ms};

/// The shared `--trace-out` option (run / serve / report --figure 1b).
fn trace_out_opt() -> Opt {
    Opt {
        name: "trace-out",
        takes_value: true,
        default: None,
        help: "write a Chrome trace-event JSON of the run here (load into Perfetto or chrome://tracing)",
    }
}

/// An enabled bus when `--trace-out` was passed, the near-free disabled
/// bus otherwise.
fn telemetry_for(a: &Args) -> Telemetry {
    if a.get("trace-out").is_some() {
        Telemetry::on()
    } else {
        Telemetry::off()
    }
}

/// Drain the event bus into the `--trace-out` file.  No-op without the
/// flag.
fn write_trace_out(a: &Args, telemetry: &Telemetry) -> Result<()> {
    let Some(path) = a.get("trace-out") else {
        return Ok(());
    };
    let events = telemetry.drain();
    let dropped = telemetry.dropped();
    chrome::write_chrome_trace(std::path::Path::new(path), &events, dropped)?;
    eprintln!(
        "hermes: wrote {} trace event(s) -> {path}{}",
        events.len(),
        if dropped > 0 { format!(" ({dropped} dropped: ring full)") } else { String::new() }
    );
    Ok(())
}

/// End-of-run telemetry-loss report: bus-ring drops plus per-subscriber
/// drops (a slow in-process consumer sheds events rather than stalling
/// the emitters — but shed events must be visible, never silent).
fn print_telemetry_drops(telemetry: &Telemetry) {
    let dropped = telemetry.dropped();
    if dropped > 0 {
        println!("  telemetry: {dropped} event(s) dropped (ring full)");
    }
    for (label, n) in telemetry.subscriber_drops() {
        if n > 0 {
            println!("  telemetry: subscriber '{label}' dropped {n} event(s)");
        }
    }
}

/// Attach the same loss counters to a machine-readable summary.
fn with_telemetry_drops(v: hermes::util::json::Value, telemetry: &Telemetry) -> hermes::util::json::Value {
    let mut subs = hermes::util::json::Value::obj();
    for (label, n) in telemetry.subscriber_drops() {
        subs = subs.set(&label, n);
    }
    v.set("telemetry_dropped_events", telemetry.dropped()).set("subscriber_drops", subs)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_usage();
        return;
    }
    let cmd = argv[0].clone();
    let rest = argv[1..].to_vec();
    let code = match dispatch(&cmd, &rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "hermes — memory-efficient PIPELOAD pipeline inference (paper reproduction)\n\n\
         usage: hermes <command> [options]\n\n\
         commands:\n\
           list          show model profiles from the AOT manifest\n\
           gen-weights   synthesize .hws stage shards for a profile\n\
           profile       Layer Profiler: per-layer load/compute/memory\n\
           plan          Pipeline Planner: budgets -> optimal #LAs\n\
           run           Execution Engine: one run (baseline|pipeswitch|pipeload)\n\
           serve         serving session: synthetic workload, or a multi-model\n\
                         TCP front-end (--listen) with a shared memory budget\n\
           report        regenerate paper tables (1,2,3) / figures (1b,2,3,7)\n\
           analyze       trace analytics: request lifecycle breakdown, per-stage\n\
                         bubble/critical-path attribution, memory-audit check\n\
                         (reads a --trace-out JSON, or runs + analyzes in one go)\n\n\
         run `hermes <command> --help` for per-command options"
    );
}

fn common_opts() -> Vec<Opt> {
    vec![
        Opt { name: "model", takes_value: true, default: Some("bert-large-sim"), help: "model profile name (see `hermes list`)" },
        Opt { name: "disk", takes_value: true, default: Some("edge-emmc"), help: "storage preset: edge-emmc|edge-sd|edge-nvme|unthrottled" },
        Opt { name: "seed", takes_value: true, default: Some("42"), help: "input seed" },
        Opt { name: "help", takes_value: false, default: None, help: "show help" },
    ]
}

fn dispatch(cmd: &str, rest: &[String]) -> Result<()> {
    match cmd {
        "list" => cmd_list(),
        "gen-weights" => cmd_gen_weights(rest),
        "profile" => cmd_profile(rest),
        "plan" => cmd_plan(rest),
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "report" => cmd_report(rest),
        "analyze" => cmd_analyze(rest),
        _ => bail!("unknown command '{cmd}' (try --help)"),
    }
}

fn cmd_list() -> Result<()> {
    let engine = Engine::with_default_paths()?;
    let mut names: Vec<&String> = engine.runtime.manifest.profiles.keys().collect();
    names.sort();
    println!("{:<18} {:>8} {:>8} {:>12}  {}", "profile", "stages", "layers", "weights", "paper model");
    for n in names {
        let p = engine.runtime.profile(n)?;
        println!(
            "{:<18} {:>8} {:>8} {:>12}  {}",
            p.name,
            p.stages.len(),
            p.layers,
            human_bytes(p.total_weight_bytes),
            p.paper_model
        );
    }
    Ok(())
}

fn cmd_gen_weights(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "force", takes_value: false, default: None, help: "overwrite existing shards" });
    opts.push(Opt { name: "all", takes_value: false, default: None, help: "generate every profile" });
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!("{}", render_help("gen-weights", "synthesize stage shards", &opts));
        return Ok(());
    }
    let engine = Engine::with_default_paths()?;
    let names: Vec<String> = if a.flag("all") {
        engine.runtime.manifest.profiles.keys().cloned().collect()
    } else {
        vec![a.req("model")?.to_string()]
    };
    for name in names {
        let p = engine.runtime.profile(&name)?;
        let bytes = hermes::weights::gen::gen_profile_weights(
            p,
            &engine.paths.weights,
            hermes::engine::WEIGHTS_SEED,
            0.05,
            a.flag("force"),
        )?;
        println!("{name}: {} of shards in {}", human_bytes(bytes), engine.paths.weights.display());
    }
    Ok(())
}

fn cmd_profile(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "out", takes_value: true, default: None, help: "write profile JSON here" });
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!("{}", render_help("profile", "Layer Profiler pre-run", &opts));
        return Ok(());
    }
    let engine = Engine::with_default_paths()?;
    let model = a.req("model")?;
    let mp = report::profile_one(&engine, model, a.req("disk")?)?;
    let p = engine.runtime.profile(model)?;
    let (l, c, b) = mp.body_means(p.body_kind());
    println!("{model} on disk={}", mp.disk);
    println!("  body layers: load {} / compute {} per layer ({} each)", human_ms(l), human_ms(c), human_bytes(b));
    println!("  load/compute ratio: {:.1}x", mp.load_compute_ratio(p.body_kind()));
    println!("  totals: load {}  compute {}", human_ms(mp.total_load_ms()), human_ms(mp.total_compute_ms()));
    let out = a
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| engine.paths.results.join(format!("profile_{model}.json")));
    mp.save(&out)?;
    println!("  saved -> {}", out.display());
    Ok(())
}

fn cmd_plan(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "budgets-mb", takes_value: true, default: None, help: "comma-separated budgets in MB (default: fractions of model size)" });
    opts.push(Opt { name: "max-agents", takes_value: true, default: Some("8"), help: "largest LA count to consider" });
    opts.push(Opt { name: "analytic", takes_value: false, default: None, help: "skip empirical pre-runs" });
    opts.push(Opt { name: "out", takes_value: true, default: None, help: "write schedule JSON here" });
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!("{}", render_help("plan", "Pipeline Planner", &opts));
        return Ok(());
    }
    let engine = Engine::with_default_paths()?;
    let model = a.req("model")?;
    let stats = report::profile_one(&engine, model, a.req("disk")?)?;
    let p = engine.runtime.profile(model)?;
    let budgets: Vec<u64> = if let Some(_) = a.get("budgets-mb") {
        a.list("budgets-mb")
            .iter()
            .map(|s| Ok((s.parse::<f64>()? * 1024.0 * 1024.0) as u64))
            .collect::<Result<_>>()?
    } else {
        let min = planner::min_feasible_budget(&stats, p.body_kind());
        [0.15, 0.25, 0.4, 0.6, 0.8]
            .iter()
            .map(|f| ((p.total_weight_bytes as f64 * f) as u64).max(min))
            .collect()
    };
    let sched = planner::plan(&engine, &stats, &budgets, a.usize("max-agents")?, !a.flag("analytic"))?;
    println!("schedule for {model} (disk={}):", sched.disk);
    for e in &sched.entries {
        println!(
            "  budget {:>10} -> {} LAs  (latency {} predicted{}, peak {} predicted{})",
            human_bytes(e.budget_bytes),
            e.agents,
            human_ms(e.predicted_latency_ms),
            e.measured_latency_ms.map(|m| format!(", {} measured", human_ms(m))).unwrap_or_default(),
            human_bytes(e.predicted_peak_bytes),
            e.measured_peak_bytes.map(|m| format!(", {} measured", human_bytes(m))).unwrap_or_default(),
        );
    }
    let out = a
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| engine.paths.results.join(format!("schedule_{model}.json")));
    sched.save(&out)?;
    println!("saved -> {}", out.display());
    Ok(())
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "mode", takes_value: true, default: Some("pipeload"), help: "baseline|pipeswitch|pipeload" });
    opts.push(Opt { name: "agents", takes_value: true, default: Some("4"), help: "number of Loading Agents (pipeload)" });
    opts.push(Opt { name: "budget-mb", takes_value: true, default: None, help: "memory budget in MB" });
    opts.push(Opt { name: "pin-budget-mb", takes_value: true, default: None, help: "hot-layer cache pin budget in MB (pipeload: keep layers resident across decode tokens when the budget has slack)" });
    opts.push(Opt { name: "pin-policy", takes_value: true, default: Some("fifo"), help: "hot-layer pin policy: fifo (compute order) | cost (keep layers by reload-cost per byte)" });
    opts.push(Opt { name: "kv-cache", takes_value: false, default: None, help: "paged KV cache: decode runs 1 full-prefix pass + incremental single-token passes (GPT-style profiles)" });
    opts.push(Opt { name: "kv-budget-mb", takes_value: true, default: None, help: "KV pool cap in MB (with --kv-cache; pin + kv must fit --budget-mb)" });
    opts.push(Opt { name: "kv-block-tokens", takes_value: true, default: None, help: "KV pool allocation granularity in tokens per block (with --kv-cache; >= 1)" });
    opts.push(Opt { name: "prefetch-depth", takes_value: true, default: Some("0"), help: "cross-pass prefetch: idle loaders preload this many head stages of the next decode pass (pipeload; 0 = off)" });
    opts.push(Opt { name: "no-device-cache", takes_value: false, default: None, help: "disable the device-resident layer cache (pinned stages then re-upload host->device every pass)" });
    opts.push(Opt { name: "batch", takes_value: true, default: Some("1"), help: "batch size (must be AOT-compiled)" });
    opts.push(Opt { name: "tokens", takes_value: true, default: None, help: "generated tokens (generative models)" });
    opts.push(Opt { name: "trace", takes_value: false, default: None, help: "print the execution Gantt chart" });
    opts.push(trace_out_opt());
    opts.push(Opt { name: "schedule", takes_value: true, default: None, help: "pick #LAs from a planner schedule JSON given --budget-mb (with --memory-trace, re-consulted on every budget step)" });
    opts.push(Opt { name: "memory-trace", takes_value: true, default: None, help: "elastic budget: JSON steps file {\"steps\":[{\"at_pass\":N,\"budget_mb\":X},...]}, or 'shrink-grow' to synthesize one from --budget-mb" });
    opts.push(Opt { name: "fault-plan", takes_value: true, default: None, help: "deterministic fault injection: JSON steps file/inline {\"steps\":[{\"at_pass\":N,\"kind\":\"disk_error\",...}]}, or compact 'kind@pass[xN][:lane][+ms];...' (kinds: disk_error|disk_slow|agent_panic|lane_death|acquire_fail|conn_drop)" });
    opts.push(Opt { name: "pass-timeout-ms", takes_value: true, default: None, help: "per-pass watchdog: quiesce a pass stuck longer than this (counts passes_timed_out; off by default)" });
    opts.push(Opt { name: "load-retries", takes_value: true, default: Some("2"), help: "bounded retries for transient shard-load failures (deterministic jittered backoff)" });
    opts.push(Opt { name: "retry-backoff-ms", takes_value: true, default: Some("1"), help: "base backoff between load retries (doubles per attempt, seeded jitter)" });
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!("{}", render_help("run", "Execution Engine", &opts));
        return Ok(());
    }
    let engine = Engine::with_default_paths()?;
    let budget = a.mb_bytes("budget-mb")?;
    let pin_budget = a.mb_bytes("pin-budget-mb")?;
    let mut agents = a.usize("agents")?;
    let mut schedule: Option<planner::Schedule> = None;
    if let Some(path) = a.get("schedule") {
        let sched = planner::Schedule::load(std::path::Path::new(path))?;
        let b = budget.ok_or_else(|| anyhow::anyhow!("--schedule needs --budget-mb"))?;
        let entry = sched
            .pick(b)
            .ok_or_else(|| anyhow::anyhow!("no schedule entry fits budget"))?;
        agents = entry.agents;
        println!("schedule picked {} LAs for budget {}", agents, human_bytes(b));
        schedule = Some(sched);
    }
    let memory_trace =
        a.get("memory-trace").map(|spec| PressureTrace::from_spec(spec, budget)).transpose()?;
    let cfg = RunConfig {
        profile: a.req("model")?.to_string(),
        mode: Mode::parse(a.req("mode")?)?,
        agents,
        budget,
        pin_budget,
        pin_policy: PinPolicy::parse(a.req("pin-policy")?)?,
        disk: a.req("disk")?.to_string(),
        batch: a.usize("batch")?,
        seed: a.u64("seed")?,
        trace: a.flag("trace"),
        gen_tokens: a.get("tokens").map(|s| s.parse()).transpose()?,
        kv_cache: a.flag("kv-cache"),
        kv_budget: a.mb_bytes("kv-budget-mb")?,
        kv_block_tokens: a.get("kv-block-tokens").map(|s| s.parse()).transpose()?,
        prefetch_depth: a.usize("prefetch-depth")?,
        device_cache: !a.flag("no-device-cache"),
        fault_plan: a.get("fault-plan").map(String::from),
        pass_timeout_ms: a.get("pass-timeout-ms").map(|s| s.parse()).transpose()?,
        load_retries: a.usize("load-retries")? as u32,
        retry_backoff_ms: a.u64("retry-backoff-ms")?,
        ..RunConfig::default()
    };
    let tracer = Tracer::new(cfg.trace);
    let mut builder = engine.session(&cfg).tracer(&tracer);
    if let Some(t) = memory_trace {
        builder = builder.memory_trace(t);
    }
    if let Some(s) = schedule {
        builder = builder.schedule(s);
    }
    let mut session = builder.open()?;
    let telemetry = telemetry_for(&a);
    session.set_telemetry(telemetry.clone());
    if let Some(plan) = &cfg.fault_plan {
        session.set_faults(hermes::faults::FaultInjector::from_arg(plan)?);
    }
    let (rep, out) = session.run()?;
    println!("model={} mode={} agents={}", rep.model, rep.mode, rep.agents);
    println!("  latency:    {}", human_ms(rep.latency_ms));
    println!("  peak mem:   {}", human_bytes(rep.peak_bytes));
    println!("  mem stalls: {}   wait stalls: {}", human_ms(rep.mem_stall_ms), human_ms(rep.wait_stall_ms));
    if rep.cache_hits + rep.cache_misses > 0 {
        println!(
            "  hot cache:  {} hits / {} misses ({:.0}% hit rate)",
            rep.cache_hits,
            rep.cache_misses,
            rep.cache_hit_rate() * 100.0
        );
    }
    if rep.kv_inc_passes + rep.kv_recomputes > 0 {
        println!(
            "  kv cache:   {} incremental passes / {} full recomputes ({} blocks evicted)",
            rep.kv_inc_passes, rep.kv_recomputes, rep.kv_evicted_blocks
        );
    }
    if rep.prefetched_stages + rep.device_cache_hits + rep.spawns_avoided > 0 {
        println!(
            "  overlap:    {} prefetched ({} wasted), {} device-cache hits, {} spawns avoided",
            rep.prefetched_stages, rep.prefetch_wasted, rep.device_cache_hits, rep.spawns_avoided
        );
    }
    if rep.tokens > 0 && rep.tokens_per_sec > 0.0 {
        println!(
            "  decode:     p50 {}  p95 {}  ({:.2} tokens/s)",
            human_ms(rep.decode_p50_ms),
            human_ms(rep.decode_p95_ms),
            rep.tokens_per_sec
        );
    }
    if rep.faults_injected + rep.load_retries + rep.passes_timed_out > 0 {
        println!(
            "  faults:     {} injected, {} load retries, {} passes timed out",
            rep.faults_injected, rep.load_retries, rep.passes_timed_out
        );
    }
    if rep.budget_steps > 0 {
        println!(
            "  elastic:    {} budget steps, {} evictions, {} re-plans",
            rep.budget_steps, rep.elastic_evictions, rep.replans
        );
        for ep in session.budget_epochs() {
            println!(
                "    pass {:>3}: budget {:>10} -> used {:>10}  ({} agents, pin cap {}{})",
                ep.at_pass,
                human_bytes(ep.budget_bytes),
                human_bytes(ep.used_after_bytes),
                ep.agents,
                human_bytes(ep.pin_cap_bytes),
                if ep.replanned { ", re-planned" } else { "" },
            );
        }
    }
    if rep.tokens > 0 {
        println!("  generated {} tokens: {:?}", rep.tokens, out.generated);
        if cfg.batch > 1 {
            for (row, toks) in out.generated_rows.iter().enumerate().skip(1) {
                println!("    row {row}: {toks:?}");
            }
        }
    }
    if !out.head_sample.is_empty() {
        let h: Vec<String> = out.head_sample.iter().take(6).map(|v| format!("{v:.4}")).collect();
        println!("  head sample: [{}]", h.join(", "));
    }
    if cfg.trace {
        println!("\n{}", tracer.ascii_gantt(100));
        println!("inference idle fraction: {:.0}%", tracer.inference_idle_fraction().unwrap_or(0.0) * 100.0);
    }
    write_trace_out(&a, &telemetry)?;
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "mode", takes_value: true, default: Some("pipeload"), help: "baseline|pipeswitch|pipeload" });
    opts.push(Opt { name: "agents", takes_value: true, default: Some("4"), help: "Loading Agents" });
    opts.push(Opt { name: "budget-mb", takes_value: true, default: None, help: "global memory budget in MB (shared by all models)" });
    opts.push(Opt { name: "pin-budget-mb", takes_value: true, default: None, help: "hot-layer cache pin budget in MB (pipeload)" });
    opts.push(Opt { name: "pin-policy", takes_value: true, default: Some("fifo"), help: "hot-layer pin policy: fifo | cost" });
    opts.push(Opt { name: "kv-cache", takes_value: false, default: None, help: "paged KV cache for generative lanes (incremental decode)" });
    opts.push(Opt { name: "kv-budget-mb", takes_value: true, default: None, help: "global KV allocation in MB, split across --kv-cache lanes (remainder to the first lane)" });
    opts.push(Opt { name: "kv-block-tokens", takes_value: true, default: None, help: "KV pool allocation granularity in tokens per block (with --kv-cache; >= 1)" });
    opts.push(Opt { name: "prefetch-depth", takes_value: true, default: Some("0"), help: "cross-pass prefetch depth for every lane (pipeload; 0 = off)" });
    opts.push(Opt { name: "no-device-cache", takes_value: false, default: None, help: "disable the device-resident layer cache" });
    opts.push(Opt { name: "memory-trace", takes_value: true, default: None, help: "elastic budget for the SHARED accountant: JSON steps file, or 'shrink-grow' from --budget-mb (at_pass counts passes across all lanes)" });
    opts.push(Opt { name: "requests", takes_value: true, default: Some("16"), help: "requests to serve (synthetic workload mode)" });
    opts.push(Opt { name: "rps", takes_value: true, default: Some("0"), help: "mean arrival rate (0 = closed loop)" });
    opts.push(Opt { name: "max-batch", takes_value: true, default: Some("4"), help: "max requests per batch (fixed-batch lanes)" });
    opts.push(Opt { name: "slo-ms", takes_value: true, default: Some("5000"), help: "p95 latency SLO; with --continuous, also the per-lane SLO target driving overload shedding and slo_attained_pct (requests may override it over TCP)" });
    opts.push(Opt { name: "continuous", takes_value: false, default: None, help: "continuous batching: requests join/leave the running decode at token boundaries instead of waiting out fixed batches (pipelined modes)" });
    opts.push(Opt { name: "max-active", takes_value: true, default: None, help: "max requests decoding concurrently per lane (with --continuous; default 4; elastic budget shrinks scale it down)" });
    opts.push(Opt { name: "listen", takes_value: true, default: None, help: "serve a TCP front-end on this address (e.g. 127.0.0.1:7070; one JSON object per line; {\"op\":\"shutdown\"} stops it); --model may list several profiles, comma-separated" });
    opts.push(Opt { name: "concurrent", takes_value: false, default: None, help: "run lanes concurrently (one executor thread + engine per model, shared budget); --listen only" });
    opts.push(Opt { name: "lane-weights", takes_value: true, default: None, help: "comma-separated admission weights, one per model (with --concurrent; default all-equal)" });
    opts.push(Opt { name: "workers", takes_value: true, default: None, help: "total Loading-Agent threads split across pipeload lanes by weight (with --concurrent; overrides --agents)" });
    opts.push(Opt { name: "fault-plan", takes_value: true, default: None, help: "deterministic fault plan: JSON file/inline, or compact 'kind@pass[xN][:lane][+ms];...;seed=N' (kinds: disk_error disk_slow agent_panic lane_death acquire_fail conn_drop)" });
    opts.push(Opt { name: "pass-timeout-ms", takes_value: true, default: None, help: "watchdog: abort+retry any inference pass exceeding this wall-clock bound" });
    opts.push(Opt { name: "load-retries", takes_value: true, default: Some("2"), help: "bounded retries for transient layer-load failures before a pass aborts" });
    opts.push(Opt { name: "retry-backoff-ms", takes_value: true, default: Some("1"), help: "base backoff between load retries (deterministic jitter on top)" });
    opts.push(Opt { name: "max-lane-restarts", takes_value: true, default: Some("2"), help: "crash-restart budget per lane before its requests are shed lane_dead" });
    opts.push(trace_out_opt());
    opts.push(Opt { name: "json", takes_value: false, default: None, help: "print the machine-readable summary instead of the human one" });
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!("{}", render_help("serve", "serving session (synthetic workload, or multi-model TCP front-end)", &opts));
        return Ok(());
    }
    let engine = Engine::with_default_paths()?;
    let budget = a.mb_bytes("budget-mb")?;
    let pin_budget = a.mb_bytes("pin-budget-mb")?;
    let kv_budget = a.mb_bytes("kv-budget-mb")?;
    // same rule as `run` / session validation — the --listen path would
    // otherwise silently ignore the flag (no lane ever carries it)
    if kv_budget.is_some() && !a.flag("kv-cache") {
        bail!("--kv-budget-mb only makes sense with --kv-cache");
    }
    let memory_trace =
        a.get("memory-trace").map(|spec| PressureTrace::from_spec(spec, budget)).transpose()?;
    let models = a.list("model");
    let runs: Vec<RunConfig> = models
        .iter()
        .map(|m| -> Result<RunConfig> {
            Ok(RunConfig {
                profile: m.clone(),
                mode: Mode::parse(a.req("mode")?)?,
                agents: a.usize("agents")?,
                budget,
                pin_budget,
                pin_policy: PinPolicy::parse(a.req("pin-policy")?)?,
                kv_cache: a.flag("kv-cache"),
                kv_block_tokens: a.get("kv-block-tokens").map(|s| s.parse()).transpose()?,
                prefetch_depth: a.usize("prefetch-depth")?,
                device_cache: !a.flag("no-device-cache"),
                continuous: a.flag("continuous"),
                slo_ms: if a.flag("continuous") { Some(a.f64("slo-ms")?) } else { None },
                max_active: a.get("max-active").map(|s| s.parse()).transpose()?,
                disk: a.req("disk")?.to_string(),
                seed: a.u64("seed")?,
                fault_plan: a.get("fault-plan").map(String::from),
                pass_timeout_ms: a.get("pass-timeout-ms").map(|s| s.parse()).transpose()?,
                load_retries: a.usize("load-retries")? as u32,
                retry_backoff_ms: a.u64("retry-backoff-ms")?,
                max_lane_restarts: a.usize("max-lane-restarts")? as u32,
                ..RunConfig::default()
            })
        })
        .collect::<Result<_>>()?;

    if let Some(addr) = a.get("listen") {
        // synthetic-workload knobs have no meaning for the TCP front-end
        let non_default = |name: &str| {
            let declared = opts.iter().find(|o| o.name == name).and_then(|o| o.default);
            a.get(name) != declared
        };
        if non_default("requests")
            || non_default("rps")
            || (non_default("slo-ms") && !a.flag("continuous"))
        {
            eprintln!("hermes serve: --requests/--rps drive the synthetic workload and are ignored with --listen (--slo-ms is honored with --continuous)");
        }
        let lane_weights = a
            .get("lane-weights")
            .map(|s| -> Result<Vec<f64>> {
                s.split(',')
                    .map(|w| {
                        w.trim()
                            .parse::<f64>()
                            .map_err(|_| anyhow::anyhow!("bad lane weight '{w}'"))
                    })
                    .collect()
            })
            .transpose()?;
        let worker_allotment = a.get("workers").map(|s| s.parse()).transpose()?;
        if (lane_weights.is_some() || worker_allotment.is_some()) && !a.flag("concurrent") {
            bail!("--lane-weights/--workers only make sense with --concurrent");
        }
        let router_cfg = RouterConfig {
            models: runs,
            budget,
            kv_budget,
            max_batch: a.usize("max-batch")?,
            memory_trace,
            concurrent: a.flag("concurrent"),
            lane_weights,
            worker_allotment,
            fault_plan: a.get("fault-plan").map(String::from),
            max_lane_restarts: a.usize("max-lane-restarts")? as u32,
            ..RouterConfig::default()
        };
        let telemetry = telemetry_for(&a);
        let mut frontend = TcpFrontend::bind(addr)?;
        frontend.set_telemetry(telemetry.clone());
        eprintln!("hermes serve: listening on {} ({} model(s): {})", frontend.local_addr()?, models.len(), models.join(", "));
        let s = frontend.run(&engine, router_cfg)?;
        write_trace_out(&a, &telemetry)?;
        if a.flag("json") {
            println!("{}", with_telemetry_drops(s.to_json(), &telemetry).pretty());
        } else {
            println!("served {} requests ({} rejected) in {} batches (mean batch {:.2})", s.served, s.rejected, s.batches, s.mean_batch_size);
            println!("  throughput: {:.2} req/s", s.throughput_rps);
            println!("  latency p50 {}  p95 {}  p99 {}", human_ms(s.latency.p50()), human_ms(s.latency.p95()), human_ms(s.latency.p99()));
            println!("  queue wait p50 {}  p95 {}  ({} pass(es) in flight at peak)", human_ms(s.queue_wait_p50_ms), human_ms(s.queue_wait_p95_ms), s.concurrent_passes_peak);
            println!("  peak mem: {}{}", human_bytes(s.peak_bytes), s.budget_bytes.map(|b| format!("  (budget {})", human_bytes(b))).unwrap_or_default());
            if s.budget_steps > 0 {
                println!("  elastic:  {} budget steps, {} evictions, {} re-plans", s.budget_steps, s.elastic_evictions, s.replans);
            }
            if s.joins + s.leaves + s.shed_overload > 0 {
                println!(
                    "  continuous: {} joins / {} leaves / {} shed  (SLO attained {:.1}%, {:.2} tok/s)",
                    s.joins, s.leaves, s.shed_overload, s.slo_attained_pct, s.tokens_per_sec
                );
            }
            if s.shared_kv_blocks + s.kv_dedup_bytes > 0 {
                println!(
                    "  kv sharing: {} shared blocks, {} deduplicated",
                    s.shared_kv_blocks,
                    human_bytes(s.kv_dedup_bytes)
                );
            }
            if s.faults_injected + s.load_retries + s.passes_timed_out + s.lane_restarts > 0 {
                println!(
                    "  faults:   {} injected, {} load retries, {} passes timed out ({} lane restarts, {} requeued)",
                    s.faults_injected, s.load_retries, s.passes_timed_out, s.lane_restarts, s.requeued
                );
            }
            for m in &s.per_model {
                println!("  [{}] served {} / rejected {} in {} batches, p95 {}", m.profile, m.served, m.rejected, m.batches, human_ms(m.latency.p95()));
            }
            print_telemetry_drops(&telemetry);
        }
        return Ok(());
    }

    if a.flag("concurrent") {
        bail!("--concurrent needs --listen (the synthetic workload drives one serialized lane)");
    }
    if runs.len() != 1 {
        bail!("the synthetic workload serves one model; pass --listen for multi-model serving");
    }
    let mut run = runs.into_iter().next().unwrap();
    run.kv_budget = kv_budget;
    let telemetry = telemetry_for(&a);
    let cfg = ServeConfig {
        run,
        num_requests: a.usize("requests")?,
        arrival_rps: a.f64("rps")?,
        max_batch: a.usize("max-batch")?,
        slo_ms: a.f64("slo-ms")?,
        memory_trace,
        telemetry: telemetry.clone(),
        ..ServeConfig::default()
    };
    let s = serve(&engine, &cfg)?;
    write_trace_out(&a, &telemetry)?;
    if a.flag("json") {
        println!("{}", with_telemetry_drops(s.to_json(), &telemetry).pretty());
        return Ok(());
    }
    println!("served {} requests in {} batches (mean batch {:.2})", s.served, s.batches, s.mean_batch_size);
    println!("  throughput: {:.2} req/s", s.throughput_rps);
    println!("  latency p50 {}  p95 {}  p99 {}", human_ms(s.latency.p50()), human_ms(s.latency.p95()), human_ms(s.latency.p99()));
    println!("  queue wait p50 {}  p95 {}  ({} pass(es) in flight at peak)", human_ms(s.queue_wait_p50_ms), human_ms(s.queue_wait_p95_ms), s.concurrent_passes_peak);
    println!("  peak mem: {}", human_bytes(s.peak_bytes));
    if s.cache_hits + s.cache_misses > 0 {
        println!(
            "  hot cache: {} hits / {} misses",
            s.cache_hits, s.cache_misses
        );
    }
    if s.kv_inc_passes + s.kv_recomputes > 0 {
        println!(
            "  kv cache:  {} incremental passes / {} recomputes ({} blocks evicted)",
            s.kv_inc_passes, s.kv_recomputes, s.kv_evicted_blocks
        );
    }
    if s.prefetched_stages + s.device_cache_hits + s.spawns_avoided > 0 {
        println!(
            "  overlap:   {} prefetched ({} wasted), {} device-cache hits, {} spawns avoided",
            s.prefetched_stages, s.prefetch_wasted, s.device_cache_hits, s.spawns_avoided
        );
    }
    if s.budget_steps > 0 {
        println!(
            "  elastic:   {} budget steps, {} evictions, {} re-plans",
            s.budget_steps, s.elastic_evictions, s.replans
        );
    }
    if s.joins + s.leaves + s.shed_overload > 0 {
        println!(
            "  continuous: {} joins / {} leaves / {} shed  (SLO attained {:.1}%, {:.2} tok/s)",
            s.joins, s.leaves, s.shed_overload, s.slo_attained_pct, s.tokens_per_sec
        );
    }
    if s.shared_kv_blocks + s.kv_dedup_bytes > 0 {
        println!(
            "  kv sharing: {} shared blocks, {} deduplicated",
            s.shared_kv_blocks,
            human_bytes(s.kv_dedup_bytes)
        );
    }
    if s.faults_injected + s.load_retries + s.passes_timed_out + s.lane_restarts > 0 {
        println!(
            "  faults:    {} injected, {} load retries, {} passes timed out ({} lane restarts, {} requeued)",
            s.faults_injected, s.load_retries, s.passes_timed_out, s.lane_restarts, s.requeued
        );
    }
    println!("  SLO p95 <= {}: {}", human_ms(s.slo.target_ms), if s.slo.met { "MET" } else { "MISSED" });
    print_telemetry_drops(&telemetry);
    Ok(())
}

fn cmd_report(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "table", takes_value: true, default: None, help: "1 | 2 | 3" });
    opts.push(Opt { name: "figure", takes_value: true, default: None, help: "1b | 2 | 3 | 7" });
    opts.push(Opt { name: "agents", takes_value: true, default: Some("2,4,6"), help: "PIPELOAD agent counts for tables 2/3" });
    opts.push(Opt { name: "tokens", takes_value: true, default: None, help: "generated tokens override (speeds up sweeps)" });
    opts.push(Opt { name: "fresh", takes_value: false, default: None, help: "ignore cached sweep results" });
    opts.push(Opt { name: "all", takes_value: false, default: None, help: "print every table and figure" });
    opts.push(trace_out_opt());
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!("{}", render_help("report", "regenerate paper tables/figures", &opts));
        return Ok(());
    }
    let engine = Engine::with_default_paths()?;
    let disk = a.req("disk")?;
    let agents: Vec<usize> = a.list("agents").iter().map(|s| s.parse().unwrap_or(2)).collect();
    let tokens = a.get("tokens").map(|s| s.parse()).transpose()?;
    let mut wanted_tables: Vec<String> = a.get("table").map(|t| vec![t.to_string()]).unwrap_or_default();
    let mut wanted_figs: Vec<String> = a.get("figure").map(|f| vec![f.to_string()]).unwrap_or_default();
    if a.flag("all") {
        wanted_tables = vec!["1".into(), "2".into(), "3".into()];
        wanted_figs = vec!["2".into(), "3".into(), "7".into(), "1b".into()];
    }
    if wanted_tables.is_empty() && wanted_figs.is_empty() {
        bail!("pass --table N, --figure N, or --all");
    }
    for t in &wanted_tables {
        match t.as_str() {
            "1" => println!("{}", report::table1(&engine)?),
            "2" | "3" => {
                let reports = report::sweep_table23(&engine, disk, &agents, tokens, a.flag("fresh"))?;
                if t == "2" {
                    println!("{}", report::table2(&reports, &agents));
                } else {
                    println!("{}", report::table3(&reports, &agents));
                }
            }
            _ => bail!("unknown table '{t}'"),
        }
    }
    for f in &wanted_figs {
        match f.as_str() {
            "2" => println!("{}", report::fig2(&engine)?),
            "3" => println!("{}", report::fig3(&engine, disk)?),
            "7" => println!("{}", report::fig7(&engine, disk, &[0.15, 0.25, 0.4, 0.6, 0.8], 8)?),
            "1b" => {
                let trace_out = a.get("trace-out").map(std::path::Path::new);
                println!("{}", report::fig1b(&engine, disk, a.req("model")?, trace_out)?);
            }
            _ => bail!("unknown figure '{f}'"),
        }
    }
    Ok(())
}

fn cmd_analyze(rest: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "mode", takes_value: true, default: Some("pipeload"), help: "baseline|pipeswitch|pipeload (run-and-analyze mode)" });
    opts.push(Opt { name: "agents", takes_value: true, default: Some("4"), help: "number of Loading Agents (run-and-analyze mode)" });
    opts.push(Opt { name: "budget-mb", takes_value: true, default: None, help: "memory budget in MB (run-and-analyze mode)" });
    opts.push(Opt { name: "pin-budget-mb", takes_value: true, default: None, help: "hot-layer cache pin budget in MB" });
    opts.push(Opt { name: "kv-cache", takes_value: false, default: None, help: "paged KV cache (generative profiles)" });
    opts.push(Opt { name: "kv-budget-mb", takes_value: true, default: None, help: "KV pool cap in MB (with --kv-cache)" });
    opts.push(Opt { name: "prefetch-depth", takes_value: true, default: Some("0"), help: "cross-pass prefetch depth (pipeload)" });
    opts.push(Opt { name: "batch", takes_value: true, default: Some("1"), help: "batch size (must be AOT-compiled)" });
    opts.push(Opt { name: "tokens", takes_value: true, default: None, help: "generated tokens (generative models)" });
    opts.push(Opt { name: "gantt", takes_value: false, default: None, help: "also print the reconstructed per-worker Gantt chart" });
    opts.push(Opt { name: "json", takes_value: false, default: None, help: "print the machine-readable analysis instead of the human report" });
    let a = Args::parse(rest, &opts)?;
    if a.flag("help") {
        println!(
            "{}\n\nusage:\n  hermes analyze <trace.json>   analyze an existing --trace-out file\n  hermes analyze [run flags]    run once with telemetry on, then analyze",
            render_help("analyze", "trace analytics: lifecycle breakdown, critical-path attribution, memory audit", &opts)
        );
        return Ok(());
    }
    let analysis = if let Some(path) = a.positional.first() {
        Analysis::from_file(std::path::Path::new(path))?
    } else {
        let engine = Engine::with_default_paths()?;
        let cfg = RunConfig {
            profile: a.req("model")?.to_string(),
            mode: Mode::parse(a.req("mode")?)?,
            agents: a.usize("agents")?,
            budget: a.mb_bytes("budget-mb")?,
            pin_budget: a.mb_bytes("pin-budget-mb")?,
            kv_cache: a.flag("kv-cache"),
            kv_budget: a.mb_bytes("kv-budget-mb")?,
            prefetch_depth: a.usize("prefetch-depth")?,
            batch: a.usize("batch")?,
            gen_tokens: a.get("tokens").map(|s| s.parse()).transpose()?,
            disk: a.req("disk")?.to_string(),
            seed: a.u64("seed")?,
            ..RunConfig::default()
        };
        let telemetry = Telemetry::on();
        let mut session = engine.open_session(&cfg)?;
        session.set_telemetry(telemetry.clone());
        session.run()?;
        drop(session);
        Analysis::from_bus(&telemetry.drain(), telemetry.dropped())
    };
    if a.flag("json") {
        println!("{}", analysis.to_json().pretty());
    } else {
        println!("{}", analysis.render_text());
        if a.flag("gantt") {
            println!("{}", analysis.ascii_gantt(100));
        }
    }
    // a broken trace (truncated lifecycles, audit drift, dropped events)
    // must fail loudly — scripts gate on the exit code
    if !analysis.ok() {
        bail!("trace analysis found {} error(s)", analysis.errors.len());
    }
    Ok(())
}
