//! Elastic memory controller: runtime budget adaptation under
//! memory-pressure traces.
//!
//! Hermes plans for *a* memory constraint, but an edge device's available
//! memory is not a constant: co-resident apps come and go, and the budget
//! that held at session-open can be wrong ten tokens later.  TPI-LLM
//! (arXiv:2410.00531) schedules inside a sliding memory window and
//! EdgePipe (see PAPERS.md) re-partitions when device capacity changes;
//! this module brings the same reactivity to the PIPELOAD stack:
//!
//! * a [`PressureTrace`] is a replayable sequence of budget steps
//!   `(at_pass, budget)` — loaded from JSON (`--memory-trace <file>`) or
//!   synthesized (`--memory-trace shrink-grow`).  `at_pass` counts
//!   completed engine passes (each generated token is one pass), so a
//!   trace is deterministic: the same trace + the same workload replays
//!   the same pressure, which is what makes elastic runs testable against
//!   static runs;
//! * a [`BudgetController`] walks the trace between passes and reports
//!   which budget should now be in force ([`BudgetController::poll`]);
//! * the [`Session`](crate::engine::Session) (and, for multi-model
//!   serving, the [`Router`](crate::server::Router) with its **shared**
//!   accountant) applies each step: `MemoryAccountant::resize`, then the
//!   existing eviction chain — pinned hot layers first, then cached KV
//!   sequences, through `OrderedGate::reclaim_to_budget` — until
//!   `used <= budget`; then re-derives the pin/KV caps under the
//!   `budget - max_stage` liveness rule and re-consults
//!   [`Schedule::pick`](crate::planner::Schedule::pick) for the Loading
//!   Agent count (epoch re-planning).
//!
//! Correctness bar: tokens are bit-identical to a static-budget run.
//! A shrink only evicts (and every eviction path already has a recompute
//! fallback); a grow only widens headroom.  Each applied step is recorded
//! as a [`BudgetEpoch`] so tests (and `examples/elastic_pressure.rs`) can
//! assert that `used` settled under the instantaneous budget and that the
//! plan actually adapted.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Spec string that synthesizes a trace instead of reading a file.
pub const SHRINK_GROW_SPEC: &str = "shrink-grow";

/// Synthesized shrink-grow shape: shrink to 60% of the base budget before
/// pass [`SHRINK_AT_PASS`], restore the base before [`GROW_AT_PASS`].
pub const SHRINK_FRACTION_PCT: u64 = 60;
pub const SHRINK_AT_PASS: usize = 2;
pub const GROW_AT_PASS: usize = 4;

/// One budget change: from the moment `at_pass` passes have completed,
/// `budget_bytes` is the device's memory constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PressureStep {
    /// applies once this many engine passes have completed (0 = before the
    /// first pass)
    pub at_pass: usize,
    /// the new memory budget in bytes (> 0)
    pub budget_bytes: u64,
}

/// A replayable memory-pressure trace: budget steps ordered by `at_pass`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PressureTrace {
    steps: Vec<PressureStep>,
}

impl PressureTrace {
    /// Build a trace; steps are sorted by `at_pass` (ties keep insertion
    /// order — the later entry wins when applied, like a real fluctuation
    /// that settles).  Zero budgets are rejected: a device with 0 bytes
    /// free is not a constraint to adapt to, it is an OOM kill.
    pub fn new(steps: Vec<PressureStep>) -> Result<PressureTrace> {
        for s in &steps {
            if s.budget_bytes == 0 {
                bail!("pressure step at pass {} has a 0 B budget", s.at_pass);
            }
        }
        let mut steps = steps;
        steps.sort_by_key(|s| s.at_pass);
        Ok(PressureTrace { steps })
    }

    /// The canonical synthetic trace: shrink to [`SHRINK_FRACTION_PCT`]%
    /// of `base_budget` once [`SHRINK_AT_PASS`] passes have completed, and
    /// grow back to `base_budget` once [`GROW_AT_PASS`] passes have.
    pub fn shrink_grow(base_budget: u64) -> PressureTrace {
        let shrunk = (base_budget * SHRINK_FRACTION_PCT / 100).max(1);
        PressureTrace {
            steps: vec![
                PressureStep { at_pass: SHRINK_AT_PASS, budget_bytes: shrunk },
                PressureStep { at_pass: GROW_AT_PASS, budget_bytes: base_budget },
            ],
        }
    }

    /// Resolve a `--memory-trace` spec: the literal `shrink-grow` (scaled
    /// from `base_budget`, which must then be set) or a JSON file path.
    pub fn from_spec(spec: &str, base_budget: Option<u64>) -> Result<PressureTrace> {
        if spec == SHRINK_GROW_SPEC {
            let base = base_budget.ok_or_else(|| {
                anyhow!("--memory-trace shrink-grow needs a base budget (--budget-mb)")
            })?;
            return Ok(PressureTrace::shrink_grow(base));
        }
        PressureTrace::load(Path::new(spec))
    }

    pub fn load(path: &Path) -> Result<PressureTrace> {
        PressureTrace::from_json(&Value::from_file(path)?)
            .with_context(|| format!("parsing memory trace {}", path.display()))
    }

    /// Accepts `{"steps": [{"at_pass": N, "budget_mb": X}, ...]}` or the
    /// bare array.  Budgets are megabytes (fractions allowed), matching
    /// the CLI's `--budget-mb` convention.
    pub fn from_json(v: &Value) -> Result<PressureTrace> {
        let arr = match v.get("steps") {
            Some(steps) => steps.as_arr()?,
            None => v.as_arr()?,
        };
        let steps = arr
            .iter()
            .map(|e| {
                let mb = e.req("budget_mb")?.as_f64()?;
                if !mb.is_finite() || mb <= 0.0 {
                    bail!("budget_mb must be a positive number, got {mb}");
                }
                Ok(PressureStep {
                    at_pass: e.req("at_pass")?.as_usize()?,
                    budget_bytes: (mb * 1024.0 * 1024.0) as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        PressureTrace::new(steps)
    }

    pub fn to_json(&self) -> Value {
        Value::obj().set(
            "steps",
            Value::Arr(
                self.steps
                    .iter()
                    .map(|s| {
                        Value::obj()
                            .set("at_pass", s.at_pass)
                            .set("budget_mb", s.budget_bytes as f64 / (1024.0 * 1024.0))
                    })
                    .collect(),
            ),
        )
    }

    pub fn steps(&self) -> &[PressureStep] {
        &self.steps
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// Walks a [`PressureTrace`] as passes complete.  One controller drives
/// one accountant — a session's own, or the router's shared one.
#[derive(Debug, Clone)]
pub struct BudgetController {
    trace: PressureTrace,
    next: usize,
}

impl BudgetController {
    pub fn new(trace: PressureTrace) -> BudgetController {
        BudgetController { trace, next: 0 }
    }

    /// Consume every step due once `passes_done` passes have completed and
    /// return the last of them (the budget now in force), or `None` when
    /// no step is due.  Intermediate due steps are skipped, not applied —
    /// a fluctuation that came and went between two pass boundaries only
    /// ever lands at its settled value.
    pub fn poll(&mut self, passes_done: usize) -> Option<PressureStep> {
        let mut due = None;
        while self.next < self.trace.steps.len()
            && self.trace.steps[self.next].at_pass <= passes_done
        {
            due = Some(self.trace.steps[self.next]);
            self.next += 1;
        }
        due
    }

    /// Steps not yet consumed by [`BudgetController::poll`].
    pub fn remaining(&self) -> usize {
        self.trace.steps.len() - self.next
    }

    pub fn trace(&self) -> &PressureTrace {
        &self.trace
    }
}

/// Record of one applied budget step (the session keeps a log of these;
/// see [`Session::budget_epochs`](crate::engine::Session::budget_epochs)).
#[derive(Debug, Clone)]
pub struct BudgetEpoch {
    /// passes completed BY THE APPLYING SESSION when the step was applied.
    /// Under a Router this is lane-local and may differ from the trace's
    /// `at_pass`, which counts passes fleet-wide.
    pub at_pass: usize,
    /// the budget now in force (a step below the session's feasibility
    /// floor is clamped up to it — see `Session::budget_floor`)
    pub budget_bytes: u64,
    /// bytes the apply returned to the budget while settling — under a
    /// shared accountant this can include victim lanes' reclaimed state
    pub freed_bytes: u64,
    /// the session's OWN pinned layers + KV blocks reclaimed while
    /// settling (victim lanes' losses are attributed to the victims)
    pub evictions: u64,
    /// accountant `used` after the eviction chain settled — the elastic
    /// invariant is `used_after_bytes <= budget_bytes` whenever everything
    /// over budget was evictable (pins/KV; in-flight weights are not)
    pub used_after_bytes: u64,
    /// Loading Agents in force after epoch re-planning
    pub agents: usize,
    /// hot-layer pin cap after the `budget - max_stage` re-derivation
    pub pin_cap_bytes: u64,
    /// KV pool cap after rebalancing (None = accountant-bounded only)
    pub kv_cap_bytes: Option<u64>,
    /// did `Schedule::pick` change the agent count this epoch?
    pub replanned: bool,
}

/// Elastic counters surfaced in `RunReport` / `ServeSummary` /
/// `RouterSummary` / `serve --json`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ElasticStats {
    /// budget steps applied
    pub budget_steps: u64,
    /// own pinned layers + KV blocks evicted by elastic shrinks (distinct
    /// from `S^stop` admission pressure, which counts elsewhere)
    pub elastic_evictions: u64,
    /// epoch re-plans that changed the Loading Agent count
    pub replans: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shrink_grow_shape() {
        let t = PressureTrace::shrink_grow(1000);
        assert_eq!(t.len(), 2);
        assert_eq!(t.steps()[0], PressureStep { at_pass: SHRINK_AT_PASS, budget_bytes: 600 });
        assert_eq!(t.steps()[1], PressureStep { at_pass: GROW_AT_PASS, budget_bytes: 1000 });
    }

    #[test]
    fn from_spec_requires_base_for_shrink_grow() {
        assert!(PressureTrace::from_spec(SHRINK_GROW_SPEC, None).is_err());
        let t = PressureTrace::from_spec(SHRINK_GROW_SPEC, Some(1 << 20)).unwrap();
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn json_roundtrip_and_bare_array() {
        let t = PressureTrace::new(vec![
            PressureStep { at_pass: 3, budget_bytes: 2 * 1024 * 1024 },
            PressureStep { at_pass: 1, budget_bytes: 512 * 1024 },
        ])
        .unwrap();
        // sorted by at_pass
        assert_eq!(t.steps()[0].at_pass, 1);
        let rt = PressureTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(rt, t);
        // bare-array form parses too
        let bare = Value::parse(r#"[{"at_pass": 0, "budget_mb": 1.5}]"#).unwrap();
        let t2 = PressureTrace::from_json(&bare).unwrap();
        assert_eq!(t2.steps()[0].budget_bytes, 1536 * 1024);
    }

    #[test]
    fn json_rejects_nonpositive_budgets() {
        let bad = Value::parse(r#"[{"at_pass": 0, "budget_mb": 0}]"#).unwrap();
        assert!(PressureTrace::from_json(&bad).is_err());
        let neg = Value::parse(r#"[{"at_pass": 0, "budget_mb": -2}]"#).unwrap();
        assert!(PressureTrace::from_json(&neg).is_err());
    }

    #[test]
    fn controller_applies_steps_in_order_last_wins() {
        let t = PressureTrace::new(vec![
            PressureStep { at_pass: 0, budget_bytes: 100 },
            PressureStep { at_pass: 2, budget_bytes: 60 },
            PressureStep { at_pass: 2, budget_bytes: 50 },
            PressureStep { at_pass: 5, budget_bytes: 100 },
        ])
        .unwrap();
        let mut c = BudgetController::new(t);
        assert_eq!(c.remaining(), 4);
        // pass 0 boundary: only the first step is due
        assert_eq!(c.poll(0).unwrap().budget_bytes, 100);
        assert_eq!(c.poll(1), None, "no step between 1 and 2");
        // both at_pass=2 steps are due; the settled (last) value wins
        assert_eq!(c.poll(2).unwrap().budget_bytes, 50);
        assert_eq!(c.poll(3), None);
        // jumping past the end consumes the tail
        assert_eq!(c.poll(10).unwrap().budget_bytes, 100);
        assert_eq!(c.remaining(), 0);
        assert_eq!(c.poll(11), None);
    }

    #[test]
    fn empty_trace_never_fires() {
        let mut c = BudgetController::new(PressureTrace::default());
        assert_eq!(c.poll(0), None);
        assert_eq!(c.remaining(), 0);
    }
}
