//! Paper table/figure harness: regenerates every evaluation artifact.
//!
//! | paper artifact | function | CLI |
//! |----------------|----------|-----|
//! | Table I  (model configs)            | [`table1`]  | `hermes report --table 1` |
//! | Table II (latency / speedup)        | [`table2`]  | `hermes report --table 2` |
//! | Table III (memory / ratio)          | [`table3`]  | `hermes report --table 3` |
//! | Fig 2 (per-layer-type memory share) | [`fig2`]    | `hermes report --figure 2` |
//! | Fig 3 (load vs compute latency)     | [`fig3`]    | `hermes report --figure 3` |
//! | Fig 7 (latency & #LAs vs budget)    | [`fig7`]    | `hermes report --figure 7` |
//! | Fig 1b (pipeline stall, Obs II)     | [`fig1b`]   | `hermes report --figure 1b` |
//!
//! Absolute numbers come from the scaled sim profiles + storage simulator;
//! the *shape* (who wins, by what factor, where crossovers fall) is the
//! reproduction target (DESIGN.md section 3).  Table II/III share one
//! sweep, cached under `results/` so the two tables agree.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::config::{Mode, RunConfig};
use crate::diskio::Disk;
use crate::engine::{make_input, Engine};
use crate::metrics::{fmt_mb, fmt_ms, fmt_ratio, RunReport, Table};
use crate::planner;
use crate::profiler::{profile_model, ModelProfile};
use crate::telemetry::Telemetry;
use crate::util::json::Value;

/// The paper's four evaluated models (Table I order).
pub const PAPER_MODELS: [&str; 4] =
    ["vit-large-sim", "gpt2-base-sim", "bert-large-sim", "gptj-sim"];

/// Fig 2 additionally decomposes the two BART variants.
pub const FIG2_MODELS: [&str; 6] = [
    "vit-large-sim",
    "bert-large-sim",
    "gpt2-base-sim",
    "gptj-sim",
    "bart-base-sim",
    "bart-large-sim",
];

const MB: f64 = 1024.0 * 1024.0;

fn params_millions(engine: &Engine, name: &str) -> Result<f64> {
    let p = engine.runtime.profile(name)?;
    let mut elems: u64 = 0;
    for stage in &p.stages {
        for spec in p.stage_params(stage)? {
            elems += spec.num_elements() as u64;
        }
    }
    Ok(elems as f64 / 1e6)
}

/// Table I: model configurations.
pub fn table1(engine: &Engine) -> Result<String> {
    let mut t = Table::new(&[
        "Model",
        "Params (M)",
        "Layer kind",
        "#Layers",
        "DType",
        "Mem layers/total (MB)",
        "Mem per layer (MB)",
        "Paper model",
    ]);
    for name in PAPER_MODELS {
        let p = engine.runtime.profile(name)?;
        let body_kind = p.body_kind().to_string();
        let body_bytes: u64 = p
            .stages
            .iter()
            .filter(|s| s.kind == body_kind)
            .map(|s| p.stage_bytes(s))
            .sum();
        let n_body = p.stages.iter().filter(|s| s.kind == body_kind).count();
        t.row(vec![
            name.into(),
            format!("{:.1}", params_millions(engine, name)?),
            body_kind.clone(),
            n_body.to_string(),
            "f32".into(),
            format!("{:.0} / {:.0}", body_bytes as f64 / MB, p.total_weight_bytes as f64 / MB),
            format!("{:.1}", body_bytes as f64 / n_body.max(1) as f64 / MB),
            p.paper_model.clone(),
        ]);
    }
    Ok(format!("TABLE I: Model Configurations (sim profiles)\n{}", t.render()))
}

/// Fig 2: memory decomposition across layer types (Obs I).
pub fn fig2(engine: &Engine) -> Result<String> {
    let mut out = String::from("Fig 2: decomposition of layers' memory usage (Obs I)\n");
    let mut t = Table::new(&["Model", "Embed %", "Enc/Dec %", "Other %", "bar (enc/dec share)"]);
    for name in FIG2_MODELS {
        let p = engine.runtime.profile(name)?;
        let body_kinds = ["encoder_layer", "decoder_layer", "gptj_layer", "cross_decoder_layer"];
        let mut emb = 0u64;
        let mut body = 0u64;
        let mut other = 0u64;
        for s in &p.stages {
            let b = p.stage_bytes(s);
            if s.kind == "embedding" || s.kind == "patch_embed" {
                emb += b;
            } else if body_kinds.contains(&s.kind.as_str()) {
                body += b;
            } else {
                other += b;
            }
        }
        let total = (emb + body + other).max(1) as f64;
        let share = body as f64 / total;
        let bar = "#".repeat((share * 30.0).round() as usize);
        t.row(vec![
            name.into(),
            format!("{:.1}", emb as f64 / total * 100.0),
            format!("{:.1}", share * 100.0),
            format!("{:.1}", other as f64 / total * 100.0),
            bar,
        ]);
    }
    out.push_str(&t.render());
    out.push_str("paper: encoder/decoder layers consume 70-95% of total memory\n");
    Ok(out)
}

/// Run the Layer Profiler for one model (helper shared by fig3 / planner).
pub fn profile_one(engine: &Engine, name: &str, disk_name: &str) -> Result<ModelProfile> {
    engine.ensure_weights(name)?;
    let profile = engine.runtime.profile(name)?;
    let disk = Disk::preset(disk_name)?;
    let (input, _, _) = make_input(profile, 1, 7);
    profile_model(&engine.runtime, profile, &engine.paths.weights, &disk, 1, &input)
}

/// Fig 3: per-layer loading vs inference latency (Obs II).
pub fn fig3(engine: &Engine, disk_name: &str) -> Result<String> {
    let mut out = format!("Fig 3: loading vs inference latency per body layer (disk={disk_name})\n");
    let mut t = Table::new(&["Model", "load ms/layer", "compute ms/layer", "ratio", "idle frac (std pipeline est.)"]);
    for name in PAPER_MODELS {
        let mp = profile_one(engine, name, disk_name)?;
        let p = engine.runtime.profile(name)?;
        let (l, c, _) = mp.body_means(p.body_kind());
        // standard pipeline leaves compute idle ~ (l-c)/l of the time
        let idle = if l > 0.0 { ((l - c) / l).max(0.0) } else { 0.0 };
        t.row(vec![
            name.into(),
            fmt_ms(l),
            fmt_ms(c),
            format!("{:.1}x", if c > 0.0 { l / c } else { f64::INFINITY }),
            format!("{:.0}%", idle * 100.0),
        ]);
    }
    out.push_str(&t.render());
    out.push_str("paper: ratio ~10x for ~1 GB models, ~2x for GPT-J; 60-80% idle\n");
    Ok(out)
}

/// One sweep powers Tables II and III (cached so the tables agree).
pub fn sweep_table23(
    engine: &Engine,
    disk_name: &str,
    agents: &[usize],
    gen_tokens: Option<usize>,
    fresh: bool,
) -> Result<Vec<RunReport>> {
    let cache: PathBuf = engine.paths.results.join(format!("table23_{disk_name}.json"));
    if !fresh && cache.exists() {
        if let Ok(v) = Value::from_file(&cache) {
            if let Ok(reports) = parse_reports(&v) {
                return Ok(reports);
            }
        }
    }
    let mut reports = Vec::new();
    for name in PAPER_MODELS {
        for (mode, m) in std::iter::once((Mode::Baseline, 1))
            .chain(std::iter::once((Mode::PipeSwitch, 1)))
            .chain(agents.iter().map(|&m| (Mode::PipeLoad, m)))
        {
            let cfg = RunConfig {
                profile: name.into(),
                mode,
                agents: m,
                disk: disk_name.into(),
                gen_tokens,
                ..RunConfig::default()
            };
            let (report, _) = engine
                .run(&cfg)
                .with_context(|| format!("sweep {name} {} m={m}", mode.name()))?;
            eprintln!(
                "  [sweep] {name:<16} {:<10} m={m}: {:.1} ms, peak {:.1} MB",
                mode.name(),
                report.latency_ms,
                report.peak_bytes as f64 / MB
            );
            reports.push(report);
        }
    }
    let v = Value::Arr(reports.iter().map(|r| r.to_json()).collect());
    v.to_file(&cache)?;
    Ok(reports)
}

fn parse_reports(v: &Value) -> Result<Vec<RunReport>> {
    v.as_arr()?
        .iter()
        .map(|r| {
            Ok(RunReport {
                model: r.req("model")?.as_str()?.to_string(),
                mode: r.req("mode")?.as_str()?.to_string(),
                agents: r.req("agents")?.as_usize()?,
                latency_ms: r.req("latency_ms")?.as_f64()?,
                peak_bytes: r.req("peak_bytes")?.as_f64()? as u64,
                mem_stall_ms: r.req("mem_stall_ms")?.as_f64()?,
                wait_stall_ms: r.req("wait_stall_ms")?.as_f64()?,
                idle_fraction: r.req("idle_fraction")?.as_f64()?,
                tokens: r.req("tokens")?.as_usize()?,
                // absent in caches written before the hot-layer cache landed
                cache_hits: r.get("cache_hits").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64,
                cache_misses: r.get("cache_misses").and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
                    as u64,
                // absent in caches written before the KV-cache subsystem
                kv_inc_passes: r.get("kv_inc_passes").and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
                    as u64,
                kv_recomputes: r.get("kv_recomputes").and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
                    as u64,
                kv_evicted_blocks: r
                    .get("kv_evicted_blocks")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                // absent in caches written before KV prefix sharing
                shared_kv_blocks: r
                    .get("shared_kv_blocks")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                kv_dedup_bytes: r
                    .get("kv_dedup_bytes")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                // absent in caches written before the elastic controller
                budget_steps: r.get("budget_steps").and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
                    as u64,
                elastic_evictions: r
                    .get("elastic_evictions")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                replans: r.get("replans").and_then(|v| v.as_f64().ok()).unwrap_or(0.0) as u64,
                // absent in caches written before the overlapped-decode PR
                prefetched_stages: r
                    .get("prefetched_stages")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                prefetch_wasted: r
                    .get("prefetch_wasted")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                device_cache_hits: r
                    .get("device_cache_hits")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                spawns_avoided: r
                    .get("spawns_avoided")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                decode_p50_ms: r.get("decode_p50_ms").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                decode_p95_ms: r.get("decode_p95_ms").and_then(|v| v.as_f64().ok()).unwrap_or(0.0),
                tokens_per_sec: r
                    .get("tokens_per_sec")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0),
                // absent in caches written before the fault plane
                faults_injected: r
                    .get("faults_injected")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
                load_retries: r.get("load_retries").and_then(|v| v.as_f64().ok()).unwrap_or(0.0)
                    as u64,
                passes_timed_out: r
                    .get("passes_timed_out")
                    .and_then(|v| v.as_f64().ok())
                    .unwrap_or(0.0) as u64,
            })
        })
        .collect()
}

fn find<'a>(reports: &'a [RunReport], model: &str, mode: &str, agents: usize) -> Option<&'a RunReport> {
    reports
        .iter()
        .find(|r| r.model == model && r.mode == mode && (mode != "pipeload" || r.agents == agents))
}

/// Table II: performance comparison (latency + speedup vs baseline).
pub fn table2(reports: &[RunReport], agents: &[usize]) -> String {
    let mut headers: Vec<String> =
        vec!["Model".into(), "Baseline (ms)".into(), "PipeSwitch (ms)".into(), "PS speedup".into()];
    for m in agents {
        headers.push(format!("PL {m} LAs (ms)"));
        headers.push(format!("PL {m} speedup"));
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for model in PAPER_MODELS {
        let base = match find(reports, model, "baseline", 1) {
            Some(b) => b,
            None => continue,
        };
        let mut row = vec![model.to_string(), fmt_ms(base.latency_ms)];
        if let Some(ps) = find(reports, model, "pipeswitch", 1) {
            row.push(fmt_ms(ps.latency_ms));
            row.push(fmt_ratio(base.latency_ms / ps.latency_ms));
        } else {
            row.push("-".into());
            row.push("-".into());
        }
        for &m in agents {
            if let Some(pl) = find(reports, model, "pipeload", m) {
                row.push(fmt_ms(pl.latency_ms));
                row.push(fmt_ratio(base.latency_ms / pl.latency_ms));
            } else {
                row.push("-".into());
                row.push("-".into());
            }
        }
        t.row(row);
    }
    format!(
        "TABLE II: Performance comparison (speedup = T_baseline / T_other)\n{}",
        t.render()
    )
}

/// Table III: memory footprints (peak bytes + ratio vs baseline).
pub fn table3(reports: &[RunReport], agents: &[usize]) -> String {
    let mut headers: Vec<String> =
        vec!["Model".into(), "Baseline (MB)".into(), "PipeSwitch (MB)".into(), "PS ratio".into()];
    for m in agents {
        headers.push(format!("PL {m} LAs (MB)"));
        headers.push(format!("PL {m} ratio"));
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);
    for model in PAPER_MODELS {
        let base = match find(reports, model, "baseline", 1) {
            Some(b) => b,
            None => continue,
        };
        let mut row = vec![model.to_string(), fmt_mb(base.peak_bytes)];
        if let Some(ps) = find(reports, model, "pipeswitch", 1) {
            row.push(fmt_mb(ps.peak_bytes));
            row.push(fmt_ratio(ps.peak_bytes as f64 / base.peak_bytes as f64));
        } else {
            row.push("-".into());
            row.push("-".into());
        }
        for &m in agents {
            if let Some(pl) = find(reports, model, "pipeload", m) {
                row.push(fmt_mb(pl.peak_bytes));
                row.push(fmt_ratio(pl.peak_bytes as f64 / base.peak_bytes as f64));
            } else {
                row.push("-".into());
                row.push("-".into());
            }
        }
        t.row(row);
    }
    format!(
        "TABLE III: Memory footprints comparison (ratio = M_other / M_baseline)\n{}",
        t.render()
    )
}

/// Fig 7: latency + optimal #LAs under different memory constraints.
/// Generative pre-runs are bounded to 2 tokens (trend-preserving).
pub fn fig7(engine: &Engine, disk_name: &str, fractions: &[f64], max_agents: usize) -> Result<String> {
    let mut out = format!("Fig 7: evaluation under memory constraints (disk={disk_name})\n");
    for name in PAPER_MODELS {
        let stats = profile_one(engine, name, disk_name)?;
        let p = engine.runtime.profile(name)?;
        let total = p.total_weight_bytes;
        let min_feasible = planner::min_feasible_budget(&stats, p.body_kind());
        let budgets: Vec<u64> = fractions
            .iter()
            .map(|f| ((total as f64 * f) as u64).max(min_feasible))
            .collect();
        let p_gen = p.is_generative();
        let sched = planner::plan_with_tokens(
            engine, &stats, &budgets, max_agents, true,
            if p_gen { Some(2) } else { None },
        )?;
        out.push_str(&format!("\n{name} (model {:.0} MB):\n", total as f64 / MB));
        let mut t = Table::new(&["budget (MB)", "optimal #LAs", "latency (ms)", "peak (MB)"]);
        for e in &sched.entries {
            t.row(vec![
                fmt_mb(e.budget_bytes),
                e.agents.to_string(),
                fmt_ms(e.measured_latency_ms.unwrap_or(e.predicted_latency_ms)),
                e.measured_peak_bytes.map(fmt_mb).unwrap_or_else(|| "-".into()),
            ]);
        }
        out.push_str(&t.render());
    }
    out.push_str("\npaper: latency falls and optimal #LAs grows as the constraint relaxes\n");
    Ok(out)
}

/// Fig 1b / Obs II: pipeline-stall illustration on the standard pipeline.
///
/// Rendered from the telemetry bus through the offline analyzer
/// ([`crate::analyze::Analysis`]) — the SAME reconstruction `hermes
/// analyze` applies to a `--trace-out` file, so the figure and the
/// analytics can never drift apart.  Pass a `trace_out` path to also
/// export the run as Chrome trace-event JSON (load it into Perfetto /
/// `chrome://tracing` for the zoomable version — that backend scales to
/// multi-lane serving traces where the ASCII chart cannot).
pub fn fig1b(
    engine: &Engine,
    disk_name: &str,
    model: &str,
    trace_out: Option<&std::path::Path>,
) -> Result<String> {
    let telemetry = Telemetry::on();
    let cfg = RunConfig {
        profile: model.into(),
        mode: Mode::PipeSwitch,
        disk: disk_name.into(),
        ..RunConfig::default()
    };
    let mut session = engine.session(&cfg).open()?;
    session.set_telemetry(telemetry.clone());
    let (report, _) = session.run()?;
    drop(session);
    let events = telemetry.drain();
    let analysis = crate::analyze::Analysis::from_bus(&events, telemetry.dropped());
    let idle = analysis.inference_idle_fraction().unwrap_or(0.0);
    let mut out = format!(
        "Fig 1b: pipeline stall under the standard pipeline ({model}, disk={disk_name})\n\
         inference-lane idle fraction: {:.0}%  (paper: 60-80%)\n\
         end-to-end: {:.1} ms  (bubble {:.1} ms across {} pass(es))\n\n",
        idle * 100.0,
        report.latency_ms,
        analysis.bubble_total_ms(),
        analysis.passes.len()
    );
    out.push_str(&analysis.ascii_gantt(100));
    if let Some(path) = trace_out {
        crate::telemetry::chrome::write_chrome_trace(path, &events, telemetry.dropped())?;
        out.push_str(&format!(
            "\nchrome trace: {} event(s) -> {}\n",
            events.len(),
            path.display()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep(model: &str, mode: &str, agents: usize, lat: f64, peak: u64) -> RunReport {
        RunReport {
            model: model.into(),
            mode: mode.into(),
            agents,
            latency_ms: lat,
            peak_bytes: peak,
            mem_stall_ms: 0.0,
            wait_stall_ms: 0.0,
            idle_fraction: 0.0,
            tokens: 0,
            cache_hits: 0,
            cache_misses: 0,
            kv_inc_passes: 0,
            kv_recomputes: 0,
            kv_evicted_blocks: 0,
            shared_kv_blocks: 0,
            kv_dedup_bytes: 0,
            budget_steps: 0,
            elastic_evictions: 0,
            replans: 0,
            prefetched_stages: 0,
            prefetch_wasted: 0,
            device_cache_hits: 0,
            spawns_avoided: 0,
            decode_p50_ms: 0.0,
            decode_p95_ms: 0.0,
            tokens_per_sec: 0.0,
            faults_injected: 0,
            load_retries: 0,
            passes_timed_out: 0,
        }
    }

    #[test]
    fn table2_computes_speedups() {
        let reports = vec![
            rep("bert-large-sim", "baseline", 1, 100.0, 1000),
            rep("bert-large-sim", "pipeswitch", 1, 50.0, 1100),
            rep("bert-large-sim", "pipeload", 2, 25.0, 400),
        ];
        let s = table2(&reports, &[2]);
        assert!(s.contains("2.000"), "{s}"); // 100/50
        assert!(s.contains("4.000"), "{s}"); // 100/25
    }

    #[test]
    fn table3_computes_ratios() {
        let reports = vec![
            rep("bert-large-sim", "baseline", 1, 100.0, 1000 * 1024 * 1024),
            rep("bert-large-sim", "pipeload", 2, 25.0, 280 * 1024 * 1024),
        ];
        let s = table3(&reports, &[2]);
        assert!(s.contains("0.280"), "{s}");
    }

    #[test]
    fn reports_json_roundtrip() {
        let reports = vec![rep("m", "pipeload", 4, 12.5, 77)];
        let v = Value::Arr(reports.iter().map(|r| r.to_json()).collect());
        let back = parse_reports(&v).unwrap();
        assert_eq!(back[0].agents, 4);
        assert_eq!(back[0].peak_bytes, 77);
    }

    #[test]
    fn find_matches_pipeload_by_agents() {
        let reports = vec![
            rep("m", "pipeload", 2, 1.0, 1),
            rep("m", "pipeload", 4, 2.0, 2),
        ];
        assert_eq!(find(&reports, "m", "pipeload", 4).unwrap().latency_ms, 2.0);
        assert!(find(&reports, "m", "pipeload", 6).is_none());
    }
}
