//! Metrics: latency distributions, throughput, SLO checks, run reports,
//! and fixed-width table rendering for the paper-table harness.

use std::time::Duration;

use crate::util::json::Value;

/// Default `le` bucket bounds (ms) for the Prometheus latency histogram.
pub const DEFAULT_BUCKETS_MS: [f64; 12] =
    [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0, 2000.0, 5000.0];

/// Latency sample recorder with percentile queries.
///
/// Samples are mirrored into a sorted vector at record time
/// (binary-search insert), so every percentile query is an index — the
/// old implementation cloned and re-sorted ALL samples on each of the
/// p50/p95/p99 calls a single summary makes.
#[derive(Debug, Clone, Default)]
pub struct LatencyRecorder {
    samples_ms: Vec<f64>,
    sorted_ms: Vec<f64>,
}

impl LatencyRecorder {
    pub fn new() -> LatencyRecorder {
        LatencyRecorder::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.record_ms(d.as_secs_f64() * 1000.0);
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
        let i = self.sorted_ms.partition_point(|&x| x <= ms);
        self.sorted_ms.insert(i, ms);
    }

    pub fn len(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples_ms.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// Percentile via nearest-rank on the sorted mirror (p in [0,1]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.sorted_ms.is_empty() {
            return 0.0;
        }
        self.sorted_ms[(((self.sorted_ms.len() - 1) as f64) * p) as usize]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }

    pub fn max(&self) -> f64 {
        self.sorted_ms.last().copied().unwrap_or(0.0).max(0.0)
    }

    /// Raw samples in milliseconds, in record order (summary merging).
    pub fn samples_ms(&self) -> &[f64] {
        &self.samples_ms
    }

    /// Sum of all samples (the Prometheus `_sum` series).
    pub fn sum_ms(&self) -> f64 {
        self.samples_ms.iter().sum()
    }

    /// Cumulative bucket counts — samples `<=` each bound, in bound
    /// order (the Prometheus `le` histogram semantics).
    pub fn cumulative_buckets(&self, bounds_ms: &[f64]) -> Vec<u64> {
        bounds_ms.iter().map(|&b| self.sorted_ms.partition_point(|&x| x <= b) as u64).collect()
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("count", self.len())
            .set("mean_ms", self.mean())
            .set("p50_ms", self.p50())
            .set("p95_ms", self.p95())
            .set("p99_ms", self.p99())
            .set("max_ms", self.max())
    }
}

/// Result of one engine run (one table cell in the paper's evaluation).
#[derive(Debug, Clone)]
pub struct RunReport {
    pub model: String,
    pub mode: String,
    pub agents: usize,
    pub latency_ms: f64,
    pub peak_bytes: u64,
    /// time loading agents spent paused on the memory gate
    pub mem_stall_ms: f64,
    /// time the inference agent spent waiting for layers
    pub wait_stall_ms: f64,
    /// inference-lane idle fraction (Obs II / Fig 1b)
    pub idle_fraction: f64,
    pub tokens: usize,
    /// hot-layer cache: stages served from memory across the run's passes
    pub cache_hits: u64,
    /// hot-layer cache: stages that went to disk while a cache was attached
    pub cache_misses: u64,
    /// KV cache: decode tokens served by incremental single-token passes
    pub kv_inc_passes: u64,
    /// KV cache: decode tokens that fell back to full-prefix recompute
    /// after priming (eviction or exhausted KV budget)
    pub kv_recomputes: u64,
    /// KV cache: blocks reclaimed under `S^stop` pressure during this run
    pub kv_evicted_blocks: u64,
    /// KV prefix sharing: cross-request share events during this run
    /// (a block's refcount climbing past 1 via dedup or fork)
    pub shared_kv_blocks: u64,
    /// KV prefix sharing: bytes the accountant did NOT charge because an
    /// identical prefix block already existed (cumulative over the run)
    pub kv_dedup_bytes: u64,
    /// elastic controller: budget steps applied during this run
    pub budget_steps: u64,
    /// elastic controller: pins + KV blocks evicted by budget shrinks
    pub elastic_evictions: u64,
    /// elastic controller: epoch re-plans that changed the agent count
    pub replans: u64,
    /// cross-pass prefetch: stages loaded ahead of their pass
    pub prefetched_stages: u64,
    /// cross-pass prefetch: speculative loads reclaimed before use
    pub prefetch_wasted: u64,
    /// device-resident cache: stages that skipped host->device upload
    pub device_cache_hits: u64,
    /// worker pool: thread spawn/joins avoided vs the per-pass design
    pub spawns_avoided: u64,
    /// per-token decode latency p50 (generative runs; 0 otherwise)
    pub decode_p50_ms: f64,
    /// per-token decode latency p95 (generative runs; 0 otherwise)
    pub decode_p95_ms: f64,
    /// decode throughput over the whole request (generative runs)
    pub tokens_per_sec: f64,
    /// fault plane: faults the plan fired during this run
    pub faults_injected: u64,
    /// recovery: transient shard-load retries that kept the run alive
    pub load_retries: u64,
    /// recovery: passes the watchdog timed out and drained
    pub passes_timed_out: u64,
}

impl RunReport {
    /// Hot-layer cache hit fraction (0.0 when no cache was attached).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("model", self.model.clone())
            .set("mode", self.mode.clone())
            .set("agents", self.agents)
            .set("latency_ms", self.latency_ms)
            .set("peak_bytes", self.peak_bytes)
            .set("mem_stall_ms", self.mem_stall_ms)
            .set("wait_stall_ms", self.wait_stall_ms)
            .set("idle_fraction", self.idle_fraction)
            .set("tokens", self.tokens)
            .set("cache_hits", self.cache_hits)
            .set("cache_misses", self.cache_misses)
            .set("cache_hit_rate", self.cache_hit_rate())
            .set("kv_inc_passes", self.kv_inc_passes)
            .set("kv_recomputes", self.kv_recomputes)
            .set("kv_evicted_blocks", self.kv_evicted_blocks)
            .set("shared_kv_blocks", self.shared_kv_blocks)
            .set("kv_dedup_bytes", self.kv_dedup_bytes)
            .set("budget_steps", self.budget_steps)
            .set("elastic_evictions", self.elastic_evictions)
            .set("replans", self.replans)
            .set("prefetched_stages", self.prefetched_stages)
            .set("prefetch_wasted", self.prefetch_wasted)
            .set("device_cache_hits", self.device_cache_hits)
            .set("spawns_avoided", self.spawns_avoided)
            .set("decode_p50_ms", self.decode_p50_ms)
            .set("decode_p95_ms", self.decode_p95_ms)
            .set("tokens_per_sec", self.tokens_per_sec)
            .set("faults_injected", self.faults_injected)
            .set("load_retries", self.load_retries)
            .set("passes_timed_out", self.passes_timed_out)
    }
}

/// SLO verdict for the §V-C serving evaluation.
#[derive(Debug, Clone)]
pub struct SloReport {
    pub target_ms: f64,
    pub p95_ms: f64,
    pub met: bool,
}

impl SloReport {
    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("target_ms", self.target_ms)
            .set("p95_ms", self.p95_ms)
            .set("met", self.met)
    }
}

pub fn check_slo(lat: &LatencyRecorder, target_ms: f64) -> SloReport {
    let p95 = lat.p95();
    SloReport { target_ms, p95_ms: p95, met: p95 <= target_ms }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition (the `{"op":"metrics"}` TCP surface)
// ---------------------------------------------------------------------------

/// Append one `counter`-typed metric in Prometheus text format.
pub fn prometheus_counter(out: &mut String, name: &str, help: &str, v: u64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

/// Append one `gauge`-typed metric in Prometheus text format.
pub fn prometheus_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

/// Append one latency histogram (cumulative `le` buckets over
/// [`DEFAULT_BUCKETS_MS`] plus `+Inf`, `_sum`, `_count`).
pub fn prometheus_histogram(out: &mut String, name: &str, help: &str, lat: &LatencyRecorder) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    let counts = lat.cumulative_buckets(&DEFAULT_BUCKETS_MS);
    for (bound, count) in DEFAULT_BUCKETS_MS.iter().zip(counts) {
        let _ = writeln!(out, "{name}_bucket{{le=\"{bound}\"}} {count}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", lat.len());
    let _ = writeln!(out, "{name}_sum {}", lat.sum_ms());
    let _ = writeln!(out, "{name}_count {}", lat.len());
}

// ---------------------------------------------------------------------------
// fixed-width table rendering (the report harness prints paper-style rows)
// ---------------------------------------------------------------------------

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }
}

/// Format helpers used across report rows.
pub fn fmt_ms(ms: f64) -> String {
    format!("{ms:.1}")
}

pub fn fmt_mb(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / (1024.0 * 1024.0))
}

pub fn fmt_ratio(x: f64) -> String {
    format!("{x:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut l = LatencyRecorder::new();
        for i in 1..=100 {
            l.record_ms(i as f64);
        }
        assert_eq!(l.p50(), 50.0);
        assert_eq!(l.p95(), 95.0);
        assert_eq!(l.p99(), 99.0);
        assert_eq!(l.max(), 100.0);
        assert!((l.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_recorder_safe() {
        let l = LatencyRecorder::new();
        assert_eq!(l.p95(), 0.0);
        assert_eq!(l.mean(), 0.0);
        assert_eq!(l.max(), 0.0);
        assert!(l.is_empty());
        assert!(l.cumulative_buckets(&DEFAULT_BUCKETS_MS).iter().all(|&c| c == 0));
    }

    #[test]
    fn unsorted_records_query_correctly() {
        // the sorted mirror must hold regardless of arrival order
        let mut l = LatencyRecorder::new();
        for v in [50.0, 3.0, 99.0, 1.0, 75.0, 2.0, 60.0] {
            l.record_ms(v);
        }
        assert_eq!(l.max(), 99.0);
        assert_eq!(l.percentile(0.0), 1.0);
        assert_eq!(l.percentile(1.0), 99.0);
        assert_eq!(l.p50(), 50.0);
        // record order is preserved for merging
        assert_eq!(l.samples_ms()[0], 50.0);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut l = LatencyRecorder::new();
        for v in [0.5, 1.5, 4.0, 9.0, 150.0] {
            l.record_ms(v);
        }
        let c = l.cumulative_buckets(&[1.0, 5.0, 100.0]);
        assert_eq!(c, vec![1, 3, 4]);
        assert!((l.sum_ms() - 165.0).abs() < 1e-9);
    }

    #[test]
    fn prometheus_text_shapes() {
        let mut l = LatencyRecorder::new();
        l.record_ms(3.0);
        l.record_ms(7000.0); // beyond the largest bound: only +Inf holds it
        let mut out = String::new();
        prometheus_counter(&mut out, "hermes_served_total", "requests served", 4);
        prometheus_gauge(&mut out, "hermes_peak_bytes", "peak accountant bytes", 123.0);
        prometheus_histogram(&mut out, "hermes_latency_ms", "end-to-end latency", &l);
        assert!(out.contains("# TYPE hermes_served_total counter"));
        assert!(out.contains("hermes_served_total 4"));
        assert!(out.contains("hermes_peak_bytes 123"));
        assert!(out.contains("hermes_latency_ms_bucket{le=\"5\"} 1"));
        assert!(out.contains("hermes_latency_ms_bucket{le=\"5000\"} 1"));
        assert!(out.contains("hermes_latency_ms_bucket{le=\"+Inf\"} 2"));
        assert!(out.contains("hermes_latency_ms_count 2"));
    }

    #[test]
    fn slo_check() {
        let mut l = LatencyRecorder::new();
        for _ in 0..99 {
            l.record_ms(10.0);
        }
        l.record_ms(100.0);
        assert!(check_slo(&l, 50.0).met); // p95 = 10
        assert!(!check_slo(&l, 5.0).met);
    }

    #[test]
    fn cache_hit_rate_math() {
        let mut r = RunReport {
            model: "m".into(),
            mode: "pipeload".into(),
            agents: 2,
            latency_ms: 1.0,
            peak_bytes: 0,
            mem_stall_ms: 0.0,
            wait_stall_ms: 0.0,
            idle_fraction: 0.0,
            tokens: 0,
            cache_hits: 0,
            cache_misses: 0,
            kv_inc_passes: 0,
            kv_recomputes: 0,
            kv_evicted_blocks: 0,
            shared_kv_blocks: 0,
            kv_dedup_bytes: 0,
            budget_steps: 0,
            elastic_evictions: 0,
            replans: 0,
            prefetched_stages: 0,
            prefetch_wasted: 0,
            device_cache_hits: 0,
            spawns_avoided: 0,
            decode_p50_ms: 0.0,
            decode_p95_ms: 0.0,
            tokens_per_sec: 0.0,
            faults_injected: 0,
            load_retries: 0,
            passes_timed_out: 0,
        };
        assert_eq!(r.cache_hit_rate(), 0.0); // no cache attached
        r.cache_hits = 3;
        r.cache_misses = 1;
        assert!((r.cache_hit_rate() - 0.75).abs() < 1e-9);
        let v = r.to_json();
        assert_eq!(v.get("cache_hits").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "latency"]);
        t.row(vec!["bert".into(), "15891.5".into()]);
        t.row(vec!["vit-large-sim".into(), "3.1".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[2].contains("bert"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_mb(1024 * 1024), "1.0");
        assert_eq!(fmt_ms(12.34), "12.3");
        assert_eq!(fmt_ratio(0.28111), "0.281");
    }
}
