//! Hermes Weight Shard (.hws) format — rust side.
//!
//! Byte-for-byte mirror of `python/compile/hws.py` (see that module's
//! docstring for the layout). A shard holds one pipeline stage's weights:
//! the unit PIPELOAD's Loading Agents stream from disk and the Daemon
//! Agent destroys after compute.
//!
//! Also hosts the synthetic weight generator (`hermes gen-weights`): the
//! paper used HuggingFace checkpoints; we generate seeded uniform weights
//! at the manifest's exact specs (DESIGN.md section 3 — every reported
//! metric is a ratio, invariant to weight values).

pub mod gen;

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::{DType, TensorSpec};

pub const MAGIC: &[u8; 4] = b"HWSH";
pub const VERSION: u32 = 1;

/// One tensor: spec + raw little-endian data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn spec(&self) -> TensorSpec {
        TensorSpec { name: self.name.clone(), shape: self.shape.clone(), dtype: self.dtype }
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != DType::F32 {
            bail!("tensor {} is {:?}, not f32", self.name, self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != DType::I32 {
            bail!("tensor {} is {:?}, not i32", self.name, self.dtype);
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// One stage's weights.
#[derive(Debug, Clone, PartialEq)]
pub struct Shard {
    pub kind: String,
    pub stage: u32,
    pub tensors: Vec<Tensor>,
}

impl Shard {
    pub fn total_data_bytes(&self) -> u64 {
        self.tensors.iter().map(|t| t.data.len() as u64).sum()
    }
}

/// Fletcher-64 over little-endian u32 words (zero-padded tail).
///
/// Hot path for every shard load (§Perf): the modular reductions are
/// deferred across blocks of words — within a block, `a` grows by at most
/// `k * (2^32-1)` and `b` by `k*a0 + k(k+1)/2 * (2^32-1)`, so with
/// k = 8192 both stay far below 2^64 and one `%` per block suffices
/// (~20x faster than per-word reduction on this box; identical result).
pub fn fletcher64(data: &[u8]) -> u64 {
    const M: u64 = (1 << 32) - 1;
    const BLOCK_WORDS: usize = 8192;
    let (mut a, mut b) = (0u64, 0u64);
    let mut chunks = data.chunks_exact(4);
    let mut in_block = 0usize;
    for c in &mut chunks {
        let w = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u64;
        a += w;
        b += a;
        in_block += 1;
        if in_block == BLOCK_WORDS {
            a %= M;
            b %= M;
            in_block = 0;
        }
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut c = [0u8; 4];
        c[..rem.len()].copy_from_slice(rem);
        a += u32::from_le_bytes(c) as u64;
        b += a;
    }
    ((b % M) << 32) | (a % M)
}

/// Exact on-disk size of a shard with the given kind + tensor specs
/// (header + data + checksum footer) — used to detect stale shards.
pub fn encoded_size(kind: &str, specs: &[TensorSpec]) -> u64 {
    let mut n = 4 + 4 + 2 + kind.len() + 4 + 4; // magic,ver,kind,stage,count
    for s in specs {
        n += 2 + s.name.len() + 1 + 1 + 4 * s.shape.len() + 8;
        n += s.num_bytes();
    }
    (n + 8) as u64
}

/// Serialize a shard to bytes (header + data + checksum footer).
pub fn encode(shard: &Shard) -> Vec<u8> {
    let data_len: usize = shard.tensors.iter().map(|t| t.data.len()).sum();
    let mut out = Vec::with_capacity(data_len + 256);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    let kb = shard.kind.as_bytes();
    out.extend_from_slice(&(kb.len() as u16).to_le_bytes());
    out.extend_from_slice(kb);
    out.extend_from_slice(&shard.stage.to_le_bytes());
    out.extend_from_slice(&(shard.tensors.len() as u32).to_le_bytes());
    for t in &shard.tensors {
        let nb = t.name.as_bytes();
        out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        out.extend_from_slice(nb);
        out.push(t.dtype.code());
        out.push(t.shape.len() as u8);
        for d in &t.shape {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        out.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
    }
    for t in &shard.tensors {
        out.extend_from_slice(&t.data);
    }
    let csum = fletcher64(&out);
    out.extend_from_slice(&csum.to_le_bytes());
    out
}

pub fn write_shard(path: &Path, shard: &Shard) -> Result<u64> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let bytes = encode(shard);
    std::fs::write(path, &bytes).with_context(|| format!("writing {}", path.display()))?;
    Ok(bytes.len() as u64)
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .b
            .get(self.i..self.i + n)
            .ok_or_else(|| anyhow::anyhow!("shard truncated at byte {}", self.i))?;
        self.i += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }
}

/// Decode a shard from bytes, verifying the checksum.
pub fn decode(bytes: &[u8]) -> Result<Shard> {
    if bytes.len() < 12 {
        bail!("shard too small ({} bytes)", bytes.len());
    }
    let (body, footer) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(footer.try_into().unwrap());
    let got = fletcher64(body);
    if want != got {
        bail!("shard checksum mismatch: stored {want:#x}, computed {got:#x}");
    }
    let mut c = Cursor { b: body, i: 0 };
    if c.take(4)? != MAGIC {
        bail!("bad shard magic");
    }
    let version = c.u32()?;
    if version != VERSION {
        bail!("unsupported shard version {version}");
    }
    let kind = c.str()?;
    let stage = c.u32()?;
    let count = c.u32()? as usize;
    let mut headers = Vec::with_capacity(count);
    for _ in 0..count {
        let name = c.str()?;
        let dtype = DType::from_code(c.take(1)?[0])?;
        let ndim = c.take(1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32()? as usize);
        }
        let dlen = c.u64()? as usize;
        headers.push((name, dtype, shape, dlen));
    }
    let mut tensors = Vec::with_capacity(count);
    for (name, dtype, shape, dlen) in headers {
        let expect: usize = shape.iter().product::<usize>() * dtype.size_bytes();
        if expect != dlen {
            bail!("tensor {name}: shape/bytes mismatch ({expect} != {dlen})");
        }
        let data = c.take(dlen)?.to_vec();
        tensors.push(Tensor { name, dtype, shape, data });
    }
    if c.i != body.len() {
        bail!("shard has {} trailing bytes", body.len() - c.i);
    }
    Ok(Shard { kind, stage, tensors })
}

/// Read + decode from any reader (the throttled disk path uses this).
pub fn read_shard_from<R: Read>(mut r: R) -> Result<Shard> {
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    decode(&buf)
}

pub fn read_shard(path: &Path) -> Result<Shard> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading shard {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding {}", path.display()))
}

/// Validate a shard's tensors against manifest specs (names, shapes, dtypes).
pub fn validate_against(shard: &Shard, specs: &[TensorSpec]) -> Result<()> {
    if shard.tensors.len() != specs.len() {
        bail!(
            "shard has {} tensors, manifest expects {}",
            shard.tensors.len(),
            specs.len()
        );
    }
    for (t, s) in shard.tensors.iter().zip(specs) {
        if t.name != s.name || t.shape != s.shape || t.dtype != s.dtype {
            bail!(
                "tensor mismatch: shard has {} {:?} {:?}, manifest expects {} {:?} {:?}",
                t.name, t.dtype, t.shape, s.name, s.dtype, s.shape
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Shard {
        Shard {
            kind: "encoder_layer".into(),
            stage: 3,
            tensors: vec![
                Tensor {
                    name: "wq".into(),
                    dtype: DType::F32,
                    shape: vec![2, 3],
                    data: (0..6u32).flat_map(|i| (i as f32).to_le_bytes()).collect(),
                },
                Tensor {
                    name: "ids".into(),
                    dtype: DType::I32,
                    shape: vec![4],
                    data: (0..4i32).flat_map(|i| i.to_le_bytes()).collect(),
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let s = sample();
        let bytes = encode(&s);
        let got = decode(&bytes).unwrap();
        assert_eq!(s, got);
        assert_eq!(got.tensors[0].as_f32().unwrap(), vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = encode(&sample());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let bytes = encode(&sample());
        assert!(decode(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode(&bytes[..4]).is_err());
    }

    #[test]
    fn empty_shard() {
        let s = Shard { kind: "k".into(), stage: 0, tensors: vec![] };
        assert_eq!(decode(&encode(&s)).unwrap(), s);
    }

    #[test]
    fn validate_specs() {
        let s = sample();
        let specs = vec![
            TensorSpec { name: "wq".into(), shape: vec![2, 3], dtype: DType::F32 },
            TensorSpec { name: "ids".into(), shape: vec![4], dtype: DType::I32 },
        ];
        validate_against(&s, &specs).unwrap();
        let bad = vec![specs[1].clone(), specs[0].clone()];
        assert!(validate_against(&s, &bad).is_err());
        assert!(validate_against(&s, &specs[..1]).is_err());
    }

    #[test]
    fn fletcher_matches_python_semantics() {
        // identical algorithm to python/compile/hws.py: padded tail
        assert_eq!(fletcher64(b""), 0);
        assert_eq!(fletcher64(b"\x01"), fletcher64(b"\x01\x00\x00\x00"));
        assert_ne!(fletcher64(b"abcdefgh"), fletcher64(b"abcdefgi"));
    }
}

#[cfg(test)]
mod fletcher_equivalence {
    use super::fletcher64;

    /// Per-word reference (the python writer's exact algorithm).
    fn reference(data: &[u8]) -> u64 {
        const M: u64 = (1 << 32) - 1;
        let (mut a, mut b) = (0u64, 0u64);
        let mut it = data.chunks_exact(4);
        for c in &mut it {
            let w = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u64;
            a = (a + w) % M;
            b = (b + a) % M;
        }
        let rem = it.remainder();
        if !rem.is_empty() {
            let mut c = [0u8; 4];
            c[..rem.len()].copy_from_slice(rem);
            a = (a + u32::from_le_bytes(c) as u64) % M;
            b = (b + a) % M;
        }
        (b << 32) | a
    }

    #[test]
    fn deferred_reduction_matches_reference() {
        let mut rng = crate::util::rng::Rng::new(99);
        for len in [0usize, 1, 3, 4, 5, 4095, 4096 * 4, 8192 * 4 + 7, 100_000] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            assert_eq!(fletcher64(&data), reference(&data), "len={len}");
        }
        // worst-case magnitude: all 0xFF maximizes a and b growth
        let data = vec![0xFFu8; 8192 * 4 * 3 + 4];
        assert_eq!(fletcher64(&data), reference(&data));
    }
}

#[cfg(test)]
mod encoded_size_tests {
    use super::*;
    use crate::model::{DType, TensorSpec};

    #[test]
    fn encoded_size_matches_encode() {
        let specs = vec![
            TensorSpec { name: "wq".into(), shape: vec![2, 3], dtype: DType::F32 },
            TensorSpec { name: "b".into(), shape: vec![4], dtype: DType::I32 },
        ];
        let shard = Shard {
            kind: "encoder_layer".into(),
            stage: 0,
            tensors: specs
                .iter()
                .map(|s| Tensor {
                    name: s.name.clone(),
                    dtype: s.dtype,
                    shape: s.shape.clone(),
                    data: vec![0u8; s.num_bytes()],
                })
                .collect(),
        };
        assert_eq!(encode(&shard).len() as u64, encoded_size("encoder_layer", &specs));
    }
}
