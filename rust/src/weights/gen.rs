//! Synthetic weight generation (`hermes gen-weights`).
//!
//! The paper evaluates HuggingFace checkpoints; this image is offline, so we
//! generate seeded weights at the manifest's exact tensor specs.  Values are
//! uniform in [-scale, scale] with LayerNorm gains centered at 1.0 — enough
//! for numerically stable forward passes.  Every metric the paper reports is
//! a ratio over identical weights, so values are immaterial (DESIGN.md §3).

use std::path::Path;

use anyhow::Result;

use crate::model::{DType, Profile, TensorSpec};
use crate::util::rng::Rng;
use crate::weights::{encoded_size, write_shard, Shard, Tensor};

/// Fill one tensor with seeded values.
pub fn gen_tensor(spec: &TensorSpec, rng: &mut Rng, scale: f32) -> Tensor {
    let n = spec.num_elements();
    let mut data = Vec::with_capacity(n * spec.dtype.size_bytes());
    match spec.dtype {
        DType::F32 => {
            let center = if spec.name.ends_with("_g") { 1.0f32 } else { 0.0 };
            for _ in 0..n {
                let v = center + (rng.f32() * 2.0 - 1.0) * scale;
                data.extend_from_slice(&v.to_le_bytes());
            }
        }
        DType::I32 | DType::U32 => {
            for _ in 0..n {
                data.extend_from_slice(&(rng.range(0, 1 << 16) as u32).to_le_bytes());
            }
        }
        DType::F16 => {
            // stored as raw f16 bit patterns of small values (unused today)
            for _ in 0..n {
                let v = (rng.f32() * 2.0 - 1.0) * scale;
                data.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
    }
    Tensor { name: spec.name.clone(), dtype: spec.dtype, shape: spec.shape.clone(), data }
}

/// Minimal f32 -> f16 bit conversion (round-to-nearest-even not required
/// for synthetic weights; truncation is fine).
fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32 - 127 + 15;
    let frac = ((bits >> 13) & 0x3FF) as u16;
    if exp <= 0 {
        sign // flush to zero
    } else if exp >= 31 {
        sign | 0x7C00
    } else {
        sign | ((exp as u16) << 10) | frac
    }
}

/// Generate all stage shards for a profile into `dir/<profile>/stage_*.hws`.
/// Returns total bytes written.  Skips existing files unless `force`.
pub fn gen_profile_weights(
    profile: &Profile,
    dir: &Path,
    seed: u64,
    scale: f32,
    force: bool,
) -> Result<u64> {
    let pdir = dir.join(&profile.name);
    std::fs::create_dir_all(&pdir)?;
    let mut base = Rng::new(seed ^ fxhash(profile.name.as_bytes()));
    let mut total = 0u64;
    for stage in &profile.stages {
        let path = pdir.join(&stage.shard);
        let mut rng = base.fork(stage.index as u64);
        let specs = profile.stage_params(stage)?;
        if !force && path.exists() {
            // self-heal: regenerate when the manifest specs changed size
            let expect = encoded_size(&stage.kind, specs);
            let have = std::fs::metadata(&path)?.len();
            if have == expect {
                total += have;
                continue;
            }
        }
        let tensors: Vec<Tensor> =
            specs.iter().map(|s| gen_tensor(s, &mut rng, scale)).collect();
        let shard = Shard { kind: stage.kind.clone(), stage: stage.index as u32, tensors };
        total += write_shard(&path, &shard)?;
    }
    Ok(total)
}

/// Tiny FNV-style hash for name->seed mixing.
fn fxhash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DType;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype: DType::F32 }
    }

    #[test]
    fn tensor_values_bounded() {
        let mut rng = Rng::new(1);
        let t = gen_tensor(&spec("w", &[100]), &mut rng, 0.05);
        for v in t.as_f32().unwrap() {
            assert!(v.abs() <= 0.05 + 1e-6, "{v}");
        }
    }

    #[test]
    fn ln_gain_centered_at_one() {
        let mut rng = Rng::new(2);
        let t = gen_tensor(&spec("ln1_g", &[64]), &mut rng, 0.05);
        let vals = t.as_f32().unwrap();
        let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
        assert!((mean - 1.0).abs() < 0.05, "{mean}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let ta = gen_tensor(&spec("w", &[32]), &mut a, 0.1);
        let tb = gen_tensor(&spec("w", &[32]), &mut b, 0.1);
        assert_eq!(ta.data, tb.data);
    }

    #[test]
    fn f16_conversion_special_cases() {
        assert_eq!(f32_to_f16_bits(0.0), 0);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(1e10), 0x7C00); // overflow -> inf
    }
}
