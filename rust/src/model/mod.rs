//! Model registry: typed view of `artifacts/manifest.json`.
//!
//! The AOT step (`python -m compile.aot`) is the single source of truth for
//! architecture dims, per-layer tensor specs, stage tables, and HLO entry
//! shapes; this module only *parses* it. Rust never re-derives tensor
//! shapes, so the two languages cannot drift (DESIGN.md section 2).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Element type of a tensor (matches the .hws dtype codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
    F16,
}

impl DType {
    pub fn from_str(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            "f16" => DType::F16,
            _ => bail!("unknown dtype '{s}'"),
        })
    }

    pub fn from_code(c: u8) -> Result<DType> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U32,
            3 => DType::F16,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
            DType::U32 => 2,
            DType::F16 => 3,
        }
    }

    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::F16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
            DType::F16 => "f16",
        }
    }

    /// Matching XLA element type for literal construction.
    pub fn xla(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
            DType::F16 => xla::ElementType::F16,
        }
    }
}

/// One named tensor inside a stage shard (ordered).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn num_bytes(&self) -> usize {
        self.num_elements() * self.dtype.size_bytes()
    }

    fn parse(v: &Value) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: DType::from_str(v.req("dtype")?.as_str()?)?,
        })
    }
}

/// One pipeline stage (what a Loading Agent loads and the Daemon destroys).
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub index: usize,
    pub kind: String,
    pub shard: String,
}

/// One AOT-compiled HLO entry (layer kind x batch).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub key: String,
    pub kind: String,
    pub batch: usize,
    /// path relative to the artifacts root
    pub hlo: String,
    pub activations: Vec<TensorSpec>,
    pub output: TensorSpec,
}

/// Per-layer-kind parameter table.
#[derive(Debug, Clone)]
pub struct KindSpec {
    pub params: Vec<TensorSpec>,
    pub param_bytes: u64,
}

/// A model profile: architecture dims + stage table + HLO entry index.
#[derive(Debug, Clone)]
pub struct Profile {
    pub name: String,
    pub family: String,
    pub arch: String,
    pub paper_model: String,
    pub hidden: usize,
    pub heads: usize,
    pub ffn: usize,
    pub layers: usize,
    pub decoder_layers: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub num_classes: usize,
    pub patch_dim: usize,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    pub batches: Vec<usize>,
    pub stages: Vec<StageSpec>,
    pub kinds: HashMap<String, KindSpec>,
    pub entries: HashMap<String, EntrySpec>,
    pub total_weight_bytes: u64,
}

impl Profile {
    /// Is this a generative (per-token pipelined decode) model?
    pub fn is_generative(&self) -> bool {
        self.family == "gpt2" || self.family == "gptj" || self.family == "bart"
    }

    /// The dominant body layer kind ("encoder_layer", "decoder_layer", ...).
    pub fn body_kind(&self) -> &str {
        match self.family.as_str() {
            "bert" | "vit" => "encoder_layer",
            "gpt2" => "decoder_layer",
            "gptj" => "gptj_layer",
            "bart" => "cross_decoder_layer",
            _ => "encoder_layer",
        }
    }

    /// Ordered tensor specs for a stage (by its layer kind).
    pub fn stage_params(&self, stage: &StageSpec) -> Result<&[TensorSpec]> {
        Ok(&self
            .kinds
            .get(&stage.kind)
            .ok_or_else(|| anyhow!("no kind spec for '{}'", stage.kind))?
            .params)
    }

    /// Weight bytes of one stage.
    pub fn stage_bytes(&self, stage: &StageSpec) -> u64 {
        self.kinds.get(&stage.kind).map(|k| k.param_bytes).unwrap_or(0)
    }

    /// Bytes of the largest stage — the admission-feasibility floor (a
    /// budget below this can never admit that stage; the pin-cap liveness
    /// rule and the elastic controller's clamp both derive from it).
    pub fn max_stage_bytes(&self) -> u64 {
        self.stages.iter().map(|s| self.stage_bytes(s)).max().unwrap_or(0)
    }

    /// HLO entry for (kind, batch).
    pub fn entry(&self, kind: &str, batch: usize) -> Result<&EntrySpec> {
        self.entries
            .get(&format!("{kind}@b{batch}"))
            .ok_or_else(|| anyhow!("profile {} has no entry {kind}@b{batch}", self.name))
    }

    /// Average body-layer weight bytes (planner's per-LA memory increment).
    pub fn body_layer_bytes(&self) -> u64 {
        self.kinds.get(self.body_kind()).map(|k| k.param_bytes).unwrap_or(0)
    }

    /// Bytes of non-body stages (embedding + head resident overhead).
    pub fn other_bytes(&self) -> u64 {
        self.stages
            .iter()
            .filter(|s| s.kind != self.body_kind() && s.kind != "encoder_layer")
            .map(|s| self.stage_bytes(s))
            .sum()
    }

    fn parse(name: &str, v: &Value) -> Result<Profile> {
        let cfg = v.req("config")?;
        let geti = |k: &str| -> usize { cfg.get(k).and_then(|x| x.as_usize().ok()).unwrap_or(0) };
        let stages = v
            .req("stages")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(StageSpec {
                    index: s.req("index")?.as_usize()?,
                    kind: s.req("kind")?.as_str()?.to_string(),
                    shard: s.req("shard")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut kinds = HashMap::new();
        for (k, kv) in v.req("kinds")?.as_obj()? {
            let params = kv
                .req("params")?
                .as_arr()?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let param_bytes = kv.req("param_bytes")?.as_f64()? as u64;
            kinds.insert(k.clone(), KindSpec { params, param_bytes });
        }
        let mut entries = HashMap::new();
        for (k, ev) in v.req("entries")?.as_obj()? {
            let activations = ev
                .req("activations")?
                .as_arr()?
                .iter()
                .map(TensorSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                k.clone(),
                EntrySpec {
                    key: k.clone(),
                    kind: ev.req("kind")?.as_str()?.to_string(),
                    batch: ev.req("batch")?.as_usize()?,
                    hlo: ev.req("hlo")?.as_str()?.to_string(),
                    activations,
                    output: TensorSpec::parse(ev.req("output")?)?,
                },
            );
        }
        Ok(Profile {
            name: name.to_string(),
            family: cfg.req("family")?.as_str()?.to_string(),
            arch: cfg.req("arch")?.as_str()?.to_string(),
            paper_model: cfg
                .get("paper_model")
                .and_then(|x| x.as_str().ok())
                .unwrap_or("")
                .to_string(),
            hidden: geti("hidden"),
            heads: geti("heads"),
            ffn: geti("ffn"),
            layers: geti("layers"),
            decoder_layers: geti("decoder_layers"),
            vocab: geti("vocab"),
            max_seq: geti("max_seq"),
            num_classes: geti("num_classes"),
            patch_dim: geti("patch_dim"),
            prompt_tokens: geti("prompt_tokens"),
            gen_tokens: geti("gen_tokens"),
            batches: cfg
                .get("batches")
                .and_then(|b| b.as_arr().ok())
                .map(|a| a.iter().filter_map(|x| x.as_usize().ok()).collect())
                .unwrap_or_else(|| vec![1]),
            stages,
            kinds,
            entries,
            total_weight_bytes: v.req("total_weight_bytes")?.as_f64()? as u64,
        })
    }
}

/// The parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub profiles: HashMap<String, Profile>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let v = Value::from_file(&path).with_context(|| {
            format!(
                "loading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let mut profiles = HashMap::new();
        for (name, pv) in v.req("profiles")?.as_obj()? {
            profiles.insert(
                name.clone(),
                Profile::parse(name, pv).with_context(|| format!("profile {name}"))?,
            );
        }
        Ok(Manifest { root: artifacts_dir.to_path_buf(), profiles })
    }

    pub fn profile(&self, name: &str) -> Result<&Profile> {
        self.profiles.get(name).ok_or_else(|| {
            anyhow!(
                "unknown profile '{name}' (have: {})",
                self.profiles.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.root.join(&entry.hlo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "profiles": {
        "t": {
          "config": {"family": "bert", "arch": "encoder", "hidden": 8,
                     "heads": 2, "ffn": 16, "layers": 2, "vocab": 16,
                     "max_seq": 4, "batches": [1]},
          "stages": [
            {"index": 0, "kind": "embedding", "shard": "stage_000.hws"},
            {"index": 1, "kind": "encoder_layer", "shard": "stage_001.hws"},
            {"index": 2, "kind": "encoder_layer", "shard": "stage_002.hws"},
            {"index": 3, "kind": "pooler", "shard": "stage_003.hws"}
          ],
          "kinds": {
            "embedding": {"params": [{"name": "tok", "shape": [16, 8], "dtype": "f32"}],
                          "param_bytes": 512},
            "encoder_layer": {"params": [{"name": "wq", "shape": [8, 8], "dtype": "f32"}],
                              "param_bytes": 256},
            "pooler": {"params": [{"name": "pw", "shape": [8, 8], "dtype": "f32"}],
                       "param_bytes": 256}
          },
          "entries": {
            "encoder_layer@b1": {
              "kind": "encoder_layer", "batch": 1, "hlo": "t/encoder_layer.b1.hlo.txt",
              "activations": [{"name": "x", "shape": [1, 4, 8], "dtype": "f32"}],
              "output": {"name": "x", "shape": [1, 4, 8], "dtype": "f32"}
            }
          },
          "total_weight_bytes": 1280
        }
      }
    }"#;

    fn sample() -> Profile {
        let v = Value::parse(SAMPLE).unwrap();
        Profile::parse("t", v.req("profiles").unwrap().get("t").unwrap()).unwrap()
    }

    #[test]
    fn parses_profile() {
        let p = sample();
        assert_eq!(p.hidden, 8);
        assert_eq!(p.stages.len(), 4);
        assert_eq!(p.body_kind(), "encoder_layer");
        assert_eq!(p.body_layer_bytes(), 256);
        assert_eq!(p.other_bytes(), 512 + 256);
        assert!(!p.is_generative());
    }

    #[test]
    fn entry_lookup() {
        let p = sample();
        let e = p.entry("encoder_layer", 1).unwrap();
        assert_eq!(e.activations[0].shape, vec![1, 4, 8]);
        assert_eq!(e.output.num_elements(), 32);
        assert!(p.entry("encoder_layer", 9).is_err());
        assert!(p.entry("nope", 1).is_err());
    }

    #[test]
    fn tensor_spec_sizes() {
        let t = TensorSpec { name: "w".into(), shape: vec![3, 4], dtype: DType::F32 };
        assert_eq!(t.num_elements(), 12);
        assert_eq!(t.num_bytes(), 48);
        assert_eq!(DType::F16.size_bytes(), 2);
        for d in [DType::F32, DType::I32, DType::U32, DType::F16] {
            assert_eq!(DType::from_code(d.code()).unwrap(), d);
            assert_eq!(DType::from_str(d.name()).unwrap(), d);
        }
    }

    #[test]
    fn stage_param_access() {
        let p = sample();
        let st = &p.stages[1];
        assert_eq!(p.stage_params(st).unwrap()[0].name, "wq");
        assert_eq!(p.stage_bytes(st), 256);
    }
}
