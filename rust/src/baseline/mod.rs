//! Comparator executors (paper section V-A2).
//!
//! * **Baseline** — the non-pipeline workflow: load the *whole* model
//!   first (one disk stream), then run inference over the resident
//!   shards.  Generative models load once and then infer once per token,
//!   which is exactly why the paper's Table II shows pipelines *losing*
//!   to the baseline at low agent counts for GPT-style models.
//! * **PipeSwitch-style standard pipeline** — provided by
//!   [`crate::pipeload::PipelineOpts::pipeswitch`] (one loading stream,
//!   layer-granularity load/compute overlap, no weight destruction).

use anyhow::{anyhow, Context, Result};

use crate::memory::MemoryAccountant;
use crate::pipeload::{ExecCtx, ModelInput, PassStats};
use crate::signals::Signal;
use crate::trace::{Kind, Lane};
use crate::weights::{read_shard_from, Shard};

/// The fully-loaded model: every stage shard resident in memory.
pub struct ResidentModel {
    pub shards: Vec<Shard>,
    pub bytes: u64,
    pub load_ms: f64,
}

/// Phase 1 of the baseline: stream every shard into memory (single stream).
pub fn load_all(ctx: &ExecCtx, accountant: &MemoryAccountant) -> Result<ResidentModel> {
    let mut shards = Vec::with_capacity(ctx.profile.stages.len());
    let mut bytes = 0u64;
    let t0 = ctx.tracer.now_ms();
    for stage in &ctx.profile.stages {
        let b = ctx.profile.stage_bytes(stage);
        accountant
            .acquire(b)
            .with_context(|| format!("baseline loading stage {}", stage.index))?;
        let s0 = ctx.tracer.now_ms();
        let reader = ctx.disk.open(&ctx.shard_dir.join(&stage.shard))?;
        let shard = read_shard_from(reader)
            .with_context(|| format!("shard {}", stage.shard))?;
        ctx.tracer
            .record(Lane::Loader(0), Kind::Load, Some(stage.index), s0, ctx.tracer.now_ms());
        bytes += b;
        shards.push(shard);
    }
    Ok(ResidentModel { shards, bytes, load_ms: ctx.tracer.now_ms() - t0 })
}

/// Phase 2: one forward pass over resident shards (no loading, no daemon).
pub fn forward_resident(
    ctx: &ExecCtx,
    model: &ResidentModel,
    accountant: &MemoryAccountant,
    input: &ModelInput,
) -> Result<(xla::PjRtBuffer, PassStats)> {
    let profile = ctx.profile;
    let mut stats = PassStats::default();
    let mut act: Option<xla::PjRtBuffer> = None;
    let mut act_bytes = 0u64;
    let mut enc_out: Option<xla::PjRtBuffer> = None;
    let mut enc_out_bytes = 0u64;

    for (k, stage) in profile.stages.iter().enumerate() {
        let entry = profile.entry(&stage.kind, ctx.batch)?;
        let shard = &model.shards[k];
        if k == 0 {
            let b = input.to_buffer(ctx.runtime, &entry.activations[0])?;
            act_bytes = entry.activations[0].num_bytes() as u64;
            accountant.force_add(act_bytes);
            act = Some(b);
        } else if stage.kind == "cross_decoder_layer" && enc_out.is_none() {
            enc_out_bytes = act_bytes;
            accountant.force_add(enc_out_bytes);
            enc_out = act.take();
        }
        let x_ref;
        let act_refs: Vec<&xla::PjRtBuffer> = if stage.kind == "cross_decoder_layer" {
            let enc = enc_out.as_ref().unwrap();
            match act.as_ref() {
                Some(x) => vec![x, enc],
                None => vec![enc, enc],
            }
        } else {
            x_ref = act.as_ref().ok_or_else(|| anyhow!("no activation at stage {k}"))?;
            vec![x_ref]
        };

        // transient weight upload inside execute
        accountant.force_add(ctx.profile.stage_bytes(stage));
        let t0 = ctx.tracer.now_ms();
        let out = ctx
            .runtime
            .execute_entry(profile, entry, &act_refs, shard)
            .with_context(|| format!("baseline executing stage {k}"))?;
        let t1 = ctx.tracer.now_ms();
        ctx.tracer.record(Lane::Inference, Kind::Compute, Some(k), t0, t1);
        stats.compute_ms_total += t1 - t0;
        accountant.free(ctx.profile.stage_bytes(stage));

        let out_bytes = entry.output.num_bytes() as u64;
        accountant.force_add(out_bytes);
        accountant.free(act_bytes);
        act_bytes = out_bytes;
        act = Some(out);
        ctx.signals.emit(Signal::Comp { stage: k, agent: 0 });
    }
    if enc_out.is_some() {
        accountant.free(enc_out_bytes);
    }
    accountant.free(act_bytes);
    stats.peak_bytes = accountant.peak();
    Ok((act.unwrap(), stats))
}
