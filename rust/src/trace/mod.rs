//! Execution trace: spans per agent, stall analysis, ASCII Gantt.
//!
//! Feeds two paper artifacts: the Fig-1b pipeline-stall illustration (the
//! standard pipeline leaves compute idle 60–80% of the time — Obs II) and
//! debugging output for the PIPELOAD schedule itself
//! (`hermes report --figure 1b`, `hermes run --trace`).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Value;

/// Which worker produced a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    Loader(usize),
    Inference,
    Daemon,
    Driver,
}

impl Lane {
    pub fn label(&self) -> String {
        match self {
            Lane::Loader(i) => format!("LA{}", i + 1),
            Lane::Inference => "IA".into(),
            Lane::Daemon => "DA".into(),
            Lane::Driver => "drv".into(),
        }
    }
}

/// What the span was doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Load,
    Compute,
    Destroy,
    /// blocked on the memory gate (S^stop)
    StallMem,
    /// inference waiting for the next layer (pipeline stall, Fig 1b)
    StallWait,
    /// daemon pinned a layer into the hot-layer cache instead of destroying
    Pin,
    /// speculative next-pass load (cross-pass prefetch overlap)
    Prefetch,
}

impl Kind {
    fn glyph(&self) -> char {
        match self {
            Kind::Load => 'L',
            Kind::Compute => '#',
            Kind::Destroy => 'd',
            Kind::StallMem => 's',
            Kind::StallWait => '.',
            Kind::Pin => 'P',
            Kind::Prefetch => 'p',
        }
    }

    fn name(&self) -> &'static str {
        match self {
            Kind::Load => "load",
            Kind::Compute => "compute",
            Kind::Destroy => "destroy",
            Kind::StallMem => "stall_mem",
            Kind::StallWait => "stall_wait",
            Kind::Pin => "pin",
            Kind::Prefetch => "prefetch",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Span {
    pub lane: Lane,
    pub kind: Kind,
    pub stage: Option<usize>,
    /// ms since trace start
    pub t0: f64,
    pub t1: f64,
}

/// Thread-safe trace recorder; clone shares the buffer.
#[derive(Debug, Clone)]
pub struct Tracer {
    start: Instant,
    spans: Arc<Mutex<Vec<Span>>>,
    enabled: bool,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new(true)
    }
}

impl Tracer {
    pub fn new(enabled: bool) -> Tracer {
        Tracer { start: Instant::now(), spans: Arc::new(Mutex::new(Vec::new())), enabled }
    }

    pub fn disabled() -> Tracer {
        Tracer::new(false)
    }

    pub fn now_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1000.0
    }

    /// Record a span with explicit timestamps (ms since trace start).
    pub fn record(&self, lane: Lane, kind: Kind, stage: Option<usize>, t0: f64, t1: f64) {
        if !self.enabled {
            return;
        }
        self.spans.lock().unwrap().push(Span { lane, kind, stage, t0, t1 });
    }

    /// Time a closure and record it.
    pub fn span<R>(&self, lane: Lane, kind: Kind, stage: Option<usize>, f: impl FnOnce() -> R) -> R {
        let t0 = self.now_ms();
        let r = f();
        self.record(lane, kind, stage, t0, self.now_ms());
        r
    }

    pub fn snapshot(&self) -> Vec<Span> {
        self.spans.lock().unwrap().clone()
    }

    /// Fraction of the busy window the inference lane spent NOT computing
    /// (the paper's "60–80% idle" stall metric, Obs II).
    pub fn inference_idle_fraction(&self) -> Option<f64> {
        let spans = self.snapshot();
        let inf: Vec<&Span> = spans.iter().filter(|s| s.lane == Lane::Inference).collect();
        if inf.is_empty() {
            return None;
        }
        let t_first = inf.iter().map(|s| s.t0).fold(f64::INFINITY, f64::min);
        let t_last = inf.iter().map(|s| s.t1).fold(0.0, f64::max);
        let window = t_last - t_first;
        if window <= 0.0 {
            return None;
        }
        let busy: f64 = inf
            .iter()
            .filter(|s| s.kind == Kind::Compute)
            .map(|s| s.t1 - s.t0)
            .sum();
        Some((1.0 - busy / window).clamp(0.0, 1.0))
    }

    /// Total stall time per kind across lanes.
    pub fn stall_ms(&self, kind: Kind) -> f64 {
        self.snapshot()
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| s.t1 - s.t0)
            .sum()
    }

    pub fn to_json(&self) -> Value {
        Value::Arr(
            self.snapshot()
                .iter()
                .map(|s| {
                    let mut o = Value::obj()
                        .set("lane", s.lane.label())
                        .set("kind", s.kind.name())
                        .set("t0_ms", s.t0)
                        .set("t1_ms", s.t1);
                    if let Some(stage) = s.stage {
                        o = o.set("stage", stage);
                    }
                    o
                })
                .collect(),
        )
    }

    /// ASCII Gantt chart: one row per lane, `width` columns over the trace
    /// window.  `L` load, `#` compute, `d` destroy, `s` memory stall,
    /// `.` waiting for a layer.
    pub fn ascii_gantt(&self, width: usize) -> String {
        let spans = self.snapshot();
        if spans.is_empty() {
            return "(empty trace)\n".into();
        }
        let t_max = spans.iter().map(|s| s.t1).fold(0.0, f64::max).max(1e-9);
        let mut lanes: Vec<Lane> = Vec::new();
        for s in &spans {
            if !lanes.contains(&s.lane) {
                lanes.push(s.lane);
            }
        }
        lanes.sort_by_key(|l| match l {
            Lane::Driver => (0, 0),
            Lane::Loader(i) => (1, *i),
            Lane::Inference => (2, 0),
            Lane::Daemon => (3, 0),
        });
        let mut out = String::new();
        out.push_str(&format!("trace window: {:.1} ms, {} spans\n", t_max, spans.len()));
        for lane in lanes {
            let mut row = vec![' '; width];
            for s in spans.iter().filter(|s| s.lane == lane) {
                let a = ((s.t0 / t_max) * width as f64) as usize;
                let b = (((s.t1 / t_max) * width as f64).ceil() as usize).min(width);
                for c in row.iter_mut().take(b.max(a + 1)).skip(a.min(width - 1)) {
                    *c = s.kind.glyph();
                }
            }
            out.push_str(&format!("{:>4} |{}|\n", lane.label(), row.iter().collect::<String>()));
        }
        out.push_str("      L=load  #=compute  d=destroy  P=pin  s=mem-stall  .=wait-stall\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_fraction_computation() {
        let t = Tracer::new(true);
        // window 0..100, compute 20..40 => idle 80%
        t.record(Lane::Inference, Kind::StallWait, None, 0.0, 20.0);
        t.record(Lane::Inference, Kind::Compute, Some(0), 20.0, 40.0);
        t.record(Lane::Inference, Kind::StallWait, None, 40.0, 100.0);
        let idle = t.inference_idle_fraction().unwrap();
        assert!((idle - 0.8).abs() < 1e-9, "{idle}");
    }

    #[test]
    fn no_inference_spans_none() {
        let t = Tracer::new(true);
        t.record(Lane::Loader(0), Kind::Load, Some(0), 0.0, 10.0);
        assert!(t.inference_idle_fraction().is_none());
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        t.record(Lane::Inference, Kind::Compute, None, 0.0, 1.0);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn gantt_renders_lanes() {
        let t = Tracer::new(true);
        t.record(Lane::Loader(0), Kind::Load, Some(0), 0.0, 50.0);
        t.record(Lane::Loader(1), Kind::Load, Some(1), 0.0, 60.0);
        t.record(Lane::Inference, Kind::Compute, Some(0), 50.0, 55.0);
        let g = t.ascii_gantt(40);
        assert!(g.contains("LA1"), "{g}");
        assert!(g.contains("LA2"));
        assert!(g.contains("IA"));
        assert!(g.contains('L'));
        assert!(g.contains('#'));
    }

    #[test]
    fn stall_totals() {
        let t = Tracer::new(true);
        t.record(Lane::Loader(0), Kind::StallMem, None, 0.0, 5.0);
        t.record(Lane::Loader(1), Kind::StallMem, None, 2.0, 4.0);
        assert!((t.stall_ms(Kind::StallMem) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn json_export_shape() {
        let t = Tracer::new(true);
        t.record(Lane::Daemon, Kind::Destroy, Some(2), 1.0, 2.0);
        let v = t.to_json();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("lane").unwrap().as_str().unwrap(), "DA");
        assert_eq!(arr[0].get("stage").unwrap().as_usize().unwrap(), 2);
    }
}
