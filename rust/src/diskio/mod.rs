//! Throttled disk reader: the edge-storage simulator.
//!
//! The paper's testbed reads checkpoints from server-class storage inside a
//! docker memory jail; its key premise (Obs II) is that **per-layer load
//! latency dwarfs compute latency** on edge devices (eMMC/SD-class storage),
//! and that several Loading Agents can stream in parallel until the medium's
//! aggregate bandwidth saturates.
//!
//! This module reproduces exactly that regime on any host:
//!
//! * a **per-stream** bandwidth limit (one Loading Agent's sequential read
//!   speed — controller queue depth 1),
//! * a global **aggregate** token bucket shared by all streams (the
//!   medium's total bandwidth — parallel agents scale until they hit it),
//! * a fixed **per-open latency** (seek / FTL lookup).
//!
//! Throttling is sleep-based, so on a 1-core box loading overlaps compute
//! exactly like real blocking I/O would. `unthrottled` passes reads through
//! for raw-host benchmarking.

use std::io::Read;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::faults::{FaultInjector, FaultKind};

/// Simulated storage medium parameters.
#[derive(Debug, Clone)]
pub struct DiskProfile {
    pub name: String,
    /// one stream's max sequential read bandwidth (bytes/sec); 0 = unlimited
    pub per_stream_bps: u64,
    /// total medium bandwidth shared across streams; 0 = unlimited
    pub aggregate_bps: u64,
    /// fixed cost per file open (seek, FTL)
    pub open_latency: Duration,
    /// throttle granularity
    pub chunk_bytes: usize,
}

impl DiskProfile {
    /// Named presets; calibration notes in EXPERIMENTS.md Fig-3 section.
    pub fn preset(name: &str) -> Result<DiskProfile> {
        let mb = |x: u64| x * 1000 * 1000;
        Ok(match name {
            // eMMC 5.1-class: ~90 MB/s a stream, controller tops out ~620
            "edge-emmc" => DiskProfile {
                name: name.into(),
                per_stream_bps: mb(90),
                aggregate_bps: mb(620),
                open_latency: Duration::from_micros(1500),
                chunk_bytes: 256 * 1024,
            },
            // SD/UHS-I card: slow streams, saturates at ~80 MB/s total
            "edge-sd" => DiskProfile {
                name: name.into(),
                per_stream_bps: mb(23),
                aggregate_bps: mb(80),
                open_latency: Duration::from_micros(4000),
                chunk_bytes: 128 * 1024,
            },
            // small NVMe (Jetson-class): fast streams, wide controller
            "edge-nvme" => DiskProfile {
                name: name.into(),
                per_stream_bps: mb(450),
                aggregate_bps: mb(2200),
                open_latency: Duration::from_micros(300),
                chunk_bytes: 512 * 1024,
            },
            "unthrottled" => DiskProfile {
                name: name.into(),
                per_stream_bps: 0,
                aggregate_bps: 0,
                open_latency: Duration::ZERO,
                chunk_bytes: 1024 * 1024,
            },
            _ => bail!(
                "unknown disk profile '{name}' (edge-emmc, edge-sd, edge-nvme, unthrottled)"
            ),
        })
    }

    /// Custom profile (used by tests and the Fig-3 calibration sweep).
    pub fn custom(per_stream_bps: u64, aggregate_bps: u64, open_us: u64) -> DiskProfile {
        DiskProfile {
            name: "custom".into(),
            per_stream_bps,
            aggregate_bps,
            open_latency: Duration::from_micros(open_us),
            chunk_bytes: 64 * 1024,
        }
    }
}

/// Shared token bucket enforcing the aggregate bandwidth cap.
#[derive(Debug)]
struct TokenBucket {
    state: Mutex<BucketState>,
    rate_bps: f64,
    burst: f64,
}

#[derive(Debug)]
struct BucketState {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(rate_bps: u64) -> TokenBucket {
        let burst = (rate_bps as f64 * 0.01).max(128.0 * 1024.0); // ~10ms of burst
        TokenBucket {
            state: Mutex::new(BucketState { tokens: burst, last: Instant::now() }),
            rate_bps: rate_bps as f64,
            burst,
        }
    }

    /// Block until `n` bytes of budget are available, then consume them.
    fn take(&self, n: usize) {
        let need = n as f64;
        loop {
            let wait = {
                let mut s = self.state.lock().unwrap();
                let now = Instant::now();
                s.tokens =
                    (s.tokens + now.duration_since(s.last).as_secs_f64() * self.rate_bps)
                        .min(self.burst.max(need));
                s.last = now;
                if s.tokens >= need {
                    s.tokens -= need;
                    return;
                }
                (need - s.tokens) / self.rate_bps
            };
            std::thread::sleep(Duration::from_secs_f64(wait.min(0.05)));
        }
    }
}

/// A simulated storage device; cheap to clone (shared bucket).
#[derive(Debug, Clone)]
pub struct Disk {
    pub profile: DiskProfile,
    bucket: Option<Arc<TokenBucket>>,
    bytes_read: Arc<Mutex<u64>>,
    /// fault-injection probe (`--fault-plan`): `disk_error` makes `open`
    /// fail with a transient error, `disk_slow` stalls it first
    faults: FaultInjector,
}

impl Disk {
    pub fn new(profile: DiskProfile) -> Disk {
        let bucket = if profile.aggregate_bps > 0 {
            Some(Arc::new(TokenBucket::new(profile.aggregate_bps)))
        } else {
            None
        };
        Disk {
            profile,
            bucket,
            bytes_read: Arc::new(Mutex::new(0)),
            faults: FaultInjector::off(),
        }
    }

    /// Attach a fault injector; affects this handle and clones made after.
    pub fn set_faults(&mut self, faults: FaultInjector) {
        self.faults = faults;
    }

    pub fn preset(name: &str) -> Result<Disk> {
        Ok(Disk::new(DiskProfile::preset(name)?))
    }

    pub fn total_bytes_read(&self) -> u64 {
        *self.bytes_read.lock().unwrap()
    }

    /// Predicted single-stream cost of loading `bytes` (open latency +
    /// per-stream bandwidth), in ms.  The cost pin policy scores layers by
    /// this estimate per byte: seek-dominated small stages score higher
    /// than bandwidth-bound large ones, so they are kept preferentially.
    pub fn est_load_ms(&self, bytes: u64) -> f64 {
        let mut ms = self.profile.open_latency.as_secs_f64() * 1000.0;
        if self.profile.per_stream_bps > 0 {
            ms += bytes as f64 / self.profile.per_stream_bps as f64 * 1000.0;
        }
        ms
    }

    /// Open a file as one throttled stream.
    pub fn open(&self, path: &Path) -> Result<ThrottledReader> {
        if let Some(ms) = self.faults.fire_ms(FaultKind::DiskSlow) {
            // injected stuck medium: the read eventually completes, but a
            // hung pass should trip the watchdog first
            std::thread::sleep(Duration::from_millis(ms));
        }
        if self.faults.fire(FaultKind::DiskError) {
            bail!("injected transient disk error opening {}", path.display());
        }
        if !self.profile.open_latency.is_zero() {
            std::thread::sleep(self.profile.open_latency);
        }
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Ok(ThrottledReader {
            file,
            disk: self.clone(),
            started: Instant::now(),
            bytes: 0,
        })
    }

    /// Read a whole file through the throttle; returns (bytes, wall time).
    pub fn read_file(&self, path: &Path) -> Result<(Vec<u8>, Duration)> {
        let t0 = Instant::now();
        let mut r = self.open(path)?;
        let size = r.file.metadata().map(|m| m.len() as usize).unwrap_or(0);
        let mut buf = Vec::with_capacity(size);
        r.read_to_end(&mut buf)?;
        Ok((buf, t0.elapsed()))
    }
}

/// One throttled sequential read stream.
pub struct ThrottledReader {
    file: std::fs::File,
    disk: Disk,
    started: Instant,
    bytes: u64,
}

impl Read for ThrottledReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let cap = buf.len().min(self.disk.profile.chunk_bytes.max(1));
        let n = self.file.read(&mut buf[..cap])?;
        if n == 0 {
            return Ok(0);
        }
        if let Some(bucket) = &self.disk.bucket {
            bucket.take(n);
        }
        self.bytes += n as u64;
        *self.disk.bytes_read.lock().unwrap() += n as u64;
        if self.disk.profile.per_stream_bps > 0 {
            // enforce cumulative per-stream rate: sleep up to the ideal time
            let ideal = self.bytes as f64 / self.disk.profile.per_stream_bps as f64;
            let actual = self.started.elapsed().as_secs_f64();
            if ideal > actual {
                std::thread::sleep(Duration::from_secs_f64(ideal - actual));
            }
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpfile(bytes: usize) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("hermes_diskio_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("f{bytes}_{:?}.bin", std::thread::current().id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&vec![0xAB; bytes]).unwrap();
        path
    }

    #[test]
    fn unthrottled_reads_verbatim() {
        let path = tmpfile(100_000);
        let disk = Disk::preset("unthrottled").unwrap();
        let (buf, _) = disk.read_file(&path).unwrap();
        assert_eq!(buf.len(), 100_000);
        assert!(buf.iter().all(|&b| b == 0xAB));
        assert_eq!(disk.total_bytes_read(), 100_000);
    }

    #[test]
    fn per_stream_rate_enforced() {
        let path = tmpfile(500_000);
        // 5 MB/s -> 500 KB should take ~100 ms
        let disk = Disk::new(DiskProfile::custom(5_000_000, 0, 0));
        let (_, dt) = disk.read_file(&path).unwrap();
        let ms = dt.as_millis();
        assert!(ms >= 80, "too fast: {ms} ms");
        assert!(ms <= 400, "too slow: {ms} ms");
    }

    #[test]
    fn aggregate_cap_limits_parallel_streams() {
        // 2 streams, each capped at 8 MB/s stream rate, but aggregate 8 MB/s:
        // 2 x 400KB at 8MB/s aggregate ≈ 100ms total, vs ~50ms uncapped.
        let path1 = tmpfile(400_000);
        let path2 = tmpfile(400_001);
        let disk = Disk::new(DiskProfile::custom(8_000_000, 8_000_000, 0));
        let t0 = Instant::now();
        let d2 = disk.clone();
        let h = std::thread::spawn(move || d2.read_file(&path2).unwrap());
        disk.read_file(&path1).unwrap();
        h.join().unwrap();
        let ms = t0.elapsed().as_millis();
        assert!(ms >= 70, "aggregate cap not enforced: {ms} ms");
    }

    #[test]
    fn parallel_streams_scale_below_aggregate() {
        // per-stream 4 MB/s, aggregate 100 MB/s: two parallel 200KB reads
        // should take ~50ms (like one), not ~100ms (serialized).
        let path1 = tmpfile(200_000);
        let path2 = tmpfile(200_001);
        let disk = Disk::new(DiskProfile::custom(4_000_000, 100_000_000, 0));
        let t0 = Instant::now();
        let d2 = disk.clone();
        let h = std::thread::spawn(move || d2.read_file(&path2).unwrap());
        disk.read_file(&path1).unwrap();
        h.join().unwrap();
        let ms = t0.elapsed().as_millis();
        assert!(ms < 95, "parallel streams serialized: {ms} ms");
    }

    #[test]
    fn open_latency_applied() {
        let path = tmpfile(10);
        let disk = Disk::new(DiskProfile::custom(0, 0, 20_000)); // 20ms seek
        let (_, dt) = disk.read_file(&path).unwrap();
        assert!(dt.as_millis() >= 18, "{:?}", dt);
    }

    #[test]
    fn presets_parse() {
        for p in ["edge-emmc", "edge-sd", "edge-nvme", "unthrottled"] {
            Disk::preset(p).unwrap();
        }
        assert!(Disk::preset("floppy").is_err());
    }
}
