//! Iteration-level scheduling: continuous batching for serving lanes.
//!
//! The fixed-batch serving path (PR 2) freezes a batch at admission and
//! holds every slot until the whole batch drains — a finished row idles
//! its slot, and a request arriving one token after a batch started waits
//! a full decode.  This module is the vLLM-style alternative
//! (Orca's iteration-level scheduling): the lane re-forms its active set
//! at **every token boundary**, so requests join a running decode with
//! one prefix (prime) pass and leave the moment their last token lands.
//!
//! * a [`BatchComposer`] owns a lane's pending queue and admission
//!   policy.  Admission upgrades from pure EDF to **deadline-aware
//!   weighted-fair**: within a lane candidates are still picked
//!   earliest-deadline-first, across lanes a [`FairClock`] serves the
//!   smallest weighted virtual time (`vtime += 1/weight` per served
//!   iteration), so a heavy lane cannot starve a light one no matter how
//!   deep its backlog;
//! * per-lane **SLO targets** (`--slo-ms`, overridable per request over
//!   the TCP protocol) drive **explicit overload shedding**: a request
//!   whose queue wait alone already exceeds its target is rejected at its
//!   admission attempt (`shed_overload`) instead of wasting a slot it is
//!   guaranteed to miss with; expired deadlines are swept from the whole
//!   queue at every wake-up, not just the head;
//! * the composer never touches the engine: the serving loop owns the
//!   per-request decode states ([`crate::engine::DecodeState`]) and the
//!   KV blocks; the composer decides *who* runs this iteration and keeps
//!   the `joins` / `leaves` / `shed_overload` / `slo_attained_pct`
//!   ledger that flows into `RouterSummary` / `ServeSummary` /
//!   `serve --json`.
//!
//! Elastic coupling: budget shrinks call
//! [`BatchComposer::set_max_active`] with [`scaled_active_cap`] **before**
//! the eviction chain runs — fewer future joiners is the cheap lever, so
//! shared KV blocks are only evicted for pressure the smaller active set
//! still generates.

use std::collections::VecDeque;
use std::time::Instant;

/// Active-set cap when `--max-active` is not given.
pub const DEFAULT_MAX_ACTIVE: usize = 4;

/// Admission policy knobs for one lane.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// hard cap on requests decoding concurrently in this lane
    pub max_active: usize,
    /// per-lane SLO target (ms, end-to-end); a request may override it.
    /// `None` = no target: nothing is shed, `slo_attained_pct` is vacuous.
    pub slo_ms: Option<f64>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig { max_active: DEFAULT_MAX_ACTIVE, slo_ms: None }
    }
}

/// Why the composer dropped a pending request instead of admitting it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropReason {
    /// the request's hard deadline passed while it was queued
    Expired,
    /// queue wait alone already exceeds the request's SLO target —
    /// serving it would burn a slot on a guaranteed miss (overload)
    Overload,
}

impl DropReason {
    /// Wire slug for the structured `reason` field on rejected responses
    /// (the serving layer's reject taxonomy counts these per reason).
    pub fn slug(&self) -> &'static str {
        match self {
            DropReason::Expired => "deadline_expired",
            DropReason::Overload => "shed_overload",
        }
    }
}

/// One queued request: admission metadata plus the caller's payload
/// (the serving loops carry their `PendingReq` here).
#[derive(Debug)]
pub struct Entry<T> {
    pub enqueued: Instant,
    pub deadline: Option<Instant>,
    /// per-request SLO override (TCP `slo_ms` field); `None` = lane target
    pub slo_ms: Option<f64>,
    pub payload: T,
}

impl<T> Entry<T> {
    fn effective_slo(&self, lane: Option<f64>) -> Option<f64> {
        self.slo_ms.or(lane)
    }
}

/// Composer counters (per lane; summed into the router summary).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SchedStats {
    /// requests admitted into a running decode
    pub joins: u64,
    /// requests retired from the active set (served or failed)
    pub leaves: u64,
    /// requests shed at admission because their SLO was already blown
    pub shed_overload: u64,
    /// token-boundary iterations the lane ran
    pub iterations: u64,
    /// served requests that finished within their effective SLO target
    pub slo_met: u64,
    /// served requests that had an effective SLO target at all
    pub slo_counted: u64,
}

impl SchedStats {
    /// Percentage of SLO-targeted requests that met their target
    /// (100.0 when nothing carried a target — vacuously attained).
    pub fn slo_attained_pct(&self) -> f64 {
        if self.slo_counted == 0 {
            100.0
        } else {
            self.slo_met as f64 / self.slo_counted as f64 * 100.0
        }
    }

    pub fn merge(&mut self, other: &SchedStats) {
        self.joins += other.joins;
        self.leaves += other.leaves;
        self.shed_overload += other.shed_overload;
        self.iterations += other.iterations;
        self.slo_met += other.slo_met;
        self.slo_counted += other.slo_counted;
    }
}

/// Iteration-level admission for one lane: a pending queue with EDF pick
/// order, whole-queue deadline sweeps, SLO-blown shedding, and a runtime
/// active-set cap the elastic controller can shrink mid-flight.
#[derive(Debug)]
pub struct BatchComposer<T> {
    cfg: SchedConfig,
    /// runtime cap; starts at `cfg.max_active`, elastic steps move it
    max_active: usize,
    pending: VecDeque<Entry<T>>,
    stats: SchedStats,
}

impl<T> BatchComposer<T> {
    pub fn new(cfg: SchedConfig) -> BatchComposer<T> {
        let max_active = cfg.max_active.max(1);
        BatchComposer { cfg, max_active, pending: VecDeque::new(), stats: SchedStats::default() }
    }

    pub fn push(&mut self, entry: Entry<T>) {
        self.pending.push_back(entry);
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty()
    }

    /// Earliest hard deadline among pending requests (fill-window bound).
    pub fn earliest_deadline(&self) -> Option<Instant> {
        self.pending.iter().filter_map(|e| e.deadline).min()
    }

    /// Remove every pending request whose deadline has passed — the whole
    /// queue, not just the head, so an expired request parked behind a
    /// live head stops distorting fill windows and queue-wait stats.
    pub fn sweep_expired(&mut self, now: Instant) -> Vec<Entry<T>> {
        let mut expired = Vec::new();
        let mut keep = VecDeque::with_capacity(self.pending.len());
        for e in self.pending.drain(..) {
            if e.deadline.map(|d| d <= now).unwrap_or(false) {
                expired.push(e);
            } else {
                keep.push_back(e);
            }
        }
        self.pending = keep;
        expired
    }

    /// Take every pending request, emptying the queue (a dead lane sheds
    /// its whole backlog; the supervisor owns the rejection bookkeeping).
    pub fn drain_pending(&mut self) -> Vec<Entry<T>> {
        self.pending.drain(..).collect()
    }

    /// EDF index into `pending`: earliest deadline first, deadline-less
    /// requests after all deadlined ones, FIFO within a class.
    fn edf_best(&self) -> Option<usize> {
        self.pending
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| (e.deadline.is_none(), e.deadline, e.enqueued))
            .map(|(i, _)| i)
    }

    /// Fill free active slots at a token boundary.  Returns
    /// `(joins, drops)`: joiners to prime into the running batch, and
    /// requests dropped with the reason (expired deadline, or SLO already
    /// blown while queued — explicit overload shedding).
    pub fn admit(
        &mut self,
        now: Instant,
        active: usize,
    ) -> (Vec<Entry<T>>, Vec<(Entry<T>, DropReason)>) {
        let mut joins = Vec::new();
        let mut drops = Vec::new();
        while active + joins.len() < self.max_active {
            let Some(i) = self.edf_best() else { break };
            let e = self.pending.remove(i).unwrap();
            if e.deadline.map(|d| d <= now).unwrap_or(false) {
                drops.push((e, DropReason::Expired));
                continue;
            }
            if let Some(target) = e.effective_slo(self.cfg.slo_ms) {
                let waited_ms = now.duration_since(e.enqueued).as_secs_f64() * 1000.0;
                if waited_ms > target {
                    self.stats.shed_overload += 1;
                    drops.push((e, DropReason::Overload));
                    continue;
                }
            }
            self.stats.joins += 1;
            joins.push(e);
        }
        (joins, drops)
    }

    /// A joiner failed to start (prime pass error): take its join back so
    /// the ledger only counts requests that actually entered the batch.
    pub fn unjoin(&mut self) {
        self.stats.joins = self.stats.joins.saturating_sub(1);
    }

    /// Record one token-boundary iteration served.
    pub fn note_iteration(&mut self) {
        self.stats.iterations += 1;
    }

    /// Retire an active request.  `ok` = it completed (SLO attainment is
    /// only scored for served requests; failures just leave).
    pub fn retire(&mut self, enqueued: Instant, slo_ms: Option<f64>, now: Instant, ok: bool) {
        self.stats.leaves += 1;
        if !ok {
            return;
        }
        if let Some(target) = slo_ms.or(self.cfg.slo_ms) {
            self.stats.slo_counted += 1;
            let total_ms = now.duration_since(enqueued).as_secs_f64() * 1000.0;
            if total_ms <= target {
                self.stats.slo_met += 1;
            }
        }
    }

    /// The elastic lever: shrink (or restore) the active-set cap.  Takes
    /// effect at the next admission — running requests finish.
    pub fn set_max_active(&mut self, cap: usize) {
        self.max_active = cap.max(1);
    }

    pub fn max_active(&self) -> usize {
        self.max_active
    }

    pub fn lane_slo_ms(&self) -> Option<f64> {
        self.cfg.slo_ms
    }

    pub fn stats(&self) -> SchedStats {
        self.stats
    }
}

/// Budget-proportional active-cap scaling (floor 1): the elastic shrink
/// lever applied BEFORE the KV eviction chain, so admission pressure
/// drops first and shared blocks are only reclaimed for pressure the
/// smaller active set still generates.  A grow restores the original cap.
pub fn scaled_active_cap(orig_cap: usize, orig_budget: u64, new_budget: u64) -> usize {
    if orig_budget == 0 || new_budget >= orig_budget {
        return orig_cap.max(1);
    }
    ((orig_cap as u128 * new_budget as u128 / orig_budget as u128) as usize).max(1)
}

/// Start-time weighted fair queuing over lanes: each served iteration
/// charges `1/weight`, [`FairClock::pick`] serves the smallest virtual
/// time among runnable lanes.  An idle lane's clock is lifted to the
/// system's virtual time when it is next served, so sleeping never banks
/// an unbounded burst.
#[derive(Debug)]
pub struct FairClock {
    weights: Vec<f64>,
    vtime: Vec<f64>,
    /// system virtual time: the start tag of the last service
    base: f64,
}

impl FairClock {
    pub fn new(weights: &[f64]) -> FairClock {
        let weights: Vec<f64> =
            weights.iter().map(|w| if w.is_finite() && *w > 0.0 { *w } else { 1.0 }).collect();
        let n = weights.len();
        FairClock { weights, vtime: vec![0.0; n], base: 0.0 }
    }

    /// The runnable lane with the smallest virtual time (ties: lowest
    /// index).  `None` when nothing is runnable.
    pub fn pick(&self, runnable: &[bool]) -> Option<usize> {
        let mut best: Option<usize> = None;
        for i in 0..self.vtime.len().min(runnable.len()) {
            if !runnable[i] {
                continue;
            }
            if best.map(|b| self.vtime[i] < self.vtime[b]).unwrap_or(true) {
                best = Some(i);
            }
        }
        best
    }

    /// Charge one served iteration to `lane`.
    pub fn charge(&mut self, lane: usize) {
        if lane >= self.vtime.len() {
            return;
        }
        let start = self.vtime[lane].max(self.base);
        self.base = start;
        self.vtime[lane] = start + 1.0 / self.weights[lane];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn entry(age_ms: u64, deadline_in_ms: Option<i64>, slo: Option<f64>) -> Entry<u32> {
        let now = Instant::now();
        Entry {
            enqueued: now - Duration::from_millis(age_ms),
            deadline: deadline_in_ms.map(|d| {
                if d >= 0 {
                    now + Duration::from_millis(d as u64)
                } else {
                    now - Duration::from_millis((-d) as u64)
                }
            }),
            slo_ms: slo,
            payload: 0,
        }
    }

    #[test]
    fn admit_fills_slots_edf_first() {
        let mut c: BatchComposer<u32> =
            BatchComposer::new(SchedConfig { max_active: 2, slo_ms: None });
        c.push(entry(0, None, None));
        c.push(entry(0, Some(50), None));
        c.push(entry(0, Some(10), None));
        let (joins, drops) = c.admit(Instant::now(), 0);
        assert_eq!(joins.len(), 2);
        assert!(drops.is_empty());
        // tightest deadline admitted first, deadline-less request left queued
        assert!(joins[0].deadline < joins[1].deadline);
        assert_eq!(c.pending_len(), 1);
        assert_eq!(c.stats().joins, 2);
        // no free slot: nothing admitted
        let (joins, _) = c.admit(Instant::now(), 2);
        assert!(joins.is_empty());
    }

    #[test]
    fn whole_queue_deadline_sweep() {
        let mut c: BatchComposer<u32> = BatchComposer::new(SchedConfig::default());
        c.push(entry(0, Some(100), None)); // live head
        c.push(entry(5, Some(-1), None)); // expired BEHIND the head
        c.push(entry(0, None, None));
        let swept = c.sweep_expired(Instant::now());
        assert_eq!(swept.len(), 1, "expired entry behind a live head is swept");
        assert_eq!(c.pending_len(), 2);
    }

    #[test]
    fn slo_blown_requests_are_shed_at_admission() {
        let mut c: BatchComposer<u32> =
            BatchComposer::new(SchedConfig { max_active: 4, slo_ms: Some(20.0) });
        c.push(entry(50, None, None)); // waited 50 ms > 20 ms lane SLO
        c.push(entry(0, None, None)); // fresh
        c.push(entry(50, None, Some(500.0))); // per-request override is lax
        let (joins, drops) = c.admit(Instant::now(), 0);
        assert_eq!(joins.len(), 2);
        assert_eq!(drops.len(), 1);
        assert_eq!(drops[0].1, DropReason::Overload);
        assert_eq!(c.stats().shed_overload, 1);
    }

    #[test]
    fn retire_scores_slo_attainment() {
        let mut c: BatchComposer<u32> =
            BatchComposer::new(SchedConfig { max_active: 4, slo_ms: Some(100.0) });
        let now = Instant::now();
        c.retire(now - Duration::from_millis(10), None, now, true); // met
        c.retire(now - Duration::from_millis(500), None, now, true); // missed
        c.retire(now - Duration::from_millis(1), None, now, false); // failed: not scored
        c.retire(now - Duration::from_millis(1), Some(0.001), now, true); // override missed
        let s = c.stats();
        assert_eq!(s.leaves, 4);
        assert_eq!(s.slo_counted, 3);
        assert_eq!(s.slo_met, 1);
        assert!((s.slo_attained_pct() - 100.0 / 3.0).abs() < 1e-9);
        // no targets anywhere -> vacuous 100%
        assert_eq!(SchedStats::default().slo_attained_pct(), 100.0);
    }

    #[test]
    fn elastic_cap_scaling() {
        assert_eq!(scaled_active_cap(8, 1000, 500), 4);
        assert_eq!(scaled_active_cap(8, 1000, 1), 1, "floor is 1, never 0");
        assert_eq!(scaled_active_cap(8, 1000, 2000), 8, "grow restores, never exceeds");
        assert_eq!(scaled_active_cap(8, 0, 0), 8, "degenerate budgets change nothing");
        let mut c: BatchComposer<u32> =
            BatchComposer::new(SchedConfig { max_active: 8, slo_ms: None });
        c.set_max_active(scaled_active_cap(8, 1000, 250));
        assert_eq!(c.max_active(), 2);
        for _ in 0..8 {
            c.push(entry(0, None, None));
        }
        let (joins, _) = c.admit(Instant::now(), 0);
        assert_eq!(joins.len(), 2, "shrunk cap admits fewer joiners");
    }

    #[test]
    fn fair_clock_weighted_shares() {
        let mut f = FairClock::new(&[2.0, 1.0]);
        let mut served = [0usize; 2];
        for _ in 0..30 {
            let lane = f.pick(&[true, true]).unwrap();
            served[lane] += 1;
            f.charge(lane);
        }
        assert_eq!(served[0], 20, "2:1 weights serve 2:1");
        assert_eq!(served[1], 10);
        // an idle lane must not bank service while asleep
        let mut f = FairClock::new(&[1.0, 1.0]);
        for _ in 0..100 {
            let lane = f.pick(&[true, false]).unwrap();
            assert_eq!(lane, 0);
            f.charge(lane);
        }
        let mut burst = 0;
        for _ in 0..10 {
            let lane = f.pick(&[true, true]).unwrap();
            f.charge(lane);
            if lane == 1 {
                burst += 1;
            }
        }
        assert!(burst <= 6, "woken lane catches up, it does not monopolize: {burst}");
    }
}
