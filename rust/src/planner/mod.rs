//! Pipeline Planner (paper section IV-2).
//!
//! From the Layer Profiler's data it derives, for each memory constraint,
//! the number of Loading Agents to use:
//!
//! 1. an **analytic model** bounds the feasible agent range — peak memory
//!    grows by one resident body layer per extra agent, latency shrinks as
//!    m layer-computes overlap one layer-load (until compute- or
//!    aggregate-bandwidth-bound);
//! 2. optional **empirical pre-runs** (the paper's approach) refine the
//!    exact optimum within that range.
//!
//! The resulting [`Schedule`] is what the Execution Engine consults at
//! run time given the device's current constraint (`Schedule::pick`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{Mode, RunConfig};
use crate::engine::Engine;
use crate::profiler::ModelProfile;
use crate::util::json::Value;

/// Analytic peak-memory estimate for m Loading Agents.
///
/// Admission is sequential: besides the layer being computed (plus its
/// transient device upload) there are at most m admitted-but-uncomputed
/// layers resident.  The per-agent increment is a *body* layer (the layers
/// PIPELOAD streams); the largest stage (often the embedding table) is
/// charged once, since sequential admission never holds two copies of it.
pub fn predict_peak_bytes(
    max_stage_bytes: u64,
    body_layer_bytes: u64,
    act_bytes: u64,
    agents: usize,
) -> u64 {
    max_stage_bytes + (agents as u64 + 1) * body_layer_bytes + act_bytes
}

/// Analytic end-to-end latency estimate (one pass) for m agents.
///
/// Loads proceed m-wide: the loading frontier finishes around
/// `ceil(n/m) * load`; compute consumes serially (`n * compute`) behind a
/// one-layer pipeline fill.  The pass ends when both are done.
pub fn predict_latency_ms(load_ms: f64, compute_ms: f64, n_layers: usize, agents: usize) -> f64 {
    let n = n_layers as f64;
    let waves = (n_layers as f64 / agents as f64).ceil();
    let load_bound = waves * load_ms + compute_ms;
    let compute_bound = load_ms + n * compute_ms;
    load_bound.max(compute_bound)
}

/// Feasible agent counts under a budget, by the analytic peak model.
pub fn candidate_agents(
    profile_stats: &ModelProfile,
    body_kind: &str,
    budget: u64,
    max_agents: usize,
) -> Vec<usize> {
    let max_stage = profile_stats.max_stage_bytes();
    let (_, _, body) = profile_stats.body_means(body_kind);
    let body = if body == 0 { max_stage } else { body };
    let act = act_estimate(profile_stats);
    (1..=max_agents)
        .filter(|&m| predict_peak_bytes(max_stage, body, act, m) <= budget)
        .collect()
}

/// Rough activation overhead: largest output the profile produced is not
/// recorded per-layer, so reserve half a max stage as a conservative pad.
pub fn act_estimate(profile_stats: &ModelProfile) -> u64 {
    profile_stats.max_stage_bytes() / 2
}

/// Smallest budget the analytic model considers runnable (1 agent).
pub fn min_feasible_budget(profile_stats: &ModelProfile, body_kind: &str) -> u64 {
    let max_stage = profile_stats.max_stage_bytes();
    let (_, _, body) = profile_stats.body_means(body_kind);
    let body = if body == 0 { max_stage } else { body };
    predict_peak_bytes(max_stage, body, act_estimate(profile_stats), 1)
}

/// One (budget -> agents) decision with its evidence.
#[derive(Debug, Clone)]
pub struct PlanEntry {
    pub budget_bytes: u64,
    pub agents: usize,
    pub predicted_latency_ms: f64,
    pub predicted_peak_bytes: u64,
    pub measured_latency_ms: Option<f64>,
    pub measured_peak_bytes: Option<u64>,
}

/// The PIPELOAD execution schedule for one model on one storage medium.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub profile: String,
    pub disk: String,
    pub entries: Vec<PlanEntry>,
}

impl Schedule {
    /// Strategy selection: the largest planned budget <= the device's
    /// current constraint (paper Fig. 6c).
    pub fn pick(&self, budget_bytes: u64) -> Option<&PlanEntry> {
        self.entries
            .iter()
            .filter(|e| e.budget_bytes <= budget_bytes)
            .max_by_key(|e| e.budget_bytes)
    }

    pub fn to_json(&self) -> Value {
        Value::obj()
            .set("profile", self.profile.clone())
            .set("disk", self.disk.clone())
            .set(
                "entries",
                Value::Arr(
                    self.entries
                        .iter()
                        .map(|e| {
                            let mut o = Value::obj()
                                .set("budget_bytes", e.budget_bytes)
                                .set("agents", e.agents)
                                .set("predicted_latency_ms", e.predicted_latency_ms)
                                .set("predicted_peak_bytes", e.predicted_peak_bytes);
                            if let Some(m) = e.measured_latency_ms {
                                o = o.set("measured_latency_ms", m);
                            }
                            if let Some(m) = e.measured_peak_bytes {
                                o = o.set("measured_peak_bytes", m);
                            }
                            o
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(v: &Value) -> Result<Schedule> {
        Ok(Schedule {
            profile: v.req("profile")?.as_str()?.to_string(),
            disk: v.req("disk")?.as_str()?.to_string(),
            entries: v
                .req("entries")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(PlanEntry {
                        budget_bytes: e.req("budget_bytes")?.as_f64()? as u64,
                        agents: e.req("agents")?.as_usize()?,
                        predicted_latency_ms: e.req("predicted_latency_ms")?.as_f64()?,
                        predicted_peak_bytes: e.req("predicted_peak_bytes")?.as_f64()? as u64,
                        measured_latency_ms: e
                            .get("measured_latency_ms")
                            .and_then(|x| x.as_f64().ok()),
                        measured_peak_bytes: e
                            .get("measured_peak_bytes")
                            .map(|x| x.as_f64().map(|f| f as u64))
                            .transpose()?,
                    })
                })
                .collect::<Result<_>>()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().to_file(path)
    }

    pub fn load(path: &Path) -> Result<Schedule> {
        Schedule::from_json(&Value::from_file(path)?)
            .with_context(|| format!("parsing schedule {}", path.display()))
    }
}

/// Build a schedule from a profile.  With `empirical`, pre-runs PIPELOAD
/// for each candidate agent count (the paper's method); otherwise the
/// analytic model decides alone.
pub fn plan(
    engine: &Engine,
    stats: &ModelProfile,
    budgets: &[u64],
    max_agents: usize,
    empirical: bool,
) -> Result<Schedule> {
    plan_with_tokens(engine, stats, budgets, max_agents, empirical, None)
}

/// Like [`plan`] but overriding generated-token count for the pre-runs
/// (bounds Fig-7 sweep cost for generative models).
pub fn plan_with_tokens(
    engine: &Engine,
    stats: &ModelProfile,
    budgets: &[u64],
    max_agents: usize,
    empirical: bool,
    gen_tokens: Option<usize>,
) -> Result<Schedule> {
    let profile = engine.runtime.profile(&stats.profile)?;
    let body_kind = profile.body_kind().to_string();
    let (load_ms, compute_ms, _) = stats.body_means(&body_kind);
    let n = profile.stages.len();
    let mut entries = Vec::new();

    for &budget in budgets {
        let candidates = candidate_agents(stats, &body_kind, budget, max_agents);
        if candidates.is_empty() {
            bail!(
                "budget {} B infeasible for {} (max stage {} B)",
                budget,
                stats.profile,
                stats.max_stage_bytes()
            );
        }
        let (_, _, body_bytes) = stats.body_means(&body_kind);
        let body_bytes = if body_bytes == 0 { stats.max_stage_bytes() } else { body_bytes };
        let mut best: Option<PlanEntry> = None;
        for &m in &candidates {
            let predicted_latency = predict_latency_ms(load_ms, compute_ms, n, m);
            let predicted_peak =
                predict_peak_bytes(stats.max_stage_bytes(), body_bytes, act_estimate(stats), m);
            let (measured_latency, measured_peak) = if empirical {
                let cfg = RunConfig {
                    profile: stats.profile.clone(),
                    mode: Mode::PipeLoad,
                    agents: m,
                    budget: Some(budget),
                    disk: stats.disk.clone(),
                    batch: stats.batch,
                    gen_tokens,
                    ..RunConfig::default()
                };
                let (report, _) = engine
                    .run(&cfg)
                    .with_context(|| format!("pre-run m={m} budget={budget}"))?;
                (Some(report.latency_ms), Some(report.peak_bytes))
            } else {
                (None, None)
            };
            let score = measured_latency.unwrap_or(predicted_latency);
            let entry = PlanEntry {
                budget_bytes: budget,
                agents: m,
                predicted_latency_ms: predicted_latency,
                predicted_peak_bytes: predicted_peak,
                measured_latency_ms: measured_latency,
                measured_peak_bytes: measured_peak,
            };
            let better = match &best {
                None => true,
                Some(b) => score < b.measured_latency_ms.unwrap_or(b.predicted_latency_ms),
            };
            if better {
                best = Some(entry);
            }
        }
        entries.push(best.unwrap());
    }
    Ok(Schedule { profile: stats.profile.clone(), disk: stats.disk.clone(), entries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::LayerProfile;

    fn stats(load: f64, compute: f64, bytes: u64, n: usize) -> ModelProfile {
        ModelProfile {
            profile: "t".into(),
            disk: "edge-emmc".into(),
            batch: 1,
            layers: (0..n)
                .map(|i| LayerProfile {
                    stage: i,
                    kind: "encoder_layer".into(),
                    load_ms: load,
                    compute_ms: compute,
                    bytes,
                })
                .collect(),
        }
    }

    #[test]
    fn latency_model_monotone_in_agents_when_load_bound() {
        // load 10x compute: more agents must not predict higher latency
        let mut prev = f64::INFINITY;
        for m in 1..=8 {
            let t = predict_latency_ms(20.0, 2.0, 24, m);
            assert!(t <= prev + 1e-9, "m={m}: {t} > {prev}");
            prev = t;
        }
    }

    #[test]
    fn latency_model_saturates_at_compute_bound() {
        // with many agents the floor is load + n*compute
        let t = predict_latency_ms(20.0, 2.0, 24, 100);
        assert!((t - (20.0 + 48.0)).abs() < 1e-9);
    }

    #[test]
    fn peak_model_grows_one_body_layer_per_agent() {
        let base = predict_peak_bytes(400, 100, 50, 1);
        for m in 2..6 {
            assert_eq!(predict_peak_bytes(400, 100, 50, m) - base, 100 * (m as u64 - 1));
        }
        // largest stage charged once, not per agent
        assert_eq!(predict_peak_bytes(400, 100, 50, 1), 400 + 200 + 50);
    }

    #[test]
    fn candidates_respect_budget() {
        let s = stats(20.0, 2.0, 100, 10);
        // peak(m) = 100 + (m+1)*100 + 50 <= budget
        assert_eq!(candidate_agents(&s, "encoder_layer", 350, 8), vec![1]);
        assert_eq!(candidate_agents(&s, "encoder_layer", 450, 8), vec![1, 2]);
        assert!(candidate_agents(&s, "encoder_layer", 200, 8).is_empty());
    }

    #[test]
    fn candidates_monotone_in_budget() {
        let s = stats(20.0, 2.0, 100, 10);
        let mut prev = 0;
        for budget in [350u64, 450, 650, 1050] {
            let c = candidate_agents(&s, "encoder_layer", budget, 8);
            assert!(c.len() >= prev);
            prev = c.len();
        }
    }

    #[test]
    fn schedule_pick_selects_largest_fitting() {
        let sched = Schedule {
            profile: "t".into(),
            disk: "d".into(),
            entries: vec![
                PlanEntry { budget_bytes: 100, agents: 1, predicted_latency_ms: 10.0, predicted_peak_bytes: 90, measured_latency_ms: None, measured_peak_bytes: None },
                PlanEntry { budget_bytes: 200, agents: 3, predicted_latency_ms: 6.0, predicted_peak_bytes: 180, measured_latency_ms: None, measured_peak_bytes: None },
            ],
        };
        assert_eq!(sched.pick(150).unwrap().agents, 1);
        assert_eq!(sched.pick(500).unwrap().agents, 3);
        assert!(sched.pick(50).is_none());
    }

    #[test]
    fn single_layer_model_boundaries() {
        let s = stats(10.0, 1.0, 200, 1);
        // one layer: min feasible = peak(1 agent) = 200 + 2*200 + 100
        let min = min_feasible_budget(&s, "encoder_layer");
        assert_eq!(min, predict_peak_bytes(200, 200, 100, 1));
        assert_eq!(min, 700);
        // one byte below the smallest feasible plan: nothing fits
        assert!(candidate_agents(&s, "encoder_layer", min - 1, 4).is_empty());
        // exactly at the boundary: the 1-agent plan fits
        assert_eq!(candidate_agents(&s, "encoder_layer", min, 4), vec![1]);
        // a body kind with no layers falls back to max_stage (body == 0)
        assert_eq!(min_feasible_budget(&s, "decoder_layer"), min);
        // a single layer can't overlap anything: latency is flat in agents
        assert_eq!(predict_latency_ms(10.0, 1.0, 1, 1), 11.0);
        assert_eq!(predict_latency_ms(10.0, 1.0, 1, 8), 11.0);
    }

    #[test]
    fn schedule_pick_boundary_cases() {
        let entry = |budget: u64, agents: usize| PlanEntry {
            budget_bytes: budget,
            agents,
            predicted_latency_ms: 1.0,
            predicted_peak_bytes: budget,
            measured_latency_ms: None,
            measured_peak_bytes: None,
        };
        let sched = Schedule {
            profile: "t".into(),
            disk: "d".into(),
            entries: vec![entry(100, 1), entry(200, 3)],
        };
        // below the smallest planned budget: no plan, the caller must
        // keep (or refuse) its current configuration
        assert!(sched.pick(99).is_none());
        // exactly on a row is inclusive
        assert_eq!(sched.pick(100).unwrap().agents, 1);
        assert_eq!(sched.pick(200).unwrap().agents, 3);
        // between rows: the largest planned budget that still fits
        assert_eq!(sched.pick(199).unwrap().agents, 1);
        // single-row schedule behaves the same way
        let one = Schedule { profile: "t".into(), disk: "d".into(), entries: vec![entry(64, 2)] };
        assert!(one.pick(63).is_none());
        assert_eq!(one.pick(1 << 40).unwrap().agents, 2);
        // empty schedule never picks
        let empty = Schedule { profile: "t".into(), disk: "d".into(), entries: vec![] };
        assert!(empty.pick(u64::MAX).is_none());
    }

    #[test]
    fn peak_model_boundary_at_exact_budget() {
        let s = stats(20.0, 2.0, 100, 10);
        // peak(m) = 100 + (m+1)*100 + 50; m=3 -> 550
        assert_eq!(predict_peak_bytes(100, 100, 50, 3), 550);
        assert_eq!(candidate_agents(&s, "encoder_layer", 550, 8), vec![1, 2, 3]);
        assert_eq!(candidate_agents(&s, "encoder_layer", 549, 8), vec![1, 2]);
    }

    #[test]
    fn schedule_json_roundtrip() {
        let sched = Schedule {
            profile: "t".into(),
            disk: "d".into(),
            entries: vec![PlanEntry {
                budget_bytes: 128,
                agents: 2,
                predicted_latency_ms: 5.5,
                predicted_peak_bytes: 120,
                measured_latency_ms: Some(6.0),
                measured_peak_bytes: Some(110),
            }],
        };
        let rt = Schedule::from_json(&sched.to_json()).unwrap();
        assert_eq!(rt.entries[0].agents, 2);
        assert_eq!(rt.entries[0].measured_peak_bytes, Some(110));
    }
}
