//! The PIPELOAD signalling vocabulary (paper Fig. 4).
//!
//! Three signal families connect the agents:
//!
//! * `S_comp(k)` — Loading Agent -> Inference Agent: layer k is resident
//!   and ready for compute (carried on an mpsc channel with the payload).
//! * `S_dest(k)` — Inference Agent -> Daemon Agent: layer k has been
//!   computed; destroy its weights.
//! * `S_stop`   — Daemon Agent -> all Loading Agents: pause loading until
//!   memory frees up.  Realized as the blocking gate in
//!   [`crate::memory::MemoryAccountant::acquire`] (acquire-before-load is
//!   exactly "stop when usage is about to exceed the constraint").
//!
//! `SignalLog` records every signal with a timestamp so tests can assert
//! protocol properties (ordering, pairing) and traces can render them.

use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One signal instance (for the log; payloads travel on channels).
#[derive(Debug, Clone, PartialEq)]
pub enum Signal {
    /// computation ready: layer `stage` loaded by agent `agent`
    Comp { stage: usize, agent: usize },
    /// memory destruction: layer `stage` computed, weights can go
    Dest { stage: usize },
    /// loading stop: some agent blocked on the memory gate for `ms`
    Stop { agent: usize, ms: f64 },
    /// pipeline-level completion/abort markers
    Done,
    Abort { reason: String },
}

/// Append-only, thread-safe signal log with relative timestamps.
#[derive(Debug, Clone)]
pub struct SignalLog {
    start: Instant,
    entries: Arc<Mutex<Vec<(f64, Signal)>>>,
}

impl Default for SignalLog {
    fn default() -> Self {
        Self::new()
    }
}

impl SignalLog {
    pub fn new() -> SignalLog {
        SignalLog { start: Instant::now(), entries: Arc::new(Mutex::new(Vec::new())) }
    }

    pub fn emit(&self, s: Signal) {
        let t = self.start.elapsed().as_secs_f64() * 1000.0;
        self.entries.lock().unwrap().push((t, s));
    }

    pub fn snapshot(&self) -> Vec<(f64, Signal)> {
        self.entries.lock().unwrap().clone()
    }

    /// All stages that got a Comp signal, in emission order.
    pub fn comp_order(&self) -> Vec<usize> {
        self.snapshot()
            .iter()
            .filter_map(|(_, s)| match s {
                Signal::Comp { stage, .. } => Some(*stage),
                _ => None,
            })
            .collect()
    }

    /// All stages that got a Dest signal, in emission order.
    pub fn dest_order(&self) -> Vec<usize> {
        self.snapshot()
            .iter()
            .filter_map(|(_, s)| match s {
                Signal::Dest { stage } => Some(*stage),
                _ => None,
            })
            .collect()
    }

    /// Protocol check: every Dest(k) must come after Comp(k); used by tests.
    pub fn verify_dest_after_comp(&self) -> Result<(), String> {
        let log = self.snapshot();
        for (i, (_, s)) in log.iter().enumerate() {
            if let Signal::Dest { stage } = s {
                let comp_before = log[..i]
                    .iter()
                    .any(|(_, x)| matches!(x, Signal::Comp { stage: c, .. } if c == stage));
                if !comp_before {
                    return Err(format!("Dest({stage}) emitted before Comp({stage})"));
                }
            }
        }
        Ok(())
    }

    pub fn stop_count(&self) -> usize {
        self.snapshot().iter().filter(|(_, s)| matches!(s, Signal::Stop { .. })).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logs_in_order_with_timestamps() {
        let log = SignalLog::new();
        log.emit(Signal::Comp { stage: 0, agent: 1 });
        log.emit(Signal::Dest { stage: 0 });
        log.emit(Signal::Done);
        let snap = log.snapshot();
        assert_eq!(snap.len(), 3);
        assert!(snap[0].0 <= snap[1].0 && snap[1].0 <= snap[2].0);
        assert_eq!(log.comp_order(), vec![0]);
        assert_eq!(log.dest_order(), vec![0]);
    }

    #[test]
    fn protocol_violation_detected() {
        let log = SignalLog::new();
        log.emit(Signal::Dest { stage: 3 });
        assert!(log.verify_dest_after_comp().is_err());

        let ok = SignalLog::new();
        ok.emit(Signal::Comp { stage: 3, agent: 0 });
        ok.emit(Signal::Dest { stage: 3 });
        assert!(ok.verify_dest_after_comp().is_ok());
    }

    #[test]
    fn stop_counting() {
        let log = SignalLog::new();
        log.emit(Signal::Stop { agent: 0, ms: 5.0 });
        log.emit(Signal::Stop { agent: 2, ms: 1.0 });
        assert_eq!(log.stop_count(), 2);
    }

    #[test]
    fn shared_across_threads() {
        let log = SignalLog::new();
        let mut hs = Vec::new();
        for a in 0..4 {
            let l = log.clone();
            hs.push(std::thread::spawn(move || {
                for s in 0..10 {
                    l.emit(Signal::Comp { stage: s, agent: a });
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(log.snapshot().len(), 40);
    }
}
